"""Heterogeneous performance analysis of any assigned architecture — the
paper's end-to-end workflow as a CLI (deliverable b, example 4).

  PYTHONPATH=src python examples/profile_model.py --arch jamba-1.5-large

Steps: (1) build the arch at smoke scale, (2) extract its SDFG and assign
every node to a TPU backend component, (3) compute per-region rooflines and
the match (which component bounds each block), (4) measure instrumentation
overhead on the live step, (5) print the dispatch recommendation.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced
from repro.core import overhead, sdfg, tracepoints as tp
from repro.hw.specs import TPU_V5E
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-1.5-large", choices=list_archs())
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, args.seq), 0, cfg.vocab_size)
    fe = (
        jax.random.normal(key, (2, args.seq, cfg.d_model), jnp.float32)
        if cfg.frontend != "text" else None
    )

    def step(p, t):
        return lm.loss_fn(p, cfg, t, t, fe)[0]

    print(f"=== {args.arch} ({cfg.family}) — SDFG + roofline analysis ===")
    g = sdfg.extract(step, params, tokens)
    s = g.summary()
    total_f = max(sum(v["flops"] for v in s.values()), 1)
    total_b = max(sum(v["bytes"] for v in s.values()), 1)
    print(f"{'component':<6} {'nodes':>6} {'flops%':>8} {'bytes%':>8}")
    for b, v in s.items():
        if v["nodes"]:
            print(f"{b:<6} {int(v['nodes']):>6} {v['flops']/total_f:>8.1%} {v['bytes']/total_b:>8.1%}")

    print("\nhot regions (match = component that bounds the block):")
    regions = sorted(g.regions().values(), key=lambda r: -r.flops)[:6]
    for r in regions:
        name = r.name.split("/")[-1] or r.name
        print(f"  {name[:44]:44s} flops={r.flops:.2e} AI={r.intensity():6.1f} "
              f"match={r.match(TPU_V5E)}")

    # instrumentation overhead on this very model (Table I protocol, fast)
    base = jax.jit(step)
    jax.block_until_ready(base(params, tokens))
    with tp.enable("tape"):
        inst = jax.jit(tp.collect(step))
        jax.block_until_ready(inst(params, tokens))
    rows = [
        overhead.hyperfine(lambda: base(params, tokens), label="baseline", warmup=3, runs=20),
        overhead.hyperfine(lambda: inst(params, tokens), label="usdt", warmup=3, runs=20),
    ]
    print()
    print(overhead.table(rows))

    bound = max(
        ((b, v["flops"] / TPU_V5E.peak_flops_bf16 if b == "MXU" else v["bytes"] / TPU_V5E.hbm_bw)
         for b, v in s.items() if v["nodes"]),
        key=lambda kv: kv[1],
    )
    print(f"\ndispatch recommendation: dominant component = {bound[0]} "
          f"(would bound a TPU v5e step at {bound[1]*1e6:.1f} µs per device-shard)")


if __name__ == "__main__":
    main()
