"""Batched serving example (deliverable b): continuous-batching engine over a
stream of requests, with the paper's lifecycle tracing + overhead measurement.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.events import EventLog
from repro.models import lm
from repro.serving.engine import Engine, ServeConfig

cfg = reduced(get_config("gemma3-4b"))
key = jax.random.PRNGKey(0)
params = lm.init_params(cfg, key)
log = EventLog()
engine = Engine(
    cfg, params, ServeConfig(max_batch=4, max_seq=96, temperature=0.8), log=log
)

rng = np.random.default_rng(0)
t0 = time.time()
for i in range(10):
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    engine.submit(prompt, max_new=12)
results = engine.run_to_completion()
wall = time.time() - t0

total = sum(len(v) for v in results.values())
print(f"served {len(results)} requests, {total} tokens in {wall:.2f}s "
      f"({total/wall:.1f} tok/s on 1 CPU)")
# lifecycle trace: request spawn->exit latencies (the paper's process tracing)
spawns = {e.payload: e.t for e in log.events("spawn", "request")}
exits = {e.payload: e.t for e in log.events("exit", "request")}
lat = [exits[r] - spawns[r] for r in spawns if r in exits]
print(f"request latency: mean {np.mean(lat)*1e3:.0f} ms, p90 {np.percentile(lat, 90)*1e3:.0f} ms")
prefills = log.durations("prefill")
print(f"prefill: mean {np.mean(prefills)*1e3:.0f} ms over {len(prefills)} admissions")
sample = results[min(results)]
print("sample output tokens:", sample)
