"""End-to-end training driver (deliverable b): train a ~100M-param model for a
few hundred steps on synthetic data with the full production stack — sharded
train state, fault-tolerant supervisor, async checkpoints, instrumentation.

Default is a ~20M-param qwen2 variant for container speed; pass --full-100m
for the ~100M-class run (same code path, longer wall time).

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--full-100m]
"""
import argparse
import dataclasses
import json
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import LayerSpec, ModelConfig
from repro.core.events import EventLog
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime.supervisor import FailureInjector, Supervisor, SupervisorConfig
from repro.training import optim
from repro.training.step import TrainConfig, init_train_state, make_train_step


def small_lm(d_model: int, n_layers: int, vocab: int) -> ModelConfig:
    return ModelConfig(
        name=f"train-e2e-{d_model}x{n_layers}",
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=max(2, d_model // 64),
        n_kv_heads=max(2, d_model // 128),
        head_dim=64,
        d_ff=d_model * 4,
        vocab_size=vocab,
        layer_pattern=(LayerSpec("ga"),),
        param_dtype="float32",
        activation_dtype="float32",
        remat_policy="everything",
        loss_chunk=2048,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--fail-at", default="120", help="injected failure steps")
    args = ap.parse_args()

    cfg = small_lm(512, 8, 8192) if not args.full_100m else small_lm(768, 12, 32768)
    n_params_est = sum(
        x.size for x in jax.tree.leaves(jax.eval_shape(
            lambda k: __import__("repro.models.lm", fromlist=["lm"]).init_params(cfg, k),
            jax.random.PRNGKey(0),
        ))
    )
    print(f"model: {cfg.name}, ~{n_params_est/1e6:.1f}M params")

    tcfg = TrainConfig(
        opt=optim.AdamWConfig(
            peak_lr=3e-3, warmup_steps=max(20, args.steps // 20), total_steps=args.steps
        )
    )
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, tcfg, key)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))

    def batch_fn(i):
        b = data.batch(i)
        return {k: jnp.asarray(v) for k, v in b.items()}

    log = EventLog()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        sup = Supervisor(
            SupervisorConfig(ckpt_dir=ckpt_dir, ckpt_every=100, max_steps=args.steps),
            step_fn,
            batch_fn,
            state,
            log=log,
            failures=FailureInjector(
                tuple(int(s) for s in args.fail_at.split(",") if s)
            ),
        )
        t0 = time.time()
        out = sup.run()
        wall = time.time() - t0

    losses = [float(m["loss"]) for m in out["metrics"]]
    k = max(1, len(losses) // 10)
    print(json.dumps({
        "steps": out["steps"],
        "restarts": out["restarts"],
        "loss_first10_mean": round(sum(losses[:k]) / k, 4),
        "loss_last10_mean": round(sum(losses[-k:]) / k, 4),
        "tokens_per_s": round(out["steps"] * args.batch * args.seq / wall),
        "step_events": len(log.events("spawn", "step")),
        "checkpoints": len(log.events("spawn", "checkpoint")),
    }, indent=1))
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "training must reduce loss"
    print("OK: loss decreased through a failure/restart cycle")


if __name__ == "__main__":
    main()
