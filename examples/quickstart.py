"""Quickstart: the paper's technique in 60 lines.

Profiles a model step three ways — static tracepoints (USDT), dynamic probes
(uprobes), and the SDFG/roofline analysis — on one of the assigned
architectures.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import overhead, sdfg, tracepoints as tp, uprobes
from repro.core.events import EventLog
from repro.models import lm

# 1. a workload: one of the 10 assigned architectures, smoke scale
cfg = reduced(get_config("gemma2-27b"))
key = jax.random.PRNGKey(0)
params = lm.init_params(cfg, key)
tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
labels = jnp.roll(tokens, -1, axis=1)


def loss_step(p, t, l):
    return lm.loss_fn(p, cfg, t, l)[0]


# 2. USDT-style static tracepoints: markers are already in the model source;
#    they compile away unless enabled (byte-identical HLO — tested).
with tp.enable("tape"):
    instrumented = jax.jit(tp.collect(loss_step))
    (loss, tape) = instrumented(params, tokens, labels)
print("loss:", float(loss))
print("tracepoint tape:", {k: float(v[0]) for k, v in tape.items()})

# 3. uprobes-style dynamic probes: attach to the *unmodified* function,
#    tapping every matmul inside the 'ffn_dense' scope — no source change.
log = EventLog()
probed = uprobes.inject_probes(
    loss_step, uprobes.by_scope("final_norm"), mode="callback", log=log
)
jax.block_until_ready(jax.jit(probed)(params, tokens, labels))
jax.effects_barrier()
print("uprobe events:", [(e.name, round(float(e.payload), 4)) for e in log.events("probe")][:4])

# 4. the SDFG IR: every equation assigned to a TPU backend component
g = sdfg.extract(loss_step, params, tokens, labels)
summary = g.summary()
print("SDFG:", len(g.nodes), "nodes;",
      {b: int(v["nodes"]) for b, v in summary.items() if v["nodes"]})
top = sorted(g.regions().values(), key=lambda r: -r.flops)[:3]
for r in top:
    print(f"  hot region {r.name.split('/')[-1][:40]:40s} "
          f"flops={r.flops:.2e} intensity={r.intensity():.1f} -> {r.match()}")

# 5. overhead of the instrumentation itself (the paper's Table I protocol)
base = jax.jit(loss_step)
jax.block_until_ready(base(params, tokens, labels))
stats = overhead.hyperfine(
    lambda: base(params, tokens, labels), label="baseline", warmup=5, runs=30
)
print(f"baseline step: {stats.mean_ms:.1f} ms (±{stats.stddev_ms:.1f})")
