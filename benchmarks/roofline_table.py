"""Roofline table: render §Roofline from dry-run records.

Reads the JSONL written by ``repro.launch.dryrun --all --out <file>`` (the
40-cell baseline sweep) and prints the per-(arch × shape) three-term table
with bottleneck + useful-FLOPs ratio.  Does NOT launch the dry-run itself
(512 placeholder devices must stay out of this process); benchmarks/run.py
invokes the sweep as a subprocess when records are missing.
"""
from __future__ import annotations

import json
import os
import sys

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "out_dryrun_single_pod.jsonl")


def load(path: str) -> list[dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r  # last record wins
    return list(recs.values())


def render(recs: list[dict]) -> str:
    lines = []
    hdr = (
        f"{'arch':<18} {'shape':<12} {'bneck':<10} {'t_comp(s)':>10} {'t_mem(s)':>10} "
        f"{'t_coll(s)':>10} {'useful':>7} {'roofline':>8}"
    )
    lines.append(hdr)
    lines.append("-" * len(hdr))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["status"] == "skip":
            lines.append(f"{r['arch']:<18} {r['shape']:<12} SKIP ({r['reason'][:70]}…)")
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']:<18} {r['shape']:<12} FAIL {r.get('error','')[:70]}")
            continue
        lines.append(
            f"{r['arch']:<18} {r['shape']:<12} {r['bottleneck']:<10} "
            f"{r['t_compute_s']:>10.4f} {r['t_memory_s']:>10.4f} {r['t_collective_s']:>10.4f} "
            f"{r.get('useful_flops_ratio', 0) or 0:>7.3f} "
            f"{r.get('roofline_fraction', 0) or 0:>8.4f}"
        )
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH
    if not os.path.exists(path):
        print(f"no dry-run records at {path}; run:\n"
              f"  PYTHONPATH=src python -m repro.launch.dryrun --all --out {path}")
        raise SystemExit(1)
    recs = load(path)
    print(render(recs))
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] not in ("ok", "skip")]
    print(f"\ncells: {len(ok)} ok, {len(skip)} documented skips, {len(fail)} failures")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
