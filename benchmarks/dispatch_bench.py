"""Dispatch benchmark: static-worst vs static-best vs profile-guided placement.

Two workloads, mirroring the paper's dispatch motivation ("workloads allocated
to the processing units where they can execute most effectively"):

  A. kernel microbench — a suite of hot-spot ops at shapes chosen so no single
     static backend wins everywhere (the mamba chunked scan beats the
     reference scan at long T but loses at tiny T).  A static placement must
     eat the loss on part of the suite; the profile-guided dispatcher learns
     the per-(op, shape) argmin and should beat the best static total.
  B. serving — the continuous-batching engine run to completion under each
     placement policy; profile-guided must match the best static backend
     (steady-state decode has one dominant shape, so matching is the win).

Plus C (cross-run warm start), D (fleet aggregation warm start) and
E (repro.router: single replica vs a routed 2-replica fleet under the same
offered load — tail p95 and per-request routing overhead).

  PYTHONPATH=src python -m benchmarks.dispatch_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.events import EventLog
from repro.dispatch import DispatchConfig, Dispatcher, with_impl
from repro.dispatch.registry import host_registry
from repro.kernels import ops
from repro.models import lm
from repro.serving.engine import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


from benchmarks.kernel_bench import _time as _timeit  # noqa: E402  (shared harness)


def _rwkv_args(T: int, H: int = 4, K: int = 32):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (1, T, H, K))
    k = jax.random.normal(ks[1], (1, T, H, K))
    v = jax.random.normal(ks[2], (1, T, H, K))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (1, T, H, K)) * 0.3))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    s0 = jnp.zeros((1, H, K, K))
    return (r, k, v, w, u, s0)


def _attn_args(S: int, Hq: int = 4, Hkv: int = 2, D: int = 32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, S, Hq, D))
    k = jax.random.normal(ks[1], (1, S, Hkv, D))
    v = jax.random.normal(ks[2], (1, S, Hkv, D))
    return (q, k, v)


def _cases(fast: bool) -> list:
    # the recurrent scan favours the stepwise reference path on this backend;
    # attention favours the chunked online-softmax path — no static choice
    # wins both, which is the dispatcher's reason to exist.  Shapes are large
    # enough that the margins (5-10x) dwarf timer + dispatch bookkeeping noise.
    return [
        ("rwkv6_scan", lambda impl: jax.jit(lambda *a: ops.rwkv6_scan(*a, impl=impl)),
         _rwkv_args(512)),
        ("attention", lambda impl: jax.jit(lambda *a: ops.attention(*a, causal=True, impl=impl)),
         _attn_args(512 if fast else 1024, Hq=8, Hkv=4, D=64)),
    ]


def kernel_workload(fast: bool) -> dict:
    """Workload A: per-(op, shape) argmin beats any single static backend."""
    backends = [t.name for t in host_registry().targets()]
    reps = 5 if fast else 10
    cases = _cases(fast)

    # static placements: one backend for the whole suite
    static_ms = {b: 0.0 for b in backends}
    per_case = []
    for name, make, args in cases:
        row = {"case": f"{name}/{args[0].shape}"}
        for b in backends:
            ms = _timeit(make(b), *args, reps=reps)
            row[b] = round(ms, 3)
            static_ms[b] += ms
        per_case.append(row)

    # profile-guided: explore until warm, then steady-state argmin per case
    log = EventLog()
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=2), log=log)
    variants = [
        {b: make(b) for b in backends} for _, make, _ in cases
    ]
    for _ in range(2 * len(backends)):  # exploration rounds (feed the store)
        for (name, _, args), vs in zip(cases, variants):
            disp.dispatch(name, vs, *args)
    profiled_ms = 0.0
    chosen = []
    for (name, _, args), vs in zip(cases, variants):
        t0 = time.perf_counter()
        for _ in range(reps):
            disp.dispatch(name, vs, *args)
        profiled_ms += (time.perf_counter() - t0) / reps * 1e3
        chosen.append(disp.decisions[-1].backend)

    best = min(static_ms, key=static_ms.get)
    worst = max(static_ms, key=static_ms.get)
    return {
        "per_case_ms": per_case,
        "static_ms": {b: round(v, 3) for b, v in static_ms.items()},
        "static_best": best,
        "static_worst": worst,
        "profiled_ms": round(profiled_ms, 3),
        "profiled_chosen": chosen,
        "dispatch_events": len(log.events(kind="dispatch")),
        "profiled_beats_or_matches_best": profiled_ms <= static_ms[best] * 1.10,
    }


def warmstart_workload(
    fast: bool, profile_in: str | None = None, profile_out: str | None = None
) -> dict:
    """Workload C: cross-run profile persistence (the --profile-in crossover).

    A cold profiled dispatcher must explore every (op, backend) pair before
    its store is warm; a dispatcher warm-started from a previous run's
    ProfileStore skips that phase and lands on the steady-state backend from
    the first dispatch.  Measured here as the count of ``source == explore``
    decisions, cold vs warm.
    """
    cases = _cases(fast)
    rounds = 2 * len(host_registry().targets()) + 3

    def run_profiled(store):
        log = EventLog()
        disp = Dispatcher(
            DispatchConfig(policy="profiled", min_samples=2), log=log, store=store
        )
        variants = [
            {t.name: make(t.impl) for t in disp.registry.targets()} for _, make, _ in cases
        ]
        for _ in range(rounds):
            for (name, _, args), vs in zip(cases, variants):
                disp.dispatch(name, vs, *args)
        steady = {}
        for name, _, _ in cases:
            steady[name] = [d for d in disp.decisions if d.op == name][-1].backend
        return disp, steady

    cold_disp, cold_steady = run_profiled(None)
    if profile_out:
        with open(profile_out, "w") as f:
            f.write(cold_disp.store.to_json())

    if profile_in is not None:
        from repro.trace import load_profile_store

        warm_store = load_profile_store(profile_in)
    else:
        # round-trip through JSON: exactly what --profile-out → --profile-in does
        from repro.dispatch.profiles import ProfileStore

        warm_store = ProfileStore.from_json(cold_disp.store.to_json())
    warm_disp, warm_steady = run_profiled(warm_store)

    cold_sum, warm_sum = cold_disp.summary(), warm_disp.summary()
    first_warm = {name: [d for d in warm_disp.decisions if d.op == name][0].backend
                  for name, _, _ in cases}
    return {
        "rounds": rounds,
        "cold_explore_dispatches": cold_sum["explore_dispatches"],
        "warm_explore_dispatches": warm_sum["explore_dispatches"],
        "cold_steady_backend": cold_steady,
        "warm_steady_backend": warm_steady,
        "warm_first_choice": first_warm,
        "warm_skips_exploration": (
            warm_sum["explore_dispatches"] < cold_sum["explore_dispatches"]
            and first_warm == cold_steady
        ),
    }


def fleet_workload(fast: bool) -> dict:
    """Workload D: central fleet aggregation warm-start (repro.fleet).

    A cold run explores, then pushes its measured ProfileStore into a fleet
    store; a second, fresh process-equivalent run pulls the matching snapshot
    and should dispatch with zero exploration from its very first call.
    Measured as exploration counts AND tail latency: exploration executes the
    slow backends too, so the cold run's p95 per-dispatch latency carries the
    worst backend while the fleet-warmed run's tail stays near the argmin.
    """
    import tempfile

    import numpy as np

    from repro.fleet import FleetClient, FleetPusher
    from repro.trace.session import git_sha

    cases = _cases(fast)
    rounds = 2 * len(host_registry().targets()) + 3

    with tempfile.TemporaryDirectory(prefix="fleet_bench_") as root:
        client = FleetClient(root)

        def run(pull: bool) -> dict:
            log = EventLog()
            disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=2), log=log)
            sha, chip = git_sha(), disp.chip.name
            match = None
            if pull:
                pulled = client.pull(sha, chip)
                if pulled["store"] is not None:
                    disp.store.merge(pulled["store"])
                match = pulled["match"]
            pusher = FleetPusher(client, disp.store, sha, chip)
            variants = [
                {t.name: make(t.impl) for t in disp.registry.targets()}
                for _, make, _ in cases
            ]
            lat = []
            for _ in range(rounds):
                for (name, _, args), vs in zip(cases, variants):
                    disp.dispatch(name, vs, *args)
                    lat.append(disp.decisions[-1].measured_s)
            pusher.push()
            return {
                "explore_dispatches": disp.summary()["explore_dispatches"],
                "pull_match": match,
                "pushed_samples": pusher.pushed_samples,
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                "tail_p95_ms": round(float(np.percentile(lat, 95)) * 1e3, 3),
            }

        cold = run(pull=False)
        warm = run(pull=True)

    return {
        "rounds": rounds,
        "cold": cold,
        "warm": warm,
        "warm_explores_zero": warm["explore_dispatches"] == 0,
        # advisory on shared runners: exploration executes the slow backends,
        # so the cold tail should dominate the fleet-warmed tail
        "warm_tail_le_cold": warm["tail_p95_ms"] <= cold["tail_p95_ms"] * 1.25,
    }


def router_workload(fast: bool) -> dict:
    """Workload E: one replica vs a routed 2-replica fleet, same offered load.

    Spawns ``python -m repro.router`` twice (synthetic replicas — this bench
    measures the routing tier, not the model) and drives the identical
    deterministic workload through the front door.  Two replicas under the
    same offered load should cut the tail (two decode loops share the
    batching pressure), and the router's own decision cost shows up as
    ``route_overhead_ms`` — both land in the stamped bench JSON, where the
    ``repro.trace diff`` gate picks up every ``*_ms`` leaf automatically.
    """
    import signal
    import subprocess
    import sys
    import tempfile

    from repro.router.loadgen import build_specs, run as loadgen_run
    from repro.utils.ready import read_ready_info, wait_for_ready_file

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    n_req = 40 if fast else 80

    def routed(replicas: int, workdir: str) -> dict:
        os.makedirs(workdir, exist_ok=True)
        ready = os.path.join(workdir, "router.ready")
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.router",
             "--replicas", str(replicas), "--synthetic",
             "--synthetic-ms-per-token", "4", "--max-batch", "2",
             "--queue-depth", "64", "--port", "0",
             "--ready-file", ready, "--workdir", os.path.join(workdir, "w")],
            env=env, cwd=repo,
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        )
        try:
            wait_for_ready_file(ready, timeout_s=120, proc=proc)
            url = read_ready_info(ready)["url"]
            specs = build_specs(n_req, [8, 16, 32], 8, seed=2)
            return loadgen_run(url, specs, concurrency=6, timeout_s=120)
        finally:
            proc.send_signal(signal.SIGTERM)
            try:
                proc.wait(timeout=30)
            except subprocess.TimeoutExpired:
                proc.kill()

    with tempfile.TemporaryDirectory(prefix="router_bench_") as root:
        single = routed(1, os.path.join(root, "single"))
        fleet = routed(2, os.path.join(root, "fleet"))

    return {
        "requests": n_req,
        "single_tail_p95_ms": round(single["latency_ms"]["p95"], 3),
        "routed_tail_p95_ms": round(fleet["latency_ms"]["p95"], 3),
        "route_overhead_ms": fleet["route_ms"]["mean"],
        "single_by_replica": single["by_replica"],
        "routed_by_replica": fleet["by_replica"],
        # advisory on shared runners (1.10 slack for timer + scheduler noise)
        "routed_tail_le_single": (
            fleet["latency_ms"]["p95"] <= single["latency_ms"]["p95"] * 1.10),
        "completed_all": (
            single["completed"] == fleet["completed"] == n_req
            and single["duplicates"] == fleet["duplicates"] == 0),
    }


def serving_workload(fast: bool) -> dict:
    """Workload B: engine wall-time under each placement policy."""
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_params(cfg, KEY)
    n_req = 8 if fast else 12
    max_new = 12 if fast else 24
    backends = [t.name for t in host_registry().targets()]

    def run_engine(policy: str, static_backend: str = "chunked"):
        log = EventLog()
        disp = Dispatcher(
            DispatchConfig(policy=policy, static_backend=static_backend, min_samples=2),
            log=log,
        )
        eng = Engine(cfg, params, ServeConfig(max_batch=2, max_seq=64), log=log,
                     dispatcher=disp)
        # warm batch: compiles + profile exploration
        for _ in range(n_req):
            eng.submit([7, 3, 5, 2] * 4, max_new=max_new)
        eng.run_to_completion()
        # measured batch: steady state
        t0 = time.perf_counter()
        for _ in range(n_req):
            eng.submit([7, 3, 5, 2] * 4, max_new=max_new)
        results = eng.run_to_completion()
        wall = time.perf_counter() - t0
        toks = sum(len(v) for v in results.values())
        return wall, toks, len(log.events(kind="dispatch")), disp

    rows = {}
    for b in backends:
        wall, toks, _, _ = run_engine("static", b)
        rows[f"static:{b}"] = {"wall_s": round(wall, 3), "tokens_per_s": round(toks / wall, 1)}
    wall, toks, n_events, disp = run_engine("profiled")
    rows["profiled"] = {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(toks / wall, 1),
        "dispatch_events": n_events,
        "by_op": disp.summary()["by_op"],
    }
    statics = {k: v["wall_s"] for k, v in rows.items() if k.startswith("static:")}
    best = min(statics, key=statics.get)
    return {
        "rows": rows,
        "static_best": best,
        "profiled_beats_or_matches_best": rows["profiled"]["wall_s"] <= statics[best] * 1.15,
    }


def run(
    fast: bool = False, profile_in: str | None = None, profile_out: str | None = None
) -> dict:
    print("-- workload A: kernel microbench suite --")
    a = kernel_workload(fast)
    print(f"{'case':<28}" + "".join(f"{b:>10}" for b in a["static_ms"]))
    for row in a["per_case_ms"]:
        print(f"{row['case']:<28}" + "".join(f"{row[b]:>10.3f}" for b in a["static_ms"]))
    print(
        f"static totals: {a['static_ms']}  (best={a['static_best']}, worst={a['static_worst']})\n"
        f"profiled total: {a['profiled_ms']} ms, chose {a['profiled_chosen']}, "
        f"{a['dispatch_events']} dispatch events, "
        f"beats/matches best: {a['profiled_beats_or_matches_best']}"
    )

    print("\n-- workload B: serving engine --")
    b = serving_workload(fast)
    for k, v in b["rows"].items():
        print(f"{k:<18} wall={v['wall_s']}s  tok/s={v['tokens_per_s']}")
    print(
        f"best static: {b['static_best']}; profiled beats/matches best: "
        f"{b['profiled_beats_or_matches_best']}"
    )

    print("\n-- workload C: cross-run warm start (--profile-in) --")
    c = warmstart_workload(fast, profile_in=profile_in, profile_out=profile_out)
    print(
        f"exploration dispatches: cold={c['cold_explore_dispatches']} "
        f"warm={c['warm_explore_dispatches']} (over {c['rounds']} rounds)\n"
        f"steady-state backends: cold={c['cold_steady_backend']}, warm first "
        f"choice={c['warm_first_choice']}\n"
        f"warm start skips exploration: {c['warm_skips_exploration']}"
    )

    print("\n-- workload D: fleet aggregation warm start (repro.fleet) --")
    d = fleet_workload(fast)
    print(
        f"exploration dispatches: cold={d['cold']['explore_dispatches']} "
        f"fleet-warm={d['warm']['explore_dispatches']} "
        f"(pull match: {d['warm']['pull_match']})\n"
        f"per-dispatch latency: cold p50={d['cold']['p50_ms']}ms "
        f"p95={d['cold']['tail_p95_ms']}ms | warm p50={d['warm']['p50_ms']}ms "
        f"p95={d['warm']['tail_p95_ms']}ms\n"
        f"fleet warm start skips exploration: {d['warm_explores_zero']}"
    )

    print("\n-- workload E: routed replica fleet (repro.router) --")
    e = router_workload(fast)
    print(
        f"tail p95: single replica={e['single_tail_p95_ms']}ms | "
        f"2 routed replicas={e['routed_tail_p95_ms']}ms "
        f"(route overhead {e['route_overhead_ms']}ms/req)\n"
        f"routed spread: {e['routed_by_replica']}; all completed: "
        f"{e['completed_all']}; routed tail <= single: {e['routed_tail_le_single']}"
    )
    return {"kernel": a, "serving": b, "warm_start": c, "fleet": d, "router": e}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--profile-in", default=None, metavar="PATH",
                    help="warm-start workload C from a session/store JSON "
                         "(default: round-trips the cold run's own store)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write workload C's cold-run ProfileStore JSON")
    args = ap.parse_args()
    rec = run(fast=args.fast, profile_in=args.profile_in, profile_out=args.profile_out)
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "out_dispatch.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
