"""Fig. 2 reproduction: system-vs-user CPU-time breakdown per configuration.

The paper's finding: Uprobes incurs more *system* time than USDT (kernel
trampolines), while USDT's cost stays in user time.  Our analogue: host
callbacks (the uprobe trap) cross the runtime boundary and synchronise
threads — kernel-side work — while the USDT tape is pure device-graph
compute (user time).
"""
from __future__ import annotations

import json

from benchmarks.overhead_table1 import bench_microbench


def run(fast: bool = False) -> dict:
    rows = bench_microbench(warmup=30, runs=200) if fast else bench_microbench()
    base = rows[0]
    print("== Fig 2 analogue: sys/user split over the measured phase ==")
    print(f"{'type':<12} {'user(s)':>8} {'sys(s)':>8} {'Δuser':>8} {'Δsys':>8}")
    out = []
    for r in rows:
        du, ds = r.user_s - base.user_s, r.system_s - base.system_s
        print(f"{r.label:<12} {r.user_s:>8.2f} {r.system_s:>8.2f} {du:>+8.2f} {ds:>+8.2f}")
        out.append(
            {"label": r.label, "user_s": r.user_s, "system_s": r.system_s,
             "delta_user_s": du, "delta_system_s": ds}
        )
    return {"rows": out}


def main() -> None:
    rec = run()
    with open("benchmarks/out_breakdown_fig2.json", "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
