"""Autotune benchmark: measured design-space sweep, tuned vs default.

Runs the real-mode ``repro.tune`` sweep over a fresh ProfileStore (CPU:
the chunked spaces; the Pallas spaces need a TPU and are excluded by the
explorer itself) and reports, per kernel, the shipped default's measured
time against the sweep winner.

Speedup >= 1.0 holds by construction — the default point is always
enumerated, never pruned, and competes in the same argmin — so a row
below 1.0 means the sweep machinery itself broke, which is exactly what
the bench-smoke gate checks.
"""
from __future__ import annotations

import json

from repro.core.events import EventLog
from repro.dispatch.profiles import ProfileStore
from repro.tune import Explorer, SweepSettings

FAST_OPS = ["rwkv6_scan", "mamba_scan"]


def run(fast: bool = False) -> dict:
    store = ProfileStore()
    log = EventLog()
    settings = SweepSettings(mode="real", warmup=1, repeats=2 if fast else 3)
    explorer = Explorer(store, log=log, settings=settings)
    summary = explorer.sweep(FAST_OPS if fast else None)

    rows = []
    for key in sorted(summary["winners"]):
        win = summary["winners"][key]
        rows.append({
            "op": win["op"],
            "backend": win["backend"],
            "config": win["config"],
            "default_ms": round(win["default_s"] * 1e3, 4),
            "best_ms": round(win["best_s"] * 1e3, 4),
            "speedup": round(win["speedup"], 3),
        })

    print(f"{'op':<20} {'backend':<10} {'winner':<14} {'default_ms':>11} "
          f"{'best_ms':>9} {'speedup':>8}")
    for row in rows:
        print(f"{row['op']:<20} {row['backend']:<10} "
              f"{row['config'] or '(defaults)':<14} {row['default_ms']:>11.4f} "
              f"{row['best_ms']:>9.4f} {row['speedup']:>7.2f}x")

    return {
        "mode": settings.mode,
        "points_total": summary["points_total"],
        "pruned": summary["pruned"],
        "sweep_points": summary["sweep_points"],
        "rows": rows,
    }


def main() -> None:
    rec = run()
    with open("benchmarks/out_tune.json", "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
