"""Kernel micro-benchmarks: wall-time of the production jnp paths on CPU plus
analytic TPU-roofline projections for the Pallas kernels (this container has
no TPU; the projection prices each kernel's FLOPs/bytes against v5e terms).

Each row stamps the active tuned config of the impl it measures
(``repro.tune`` winners installed via ``kernels.ops``; "" = shipped
defaults), so bench artifacts record *which* configuration produced each
number — comparable across runs that tuned differently.
"""
from __future__ import annotations

import json
import math
import time

import jax
import jax.numpy as jnp

from repro.hw.specs import TPU_V5E
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e3


def run(fast: bool = False) -> dict:
    rows = []
    chip = TPU_V5E

    # flash attention (train shape slice)
    B, S, Hq, Hkv, D = 1, 1024, 8, 4, 128
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    fa_bk = int(ops.tuned_overrides("flash_attention", "chunked").get("block_k", 512))
    fa = jax.jit(lambda q, k, v: ref.flash_attention_chunked(q, k, v, causal=True,
                                                            block_k=fa_bk))
    ms = _time(fa, q, k, v, reps=5 if fast else 20)
    flops = 4 * B * Hq * D * S * (S + 1) / 2
    rows.append({
        "kernel": "flash_attention", "shape": f"B{B} S{S} H{Hq}/{Hkv} D{D}",
        "config": ops.active_config("flash_attention", "chunked"),
        "cpu_ms": round(ms, 2), "flops": flops,
        "tpu_compute_us": round(flops / chip.peak_flops_bf16 * 1e6, 1),
    })

    # local window attention
    swa = jax.jit(lambda q, k, v: ref.local_window_attention(q, k, v, window=256))
    ms2 = _time(swa, q, k, v, reps=5 if fast else 20)
    flops2 = 4 * B * Hq * D * (S * 256 - 256 * 255 / 2)
    rows.append({
        "kernel": "local_window_attention", "shape": f"S{S} w256",
        "config": ops.active_config("local_window_attention", "chunked"),
        "cpu_ms": round(ms2, 2), "flops": flops2,
        "tpu_compute_us": round(flops2 / chip.peak_flops_bf16 * 1e6, 1),
    })

    # gmm
    E, C, Dm, F = 8, 256, 512, 1024
    x = jax.random.normal(ks[0], (E, C, Dm), jnp.float32)
    w = jax.random.normal(ks[1], (E, Dm, F), jnp.float32)
    g = jax.jit(ref.gmm_ref)
    ms3 = _time(g, x, w, reps=5 if fast else 20)
    flops3 = 2 * E * C * Dm * F
    rows.append({
        "kernel": "moe_gmm", "shape": f"E{E} C{C} D{Dm} F{F}",
        "config": ops.active_config("moe_gmm", "ref"),
        "cpu_ms": round(ms3, 2), "flops": flops3,
        "tpu_compute_us": round(flops3 / chip.peak_flops_bf16 * 1e6, 1),
    })

    # rwkv6 chunked
    B2, T, H, K = 1, 512, 8, 64
    r = jax.random.normal(ks[0], (B2, T, H, K))
    kk = jax.random.normal(ks[1], (B2, T, H, K))
    vv = jax.random.normal(ks[2], (B2, T, H, K))
    w6 = jnp.exp(-jnp.exp(jax.random.normal(ks[0], (B2, T, H, K)) * 0.3))
    u = jax.random.normal(ks[1], (H, K)) * 0.3
    s0 = jnp.zeros((B2, H, K, K))
    L = ops._scan_chunk("rwkv6_scan", "chunked", 32, T)
    rw = jax.jit(lambda *a: ref.rwkv6_scan_chunked(*a, chunk=L))
    ms4 = _time(rw, r, kk, vv, w6, u, s0, reps=3 if fast else 10)
    flops4 = B2 * H * T * (2 * L * K + 2 * L * K + 2 * K * K)  # att + intra + inter
    rows.append({
        "kernel": "rwkv6_scan", "shape": f"T{T} H{H} K{K} L{L}",
        "config": ops.active_config("rwkv6_scan", "chunked"),
        "cpu_ms": round(ms4, 2), "flops": flops4,
        "tpu_compute_us": round(flops4 / chip.peak_flops_bf16 * 1e6, 1),
    })

    # mamba chunked
    DI, N = 1024, 16
    x2 = jax.random.normal(ks[0], (B2, T, DI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B2, T, DI)))
    A = -jnp.exp(jax.random.normal(ks[2], (DI, N)) * 0.3)
    Bm = jax.random.normal(ks[0], (B2, T, N))
    Cm = jax.random.normal(ks[1], (B2, T, N))
    Dp = jnp.ones((DI,))
    h0 = jnp.zeros((B2, DI, N))
    mchunk = ops._scan_chunk("mamba_scan", "chunked", 64, T)
    mb = jax.jit(lambda *a: ref.mamba_scan_chunked(*a, chunk=mchunk))
    ms5 = _time(mb, x2, dt, A, Bm, Cm, Dp, h0, reps=3 if fast else 10)
    bytes5 = B2 * T * (DI * 2 + N * 2) * 4 + B2 * T * DI * N * 4
    rows.append({
        "kernel": "mamba_scan", "shape": f"T{T} DI{DI} N{N}",
        "config": ops.active_config("mamba_scan", "chunked"),
        "cpu_ms": round(ms5, 2), "flops": B2 * T * DI * N * 10,
        "tpu_memory_us": round(bytes5 / chip.hbm_bw * 1e6, 1),
    })

    print(f"{'kernel':<24} {'shape':<22} {'config':<14} {'cpu_ms':>8} {'tpu_proj_us':>11}")
    for row in rows:
        proj = row.get("tpu_compute_us", row.get("tpu_memory_us", 0))
        print(f"{row['kernel']:<24} {row['shape']:<22} "
              f"{row.get('config') or '(defaults)':<14} "
              f"{row['cpu_ms']:>8.2f} {proj:>11.1f}")
    return {"rows": rows}


def main() -> None:
    rec = run()
    with open("benchmarks/out_kernels.json", "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
