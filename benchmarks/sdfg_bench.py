"""SDFG extraction benchmark (Fig. 1 machinery, architecture-agnostic claim).

Extracts the dataflow multigraph of every assigned architecture's loss step,
reports per-backend work assignment and extraction latency — demonstrating
the IR layer handles dense / MoE / SSM / RWKV / hybrid uniformly.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced
from repro.core import sdfg
from repro.models import lm


def run(fast: bool = False) -> dict:
    rows = []
    archs = list_archs()[:4] if fast else list_archs()
    key = jax.random.PRNGKey(0)
    print(f"{'arch':<20} {'nodes':>6} {'extract_ms':>10} "
          f"{'MXU%flops':>9} {'VPU%flops':>9} {'regions':>8} {'top_region_match':>18}")
    for arch in archs:
        cfg = reduced(get_config(arch))
        params = lm.init_params(cfg, key)
        tokens = jnp.zeros((2, 32), jnp.int32)
        fe = (
            jnp.zeros((2, 32, cfg.d_model), jnp.float32)
            if cfg.frontend != "text" else None
        )

        def step(p, t):
            return lm.loss_fn(p, cfg, t, t, fe)[0]

        t0 = time.perf_counter()
        g = sdfg.extract(step, params, tokens)
        dt = (time.perf_counter() - t0) * 1e3
        s = g.summary()
        total_flops = max(sum(v["flops"] for v in s.values()), 1.0)
        regions = g.regions()
        top = max(regions.values(), key=lambda r: r.flops)
        row = {
            "arch": arch,
            "nodes": len(g.nodes),
            "edges": len(g.edges),
            "extract_ms": round(dt, 1),
            "mxu_flops_frac": round(s[sdfg.MXU]["flops"] / total_flops, 4),
            "vpu_flops_frac": round(s[sdfg.VPU]["flops"] / total_flops, 4),
            "regions": len(regions),
            "top_region_match": top.match(),
        }
        rows.append(row)
        print(f"{arch:<20} {row['nodes']:>6} {row['extract_ms']:>10.1f} "
              f"{row['mxu_flops_frac']:>9.2%} {row['vpu_flops_frac']:>9.2%} "
              f"{row['regions']:>8} {row['top_region_match']:>18}")
    return {"rows": rows}


def main() -> None:
    rec = run()
    with open("benchmarks/out_sdfg.json", "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
