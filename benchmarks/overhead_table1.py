"""Table I reproduction: instrumentation overhead, hyperfine protocol.

Three configurations on the paper's microbench workload (~1 ms):
  baseline  — uninstrumented jitted program
  usdt      — static tracepoints enabled in tape mode (in-graph, device-side)
  uprobes   — dynamic jaxpr-injected probes, host-callback mode (trap-style)

plus the same three on a model-scale workload (reduced qwen2 train step,
~100 ms class) where the fixed per-hit trap cost amortises — the regime the
paper's eBPF numbers live in (their trap is ~µs in-kernel; our host-callback
trap is ~0.4 ms, so relative overhead must be read against workload size;
see EXPERIMENTS.md §Paper-reproduction).
"""
from __future__ import annotations

import csv
import io
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, microbench, reduced
from repro.core import overhead, tracepoints as tp, uprobes
from repro.core.events import EventLog


def bench_microbench(warmup: int = 100, runs: int = 1000) -> list[overhead.TimingStats]:
    x = microbench.make_inputs()
    base_fn = jax.jit(lambda v: microbench.approx_sqrt_workload(v))
    jax.block_until_ready(base_fn(x))

    with tp.enable("tape"):
        tape_fn = jax.jit(tp.collect(microbench.approx_sqrt_workload))
        jax.block_until_ready(tape_fn(x))

    log = EventLog()
    probed = uprobes.inject_probes(
        microbench.approx_sqrt_workload, uprobes.by_primitive("scan"),
        mode="callback", log=log,
    )
    cb_fn = jax.jit(probed)
    jax.block_until_ready(cb_fn(x))

    return [
        overhead.hyperfine(lambda: base_fn(x), label="baseline", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: tape_fn(x), label="usdt", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: cb_fn(x), label="uprobes", warmup=warmup, runs=runs),
    ]


def bench_model_step(warmup: int = 10, runs: int = 60) -> list[overhead.TimingStats]:
    """Same comparison at train-step scale (per-hit trap cost amortised)."""
    from repro.models import lm

    cfg = reduced(get_config("qwen2-0.5b"), layers=4)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (8, 128), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, 1)

    def loss(p, t, l):
        return lm.loss_fn(p, cfg, t, l)[0]

    base_fn = jax.jit(lambda p, t, l: loss(p, t, l))
    jax.block_until_ready(base_fn(params, tokens, labels))

    with tp.enable("tape"):
        tape_fn = jax.jit(tp.collect(loss))
        jax.block_until_ready(tape_fn(params, tokens, labels))

    log = EventLog()
    probed = uprobes.inject_probes(loss, uprobes.by_scope("final_norm"), mode="callback", log=log)
    cb_fn = jax.jit(probed)
    jax.block_until_ready(cb_fn(params, tokens, labels))

    return [
        overhead.hyperfine(lambda: base_fn(params, tokens, labels), label="baseline", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: tape_fn(params, tokens, labels), label="usdt", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: cb_fn(params, tokens, labels), label="uprobes", warmup=warmup, runs=runs),
    ]


def run(fast: bool = False) -> dict:
    micro = bench_microbench(warmup=30, runs=200) if fast else bench_microbench()
    model = bench_model_step(warmup=5, runs=30) if fast else bench_model_step()
    out = {
        "microbench": [r.row() for r in micro],
        "model_step": [r.row() for r in model],
    }
    print("== Table I analogue: microbench (~1 ms workload, paper protocol) ==")
    print(overhead.table(micro))
    print("\n== model train-step workload (trap cost amortised) ==")
    print(overhead.table(model))
    return out


def main() -> None:
    rec = run()
    with open("benchmarks/out_overhead_table1.json", "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
