"""Table I reproduction: instrumentation overhead, hyperfine protocol.

Three configurations on the paper's microbench workload (~1 ms):
  baseline  — uninstrumented jitted program
  usdt      — static tracepoints enabled in tape mode (in-graph, device-side)
  uprobes   — dynamic jaxpr-injected probes, host-callback mode (trap-style)

plus the same three on a model-scale workload (reduced qwen2 train step,
~100 ms class) where the fixed per-hit trap cost amortises — the regime the
paper's eBPF numbers live in (their trap is ~µs in-kernel; our host-callback
trap is ~0.4 ms, so relative overhead must be read against workload size;
see EXPERIMENTS.md §Paper-reproduction).
"""
from __future__ import annotations

import csv
import io
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, microbench, reduced
from repro.core import overhead, tracepoints as tp, uprobes
from repro.core.events import EventLog


def bench_microbench(warmup: int = 100, runs: int = 1000) -> list[overhead.TimingStats]:
    x = microbench.make_inputs()
    base_fn = jax.jit(lambda v: microbench.approx_sqrt_workload(v))
    jax.block_until_ready(base_fn(x))

    with tp.enable("tape"):
        tape_fn = jax.jit(tp.collect(microbench.approx_sqrt_workload))
        jax.block_until_ready(tape_fn(x))

    log = EventLog()
    probed = uprobes.inject_probes(
        microbench.approx_sqrt_workload, uprobes.by_primitive("scan"),
        mode="callback", log=log,
    )
    cb_fn = jax.jit(probed)
    jax.block_until_ready(cb_fn(x))

    return [
        overhead.hyperfine(lambda: base_fn(x), label="baseline", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: tape_fn(x), label="usdt", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: cb_fn(x), label="uprobes", warmup=warmup, runs=runs),
    ]


def bench_model_step(warmup: int = 10, runs: int = 60) -> list[overhead.TimingStats]:
    """Same comparison at train-step scale (per-hit trap cost amortised)."""
    from repro.models import lm

    cfg = reduced(get_config("qwen2-0.5b"), layers=4)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (8, 128), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, 1)

    def loss(p, t, l):
        return lm.loss_fn(p, cfg, t, l)[0]

    base_fn = jax.jit(lambda p, t, l: loss(p, t, l))
    jax.block_until_ready(base_fn(params, tokens, labels))

    with tp.enable("tape"):
        tape_fn = jax.jit(tp.collect(loss))
        jax.block_until_ready(tape_fn(params, tokens, labels))

    log = EventLog()
    probed = uprobes.inject_probes(loss, uprobes.by_scope("final_norm"), mode="callback", log=log)
    cb_fn = jax.jit(probed)
    jax.block_until_ready(cb_fn(params, tokens, labels))

    return [
        overhead.hyperfine(lambda: base_fn(params, tokens, labels), label="baseline", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: tape_fn(params, tokens, labels), label="usdt", warmup=warmup, runs=runs),
        overhead.hyperfine(lambda: cb_fn(params, tokens, labels), label="uprobes", warmup=warmup, runs=runs),
    ]


def bench_record_path(warmup: int = 64, runs: int = 512,
                      spans_per_call: int = 16) -> tuple[list[overhead.TimingStats], dict]:
    """Record-path overhead: always-on capture vs the adaptive controller.

    The workload is a span burst (spans_per_call lifecycle spans feeding a
    JSON-serialising sink — the streaming-session regime where the record
    path dominates).  Three configurations, same hyperfine protocol as the
    paper's Table I:

      baseline   — the loop body with no collector attached
      always_on  — TraceCollector + JSON sink, controller at budget 0
                   (measure-only: overhead is tracked but capture never sheds)
      adaptive   — tight budget; the controller duty-cycles capture down, so
                   most spans skip the ring write and the sink serialisation

    The controller is stepped deterministically every 16 calls (no thread),
    identically in both instrumented arms, so the comparison isolates what
    shedding saves rather than what stepping costs.
    """
    from repro.metrics import AdaptiveController, MetricsPlane
    from repro.trace.collector import TraceCollector

    def baseline():
        for i in range(spans_per_call):
            pass

    def make(budget_pct: float):
        log = TraceCollector(capacity=4096)
        plane = MetricsPlane(log)
        buf = io.StringIO()

        def sink(e):  # captured events only: the cost shedding avoids
            buf.write(json.dumps(
                {"t": e.t, "kind": e.kind, "name": e.name, "span": e.span},
            ) + "\n")
            if buf.tell() > (1 << 20):
                buf.seek(0)
                buf.truncate()

        log.add_sink(sink, sampled=True)
        ctl = AdaptiveController(log, plane.registry, budget_pct=budget_pct,
                                 interval_s=0.005, calibration_runs=128)
        calls = {"n": 0}

        def fn():
            for i in range(spans_per_call):
                with log.lifecycle("request", i):
                    pass
            calls["n"] += 1
            if calls["n"] % 16 == 0:
                ctl.step()

        return fn, log, ctl

    rows = [overhead.hyperfine(baseline, label="baseline",
                               warmup=warmup, runs=runs)]
    snaps: dict = {}
    for label, budget in (("always_on", 0.0), ("adaptive", 1.0)):
        fn, log, ctl = make(budget)
        rows.append(overhead.hyperfine(fn, label=label,
                                       warmup=warmup, runs=runs))
        ctl.step()  # fold the tail of the run into the estimate
        snap = ctl.snapshot()
        drops = log.drop_counters()
        snap["sampled_out"] = drops["sampled_out"]
        snap["captured_events"] = len(log)
        snaps[label] = snap
    return rows, snaps


def bench_device_capture(warmup: int = 16, runs: int = 128,
                         spans_per_call: int = 8) -> tuple[list[overhead.TimingStats], dict]:
    """Device-capture overhead: what a live profiler window actually costs.

    The workload is a burst of prefill lifecycles (the serve hot path the
    live profiler snoops).  Three configurations, same hyperfine protocol:

      baseline    — the span burst with no profiler attached
      window_on   — the burst while one capture window stays open (the
                    marginal per-event snoop cost inside a window)
      per_window  — the burst plus a full open/stop/parse/align/merge cycle
                    per call — the largely *fixed* per-window machinery cost
                    the DeviceCaptureBudget loop amortises by stretching the
                    off time between windows

    Uses the synthetic backend so the numbers measure this repo's window
    machinery, not a particular accelerator's profiler.
    """
    import tempfile

    from repro.trace.collector import TraceCollector
    from repro.trace.liveprof import LiveDeviceProfiler

    def burst(col):
        for i in range(spans_per_call):
            with col.lifecycle("prefill", i):
                pass

    col0 = TraceCollector(capacity=8192)
    rows = [overhead.hyperfine(lambda: burst(col0), label="baseline",
                               warmup=warmup, runs=runs)]

    # one window held open across the whole arm: snoop cost only
    col1 = TraceCollector(capacity=8192)
    prof1 = LiveDeviceProfiler(
        col1, tempfile.mkdtemp(prefix="repro-bench-devw-"),
        backend="synthetic", budget_pct=100.0)
    assert prof1.open_window()
    rows.append(overhead.hyperfine(lambda: burst(col1), label="window_on",
                                   warmup=warmup, runs=runs))
    prof1.close_window()
    window_on_snap = prof1.snapshot()

    # full capture cycle per call: the fixed cost the budget loop bounds
    col2 = TraceCollector(capacity=8192)
    prof2 = LiveDeviceProfiler(
        col2, tempfile.mkdtemp(prefix="repro-bench-devc-"),
        backend="synthetic", budget_pct=100.0)

    def cycle():
        prof2.open_window()
        burst(col2)
        prof2.close_window()

    rows.append(overhead.hyperfine(cycle, label="per_window",
                                   warmup=warmup, runs=runs))
    cyc = prof2.snapshot()
    snaps = {
        "window_on": {"merged_events": window_on_snap["merged_events"],
                      "align": window_on_snap["align"]},
        "per_window": {"windows": cyc["windows"],
                       "merged_events": cyc["merged_events"],
                       "align": cyc["align"],
                       "budget": cyc["budget"]},
    }
    return rows, snaps


def run(fast: bool = False) -> dict:
    micro = bench_microbench(warmup=30, runs=200) if fast else bench_microbench()
    model = bench_model_step(warmup=5, runs=30) if fast else bench_model_step()
    record = (bench_record_path(warmup=32, runs=256) if fast
              else bench_record_path())
    device = (bench_device_capture(warmup=8, runs=64) if fast
              else bench_device_capture())
    out = {
        "microbench": [r.row() for r in micro],
        "model_step": [r.row() for r in model],
        "record_path": {
            "rows": [r.row() for r in record[0]],
            **record[1],
        },
        "device_capture": {
            "rows": [r.row() for r in device[0]],
            **device[1],
        },
    }
    print("== Table I analogue: microbench (~1 ms workload, paper protocol) ==")
    print(overhead.table(micro))
    print("\n== model train-step workload (trap cost amortised) ==")
    print(overhead.table(model))
    print("\n== record path: always-on capture vs adaptive controller ==")
    print(overhead.table(record[0]))
    for label, snap in record[1].items():
        print(f"  {label}: rate={snap['sample_rate']:.3f} "
              f"overhead={snap['overhead_pct']:.2f}% "
              f"sampled_out={snap['sampled_out']} "
              f"captured={snap['captured_events']} "
              f"adjustments={snap['adjustments']}")
    print("\n== device capture: window snoop cost vs full per-window cycle ==")
    print(overhead.table(device[0]))
    dcy = device[1]["per_window"]
    print(f"  per_window: windows={dcy['windows']} "
          f"merged={dcy['merged_events']} "
          f"annotated={dcy['align'].get('annotated_fraction', 0):.0%} "
          f"cost_ewma={dcy['budget']['cost_ewma_s'] * 1e3:.3f}ms")
    return out


def main() -> None:
    rec = run()
    with open("benchmarks/out_overhead_table1.json", "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
