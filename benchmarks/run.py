"""Benchmark aggregator: one section per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--with-dryrun]

Sections:
  1. Table I  — instrumentation overhead (hyperfine protocol)
  2. Fig 2    — system-vs-user breakdown
  3. SDFG     — IR extraction + backend assignment across all 10 archs
  4. Kernels  — hot-spot micro-benches + TPU roofline projections
  5. Roofline — 40-cell (arch × shape) table from dry-run records, if present
  6. Dispatch — static vs profile-guided backend placement (repro.dispatch)
  7. Tune     — measured design-space sweep, tuned configs vs defaults
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

OUT_DIR = os.path.dirname(os.path.abspath(__file__))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced run counts")
    ap.add_argument(
        "--with-dryrun", action="store_true",
        help="run the full 40-cell dry-run sweep (subprocess, ~30+ min) if records are missing",
    )
    args = ap.parse_args()

    from benchmarks import breakdown_fig2, kernel_bench, overhead_table1, sdfg_bench
    from repro.trace import artifact_meta

    # provenance stamp (schema/git/timestamp/chip) so `python -m repro.trace
    # diff` can compare out_all.json artifacts across PRs
    results = {"meta": artifact_meta({"fast": args.fast})}
    print("\n########## 1. Table I: instrumentation overhead ##########")
    results["table1"] = overhead_table1.run(fast=args.fast)
    print("\n########## 2. Fig 2: system-vs-user breakdown ##########")
    results["fig2"] = breakdown_fig2.run(fast=args.fast)
    print("\n########## 3. SDFG extraction (10 architectures) ##########")
    results["sdfg"] = sdfg_bench.run(fast=args.fast)
    print("\n########## 4. Kernel micro-benches ##########")
    results["kernels"] = kernel_bench.run(fast=args.fast)

    print("\n########## 5. Roofline table (from dry-run records) ##########")
    recs_path = os.path.join(OUT_DIR, "out_dryrun_single_pod.jsonl")
    if not os.path.exists(recs_path) and args.with_dryrun:
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--all", "--out", recs_path],
            check=False,
        )
    if os.path.exists(recs_path):
        from benchmarks import roofline_table

        recs = roofline_table.load(recs_path)
        print(roofline_table.render(recs))
        results["roofline_cells"] = len(recs)
    else:
        print(f"(no records at {recs_path}; run the dry-run sweep to fill this section)")

    print("\n########## 6. Dispatch: static vs profile-guided placement ##########")
    from benchmarks import dispatch_bench

    results["dispatch"] = dispatch_bench.run(fast=args.fast)

    print("\n########## 7. Tune: design-space sweep, tuned vs default ##########")
    from benchmarks import tune_bench

    results["tune"] = tune_bench.run(fast=args.fast)

    with open(os.path.join(OUT_DIR, "out_all.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    print("\nwrote benchmarks/out_all.json")


if __name__ == "__main__":
    main()
