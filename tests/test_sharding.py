"""Sharding rules: divisibility fallback, shape-conditional overrides."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shd


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    """Abstract mesh over fake devices (no allocation) — spec logic only."""
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_basic_param_specs():
    mesh = fake_mesh()
    # (vocab, embed): vocab->model(2), embed->data(4)
    spec = shd.spec_for((256, 64), "vocab,embed", shd.PARAM_RULES, mesh)
    assert spec == P("model", "data")


def test_divisibility_fallback_drops_mapping():
    mesh = fake_mesh((4, 16))
    # 15 heads on a 16-way model axis: dropped (smollm case)
    spec = shd.spec_for((960, 15, 64), "embed,heads,head_dim", shd.PARAM_RULES, mesh)
    assert spec == P("data")  # trailing Nones stripped
    # but divisible ffn shards
    spec = shd.spec_for((960, 2560), "embed,mlp", shd.PARAM_RULES, mesh)
    assert spec == P("data", "model")


def test_axis_used_once():
    mesh = fake_mesh((4, 2))
    # both dims logical-map to 'model': only the first gets it
    rules = {"a": "model", "b": "model"}
    spec = shd.spec_for((8, 8), "a,b", rules, mesh)
    assert spec == P("model")


def test_multi_axis_assignment():
    mesh = fake_mesh((2, 4, 2), ("pod", "data", "model"))
    spec = shd.spec_for((16, 128), "batch,seq", shd.ACT_RULES, mesh)
    assert spec == P(("pod", "data"))


def test_rules_for_shape_decode_overrides():
    mesh = fake_mesh((4, 16), ("data", "model"))
    # kv_heads=8 not divisible by 16 -> split-KV over model
    r = shd.rules_for_shape("decode", global_batch=128, seq_len=32768, mesh=mesh, n_kv_heads=8)
    assert r.act["cache_seq"] == "model" and r.act["kv_heads"] is None
    # kv_heads=16 divisible -> defaults untouched
    r = shd.rules_for_shape("decode", global_batch=128, seq_len=32768, mesh=mesh, n_kv_heads=16)
    assert r.act["cache_seq"] is None
    # batch=1 (long context) -> sequence parallel over data
    r = shd.rules_for_shape("decode", global_batch=1, seq_len=524288, mesh=mesh, n_kv_heads=16)
    assert r.act["cache_seq"] == "data" and r.act["batch"] is None


def test_tree_specs_align_with_param_tree():
    from repro.configs import get_config, reduced
    from repro.models import lm

    cfg = reduced(get_config("deepseek-moe-16b"))
    mesh = fake_mesh((2, 2))
    axes = lm.param_axes(cfg)
    abs_params = lm.abstract_params(cfg)
    assert jax.tree.structure(axes) == jax.tree.structure(abs_params)
    specs = shd.tree_specs(axes, abs_params, shd.PARAM_RULES, mesh)
    n = len(jax.tree.leaves(abs_params))
    assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) == n


def test_cache_axes_align_with_caches():
    from repro.configs import get_config, reduced
    from repro.models import lm

    for arch in ("gemma3-4b", "jamba-1.5-large", "rwkv6-7b"):
        cfg = reduced(get_config(arch))
        axes = lm.cache_axes(cfg)
        caches = lm.abstract_caches(cfg, 2, 32)
        assert jax.tree.structure(axes) == jax.tree.structure(caches), arch
        for a, c in zip(jax.tree.leaves(axes), jax.tree.leaves(caches)):
            assert len(a.split(",")) == len(c.shape), (arch, a, c.shape)


def test_shard_bytes_per_device():
    mesh = fake_mesh((4, 2))
    abs_t = {"w": jax.ShapeDtypeStruct((64, 64), jax.numpy.float32)}
    specs = {"w": P("data", "model")}
    assert shd.shard_bytes_per_device(abs_t, specs, mesh) == 64 * 64 * 4 // 8
