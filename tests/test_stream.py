"""repro.trace.stream: durable streaming sessions, crash recovery, CI gate."""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.core.events import Event
from repro.dispatch.profiles import ProfileStore
from repro.trace import (
    Session,
    StreamingSession,
    TraceCollector,
    artifact_meta,
    load_any,
    load_stream,
)
from repro.trace.cli import EXIT_REGRESSION, main
from repro.trace.session import SESSION_SCHEMA
from repro.trace.stream import MANIFEST_NAME, PROFILES_NAME

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# StreamingSession: rotation, manifest, durability
# ---------------------------------------------------------------------------


def test_stream_rotation_and_manifest(tmp_path):
    d = str(tmp_path / "run")
    col = TraceCollector(capacity=128)
    stream = StreamingSession(d, rotate_events=5,
                              meta={"driver": "test"}).attach(col)
    for i in range(12):
        with col.lifecycle("request", i):
            pass
    stream.close(stats=col.stats())

    names = sorted(os.listdir(d))
    segs = [n for n in names if n.startswith("segment-") and n.endswith(".jsonl")]
    assert MANIFEST_NAME in names
    assert len(segs) == 5  # 24 events at 5/segment: 4 full + the sealed tail of 4
    assert not any(n.endswith(".open") for n in names)  # close() seals everything

    manifest = json.load(open(os.path.join(d, MANIFEST_NAME)))
    assert manifest["schema"] == "repro.trace.stream/v1"
    assert manifest["closed"] is True
    assert manifest["driver"] == "test"
    assert manifest["git_sha"] and manifest["chip"]["name"]
    assert sum(s["events"] for s in manifest["segments"]) == 24
    assert [s["name"] for s in manifest["segments"]] == segs


def test_stream_compact_round_trips_report(tmp_path):
    d = str(tmp_path / "run")
    col = TraceCollector(capacity=128)
    stream = StreamingSession(d, rotate_events=4).attach(col)
    for i in range(6):
        with col.lifecycle("request", i):
            pass
    col.record("dispatch", "op", {"op": "op", "backend": "ref",
                                  "source": "explore", "measured_s": 0.001})
    stream.close(stats=col.stats())

    sess = load_stream(d)
    assert len(sess.events) == 13
    assert sess.decisions and sess.decisions[0]["backend"] == "ref"
    rep = sess.report()
    assert rep["latency"]["request/request"]["count"] == 6
    assert rep["dispatch"]["decisions"] == 1
    assert sess.meta["schema"] == SESSION_SCHEMA
    assert sess.meta["stream"]["closed"] is True


def test_stream_sink_is_superset_of_bounded_ring(tmp_path):
    """The durable stream must keep every event, even ones the in-memory
    ring evicts — that is the point of streaming."""
    d = str(tmp_path / "run")
    col = TraceCollector(capacity=8, track_capacity={})
    stream = StreamingSession(d, rotate_events=16).attach(col)
    for i in range(50):
        col.record("mark", "m", i)
    stream.close(stats=col.stats())
    assert len(col) == 8 and col.dropped == 42
    sess = load_stream(d)
    assert len(sess.events) == 50
    assert sess.dropped == 42  # collector stats carried via the manifest


def test_stream_rotate_snapshots_profiles(tmp_path):
    d = str(tmp_path / "run")
    store = ProfileStore()
    store.record("op", "be", "<s>", 0.001)
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=1000,
                              store_provider=lambda: store).attach(col)
    col.record("mark", "m", 0)
    stream.rotate()  # forced (checkpoint-aligned) rotation under the budget
    assert os.path.exists(os.path.join(d, PROFILES_NAME))
    store.record("op", "be", "<s>", 0.002)
    stream.close()
    restored = ProfileStore.from_json(open(os.path.join(d, PROFILES_NAME)).read())
    assert restored.entry("op", "be", "<s>").count == 2  # close() re-snapshots
    assert load_stream(d).store is not None


def test_stream_preserves_parent_links_across_segments(tmp_path):
    """A span's spawn and its children routinely land in different segments;
    recovery (without close()) must rebuild the same tree."""
    d = str(tmp_path / "run")
    col = TraceCollector()
    StreamingSession(d, rotate_events=2).attach(col)  # 1 event per line pair
    with col.lifecycle("request", "A") as rid:
        with col.lifecycle("prefill", "A") as pf:
            col.record("mark", "probe")
    with col.lifecycle("request", "B"):
        pass
    # simulated crash: never closed; events span >= 3 segments
    assert len([n for n in os.listdir(d) if n.endswith(".jsonl")]) >= 3

    sess = load_stream(d)
    spawns = {e.span: e for e in sess.events if e.kind == "spawn"}
    assert spawns[pf].parent == rid
    mark = next(e for e in sess.events if e.kind == "mark")
    assert mark.parent == pf
    roots = sess.span_tree()
    req_a = next(n for n in roots if n.span.payload == "A")
    assert [c.span.span for c in req_a.children] == [pf]
    assert [c.span.name for c in req_a.children[0].children] == ["probe"]


def test_tail_prints_depth_markers(tmp_path, capsys):
    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=64).attach(col)
    with col.lifecycle("request", 0):
        with col.lifecycle("prefill", 0):
            pass
    stream.close(stats=col.stats())
    assert main(["tail", d, "--once"]) == 0
    lines = [l for l in capsys.readouterr().out.splitlines() if l]
    assert any("· prefill" in l for l in lines)  # one dot: depth 1
    assert not any("· request" in l for l in lines)  # roots unmarked


# ---------------------------------------------------------------------------
# Crash recovery
# ---------------------------------------------------------------------------


def test_crash_salvages_closed_segments_and_open_tail(tmp_path):
    """No close(): closed segments are intact, complete lines of the open
    segment are salvaged, and a torn tail line is skipped, not fatal."""
    d = str(tmp_path / "run")
    col = TraceCollector()
    StreamingSession(d, rotate_events=4).attach(col)
    for i in range(10):
        col.record("mark", "m", i)
    # simulated crash: the session is never closed; tear the open segment
    open_segs = [n for n in os.listdir(d) if n.endswith(".open")]
    assert len(open_segs) == 1
    with open(os.path.join(d, open_segs[0]), "a") as f:
        f.write('{"t": 1.0, "kind": "ma')  # killed mid-write

    sess = load_stream(d)
    assert [e.payload for e in sess.events] == list(range(10))
    s = sess.meta["stream"]
    assert s["closed"] is False
    assert s["segments"] == 2 and s["open_segments"] == 1
    assert s["salvaged_events"] == 2 and s["skipped_lines"] == 1


def test_stream_refuses_to_reuse_a_session_dir(tmp_path):
    """A second run pointed at the same --trace-dir must not overwrite or
    silently merge with the previous session's segments."""
    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=4).attach(col)
    col.record("mark", "m", 0)
    stream.close()
    with pytest.raises(FileExistsError, match="compact"):
        StreamingSession(d)
    # the crashed-run case (manifest but no close) is protected too
    d2 = str(tmp_path / "run2")
    StreamingSession(d2).attach(TraceCollector())
    with pytest.raises(FileExistsError):
        StreamingSession(d2)


def test_sink_failure_detaches_instead_of_crashing(tmp_path):
    """A broken sink (ENOSPC, closed file) must not take down the traced
    run: the collector detaches it and surfaces the error in stats()."""
    boom = {"n": 0}

    def bad_sink(ev):
        boom["n"] += 1
        raise OSError("no space left on device")

    col = TraceCollector(sink=bad_sink)
    col.record("mark", "m", 0)  # must not raise
    col.record("mark", "m", 1)
    assert boom["n"] == 1  # detached after the first failure
    assert len(col) == 2  # in-memory ring unaffected
    assert "OSError" in col.stats()["sink_error"]


def test_load_stream_rejects_non_stream_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_stream(str(tmp_path / "empty_dir_that_is_missing"))
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(FileNotFoundError):
        load_stream(str(empty))


def test_serve_sigkill_mid_run_recovers(tmp_path):
    """SIGKILL a `launch.serve --trace-dir` subprocess mid-run: compact must
    recover every closed segment and report must run on the result."""
    d = str(tmp_path / "segments")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-0.5b",
         "--reduced", "--requests", "48", "--max-new", "16",
         "--trace-dir", d, "--trace-rotate", "16"],
        env=env, cwd=REPO, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
    )
    try:
        deadline = time.time() + 240
        while time.time() < deadline:
            closed = [n for n in os.listdir(d)] if os.path.isdir(d) else []
            if sum(n.startswith("segment-") and n.endswith(".jsonl") for n in closed) >= 2:
                break
            if proc.poll() is not None:
                out, err = proc.communicate()
                pytest.fail(f"serve exited before kill: {err.decode()[-2000:]}")
            time.sleep(0.2)
        else:
            pytest.fail("no closed segments appeared within 240s")
        assert proc.poll() is None, "server must still be mid-run when killed"
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()

    manifest = json.load(open(os.path.join(d, MANIFEST_NAME)))
    assert manifest["closed"] is False  # the crash really preempted close()
    out = str(tmp_path / "recovered.json")
    assert main(["compact", d, "-o", out]) == 0
    sess = Session.load(out)
    # every event of every closed segment survives the kill
    assert len(sess.events) >= sum(s["events"] for s in manifest["segments"])
    # parent links survive the kill too: requests hang off the run root
    # (the kill can land before any prefill streams, but request spawns are
    # written first and the serve_run spawn is the very first event)
    spawn_name = {e.span: e.name for e in sess.events if e.kind == "spawn"}
    req_parents = {spawn_name.get(e.parent) for e in sess.events
                   if e.kind == "spawn" and e.name == "request"}
    assert req_parents == {"serve_run"}
    prefill_parents = {spawn_name.get(e.parent) for e in sess.events
                       if e.kind == "spawn" and e.name == "prefill"}
    assert prefill_parents <= {"request"}  # empty only if killed pre-admission
    assert main(["report", out]) == 0
    assert main(["report", d]) == 0  # report directly on the remnants too


# ---------------------------------------------------------------------------
# Segment retention (max_segments / --trace-rotate-keep)
# ---------------------------------------------------------------------------


def test_retention_bounds_closed_segments(tmp_path):
    d = str(tmp_path / "run")
    col = TraceCollector(capacity=256)
    stream = StreamingSession(d, rotate_events=4, max_segments=2).attach(col)
    for i in range(30):
        with col.lifecycle("request", i):
            pass
    stream.close(stats=col.stats())

    segs = sorted(n for n in os.listdir(d)
                  if n.startswith("segment-") and n.endswith(".jsonl"))
    assert len(segs) == 2  # bounded, and the *newest* two survive
    manifest = json.load(open(os.path.join(d, MANIFEST_NAME)))
    assert [s["name"] for s in manifest["segments"]] == segs
    assert manifest["pruned_segments"] > 0
    assert manifest["pruned_events"] == 60 - sum(s["events"] for s in manifest["segments"])
    assert manifest["max_segments"] == 2

    # recovery tolerates the numbering gap left by pruning
    sess = load_stream(d)
    assert len(sess.events) == sum(s["events"] for s in manifest["segments"])
    assert sess.meta["stream"]["pruned_segments"] == manifest["pruned_segments"]
    assert main(["compact", d, "-o", str(tmp_path / "out.json")]) == 0


def test_retention_rejects_bad_value(tmp_path):
    with pytest.raises(ValueError, match="max_segments"):
        StreamingSession(str(tmp_path / "x"), max_segments=0)


# ---------------------------------------------------------------------------
# Live tailing (python -m repro.trace tail)
# ---------------------------------------------------------------------------


def test_tail_once_renders_tracks_and_durations(tmp_path, capsys):
    d = _closed_stream_dir(tmp_path, "run", n=3)
    assert main(["tail", d, "--once"]) == 0
    lines = [l for l in capsys.readouterr().out.splitlines() if l]
    assert len(lines) == 6  # one line per event (3 spawn/exit pairs)
    assert all("request" in l for l in lines)
    exits = [l for l in lines if " exit " in l]
    assert len(exits) == 3 and all("dur=" in l and "ms" in l for l in exits)


def test_tail_follows_rotation_until_close(tmp_path):
    """The follower must pick up events across rotations (open -> renamed
    closed -> next open) and terminate when the manifest closes."""
    import io

    from repro.trace.stream import tail_stream

    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=3).attach(col)
    col.record("mark", "m", 0)

    buf = io.StringIO()
    t = threading.Thread(target=tail_stream, args=(d,),
                         kwargs={"poll_s": 0.02, "out": buf}, daemon=True)
    t.start()
    for i in range(1, 10):
        col.record("mark", "m", i)
        time.sleep(0.01)
    stream.close(stats=col.stats())
    t.join(timeout=30)
    assert not t.is_alive()
    lines = [l for l in buf.getvalue().splitlines() if l]
    assert len(lines) == 10  # every event exactly once, across 4 segments


def test_tail_rejects_non_stream_dir(tmp_path):
    assert main(["tail", str(tmp_path / "missing"), "--once"]) == 1


def test_tail_marks_retention_gaps(tmp_path, capsys):
    """Events lost to retention pruning must appear as an explicit gap
    marker, never as a silent skip."""
    d = _closed_stream_dir(tmp_path, "run", n=8)  # 16 events over 4 segments
    os.unlink(os.path.join(d, "segment-000001.jsonl"))  # simulate pruning
    assert main(["tail", d, "--once"]) == 0
    lines = [l for l in capsys.readouterr().out.splitlines() if l]
    gaps = [l for l in lines if l.startswith("# gap:")]
    assert len(gaps) == 1 and "000001" in gaps[0]
    assert len([l for l in lines if not l.startswith("#")]) == 12  # 16 - 4 lost


# ---------------------------------------------------------------------------
# Fleet feeding: per-rotation pushes
# ---------------------------------------------------------------------------


def test_rotation_invokes_fleet_push_best_effort(tmp_path):
    calls = {"n": 0}

    def push():
        calls["n"] += 1
        raise OSError("fleet down")  # must never break the stream

    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=2, fleet_push=push).attach(col)
    for i in range(5):
        col.record("mark", "m", i)
    stream.close(stats=col.stats())
    # rotation pushes are async (an in-flight push makes the next rotation
    # skip), but close() always pushes synchronously — so at least one
    # rotation push plus the closing flush are guaranteed
    assert calls["n"] >= 2
    assert load_stream(d).report()["events"] == 5  # stream unharmed


def test_slow_fleet_push_does_not_stall_the_event_path(tmp_path):
    """A hung fleet (e.g. network black hole) must not block emit(): the
    push runs off-thread and in-flight pushes make later rotations skip."""
    release = threading.Event()
    calls = {"n": 0}

    def hung_push():
        calls["n"] += 1
        release.wait(timeout=60)

    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=2, fleet_push=hung_push).attach(col)
    t0 = time.monotonic()
    for i in range(10):  # 5 rotations' worth, while the first push hangs
        col.record("mark", "m", i)
    elapsed = time.monotonic() - t0
    assert elapsed < 5.0  # recording never waited on the hung push
    assert calls["n"] == 1  # later rotations skipped, not queued
    release.set()
    stream.close(stats=col.stats())  # close joins + flushes synchronously
    assert calls["n"] == 2


def test_streaming_rotations_feed_fleet_without_double_count(tmp_path):
    """A long-lived server's per-rotation pushes plus the final close must
    land each sample in the fleet exactly once."""
    from repro.fleet import FleetClient, FleetPusher

    client = FleetClient(str(tmp_path / "fleet"))
    store = ProfileStore()
    pusher = FleetPusher(client, store, "sha1", "chipA")

    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=2, fleet_push=pusher.push,
                              store_provider=lambda: store).attach(col)
    for i in range(6):
        store.record("op", "be", "<s>", 0.001 * (i + 1))
        col.record("mark", "m", i)
    stream.close(stats=col.stats())

    pulled = client.pull("sha1", "chipA")
    e = pulled["store"].entry("op", "be", "<s>")
    assert e.count == 6  # every rotation pushed only its delta
    assert e.min_s == 0.001
    assert pusher.pushed_samples == 6


# ---------------------------------------------------------------------------
# CLI: compact + directory inputs + regression gate
# ---------------------------------------------------------------------------


def _closed_stream_dir(tmp_path, name, n=6):
    d = str(tmp_path / name)
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=4).attach(col)
    for i in range(n):
        with col.lifecycle("request", i):
            pass
    stream.close(stats=col.stats())
    return d


def test_cli_accepts_segment_dirs_everywhere(tmp_path, capsys):
    da = _closed_stream_dir(tmp_path, "a")
    db = _closed_stream_dir(tmp_path, "b")
    assert main(["report", da]) == 0
    assert "stream" in capsys.readouterr().out
    chrome = str(tmp_path / "a.chrome.json")
    assert main(["export", da, "--format", "chrome", "-o", chrome]) == 0
    assert json.load(open(chrome))["traceEvents"]
    assert main(["diff", da, db]) == 0
    out = str(tmp_path / "a.json")
    assert main(["compact", da, "-o", out]) == 0
    assert main(["diff", out, db]) == 0  # file vs dir mixes fine
    assert load_any(out).report() == load_any(da).report()


def _artifact(tmp_path, name, prefill_ms=2.0, tok_s=100.0, explore=4):
    doc = {
        "meta": artifact_meta(),
        "serving": {"mean_prefill_ms": prefill_ms, "tokens_per_s": tok_s},
        "dispatch": {"by_source": {"explore": explore}},
    }
    p = str(tmp_path / name)
    with open(p, "w") as f:
        json.dump(doc, f)
    return p


def test_diff_gate_passes_on_identical_artifacts(tmp_path):
    pa = _artifact(tmp_path, "a.json")
    assert main(["diff", pa, pa, "--fail-over-pct", "25"]) == 0


def test_diff_gate_fails_on_latency_regression(tmp_path, capsys):
    pa = _artifact(tmp_path, "a.json", prefill_ms=2.0)
    pb = _artifact(tmp_path, "b.json", prefill_ms=3.0)  # +50% > 25%
    assert main(["diff", pa, pb, "--fail-over-pct", "25"]) == EXIT_REGRESSION
    err = capsys.readouterr().err
    assert "REGRESSION" in err and "mean_prefill_ms" in err
    # the reverse direction is an improvement, not a regression
    assert main(["diff", pb, pa, "--fail-over-pct", "25"]) == 0


def test_diff_gate_fails_on_throughput_drop(tmp_path):
    pa = _artifact(tmp_path, "a.json", tok_s=100.0)
    pb = _artifact(tmp_path, "b.json", tok_s=60.0)  # -40% < -25%
    assert main(["diff", pa, pb, "--fail-over-pct", "25"]) == EXIT_REGRESSION
    assert main(["diff", pb, pa, "--fail-over-pct", "25"]) == 0


def test_diff_gate_ignores_counters_and_small_changes(tmp_path):
    pa = _artifact(tmp_path, "a.json", prefill_ms=2.0, explore=4)
    pb = _artifact(tmp_path, "b.json", prefill_ms=2.2, explore=40)  # +10%; counter x10
    assert main(["diff", pa, pb, "--fail-over-pct", "25"]) == 0


def _session_file(tmp_path, name, dur_s):
    evs = [Event(0.0, "spawn", "request", "A", 1),
           Event(dur_s, "exit", "request", "A", 1)]
    sess = Session(meta={"schema": SESSION_SCHEMA, "git_sha": "x",
                         "created_unix": 0}, events=evs)
    return sess.save(str(tmp_path / name))


def test_diff_gate_on_sessions(tmp_path):
    pa = _session_file(tmp_path, "a.json", 0.010)
    pb = _session_file(tmp_path, "b.json", 0.020)  # +100% latency
    assert main(["diff", pa, pb, "--fail-over-pct", "25"]) == EXIT_REGRESSION
    assert main(["diff", pb, pa, "--fail-over-pct", "25"]) == 0
    assert main(["diff", pa, pa, "--fail-over-pct", "25"]) == 0
    # without the flag the same diff is informational only
    assert main(["diff", pa, pb]) == 0


def test_diff_gate_json_carries_regressions(tmp_path, capsys):
    pa = _artifact(tmp_path, "a.json", prefill_ms=2.0)
    pb = _artifact(tmp_path, "b.json", prefill_ms=4.0)
    assert main(["diff", pa, pb, "--json", "--fail-over-pct", "25"]) == EXIT_REGRESSION
    captured = capsys.readouterr()
    doc = json.loads(captured.out)
    assert doc["regressions"] and doc["regressions"][0]["kind"] == "latency"


def test_diff_gate_json_stdout_is_pure_json(tmp_path, capsys):
    """Gate chatter (including the OK line) must go to stderr: with --json,
    stdout is exactly one machine-parseable document."""
    pa = _artifact(tmp_path, "a.json")
    assert main(["diff", pa, pa, "--json", "--fail-over-pct", "25"]) == 0
    captured = capsys.readouterr()
    doc = json.loads(captured.out)  # raises on trailing chatter
    assert doc["regressions"] == []
    assert "regression gate" in captured.err


# ---------------------------------------------------------------------------
# Supervisor integration: checkpoint-aligned rotation
# ---------------------------------------------------------------------------


def test_supervisor_rotates_stream_at_checkpoints(tmp_path, key):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.runtime.supervisor import Supervisor, SupervisorConfig
    from repro.training.step import TrainConfig, init_train_state, make_train_step

    cfg = reduced(get_config("smollm-360m"))
    tcfg = TrainConfig()
    state = init_train_state(cfg, tcfg, key)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=5))

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in data.batch(i).items()}

    d = str(tmp_path / "trace")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=10_000).attach(col)
    sup = Supervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=3, max_steps=7),
        step, batch_fn, state, log=col, stream=stream,
    )
    sup.run()
    # checkpoints at steps 3 and 6 plus the final checkpoint each force a
    # rotation, so closed segments exist even far under the rotation budget
    closed = [n for n in os.listdir(d) if n.startswith("segment-") and n.endswith(".jsonl")]
    assert len(closed) >= 3
    stream.close(stats=col.stats())
    rep = load_stream(d).report()
    assert any(k.startswith("step/") for k in rep["latency"])
    assert any(k.startswith("checkpoint/") for k in rep["latency"])


# ---------------------------------------------------------------------------
# tail: one-line drop warning when the manifest's loss counters grow
# ---------------------------------------------------------------------------


def test_tail_warns_once_on_drop_counters(tmp_path, capsys):
    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(
        d, rotate_events=64,
        stats_provider=lambda: {"dropped": 5, "sampled_out": 2,
                                "by_track": {"": 4, "request": 1}},
    ).attach(col)
    col.record("mark", "m", 0)
    stream.close()
    assert main(["tail", d, "--once"]) == 0
    warns = [l for l in capsys.readouterr().out.splitlines()
             if l.startswith("# WARNING")]
    assert len(warns) == 1  # counters only grew once -> exactly one line
    assert "5 events dropped" in warns[0] and "main" in warns[0]
    assert "2 events shed by adaptive sampling" in warns[0]


def test_tail_silent_without_drops(tmp_path, capsys):
    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, rotate_events=64).attach(col)
    col.record("mark", "m", 0)
    stream.close(stats=col.stats())
    assert main(["tail", d, "--once"]) == 0
    assert not [l for l in capsys.readouterr().out.splitlines()
                if l.startswith("# WARNING")]
