"""Property-based tests on the system's invariants (hypothesis API; offline
fallback harness in tests/prop.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from prop import given, settings, st

from repro.kernels import ref
from repro.nn import core as nn

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=12)
@given(
    st.integers(1, 3),                # batch
    st.integers(2, 24),               # seq
    st.sampled_from([(2, 1), (2, 2), (4, 2)]),  # (Hq, Hkv)
    st.integers(0, 2),                # window selector
)
def test_attention_causality(B, S, heads, wsel):
    """Output at position t must not change when future tokens change."""
    Hq, Hkv = heads
    D = 8
    window = [None, 4, S][wsel] if S > 1 else None
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = ref.mha_ref(q, k, v, causal=True, window=window)
    t = S // 2
    k2 = k.at[:, t + 1 :].set(jax.random.normal(ks[3], (B, S - t - 1, Hkv, D)))
    v2 = v.at[:, t + 1 :].set(jax.random.normal(ks[3], (B, S - t - 1, Hkv, D)) * 3)
    out2 = ref.mha_ref(q, k2, v2, causal=True, window=window)
    np.testing.assert_allclose(out[:, : t + 1], out2[:, : t + 1], atol=1e-5, rtol=1e-5)


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 2), st.integers(4, 32))
def test_attention_probability_convexity(B, S):
    """Attention output lies in the convex hull of V rows: bounded by per-dim
    min/max of the visible prefix."""
    Hq = Hkv = 2
    D = 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = ref.mha_ref(q, k, v, causal=True)
    for t in (0, S // 2, S - 1):
        vis = np.asarray(v[:, : t + 1])
        lo = vis.min(axis=1) - 1e-4  # (B, Hkv, D)... v is (B,S,Hkv,D) -> min over S
        hi = vis.max(axis=1) + 1e-4
        got = np.asarray(out[:, t]).reshape(B, Hkv, Hq // Hkv, D)
        assert (got >= lo[:, :, None]).all() and (got <= hi[:, :, None]).all()


@settings(deadline=None, max_examples=8)
@given(st.floats(5.0, 100.0))
def test_softcap_bounds(cap):
    x = jnp.linspace(-1e4, 1e4, 101)
    y = nn.softcap(x, cap)
    assert float(jnp.max(jnp.abs(y))) <= cap + 1e-3
    # monotone
    assert bool(jnp.all(jnp.diff(y) >= -1e-6))


# ---------------------------------------------------------------------------
# rmsnorm / rope invariants
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 64), st.floats(0.1, 10.0))
def test_rmsnorm_scale_invariance(D, alpha):
    """RMSNorm(αx) == RMSNorm(x) (up to eps)."""
    x = jax.random.normal(KEY, (3, D)) + 0.5
    s = jnp.zeros(D)
    a = ref.rmsnorm_ref(x, s)
    b = ref.rmsnorm_ref(x * alpha, s)
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 32), st.integers(0, 1000))
def test_rope_preserves_norm_and_relative_position(D2, pos0):
    D = D2 * 2
    x = jax.random.normal(KEY, (1, 4, 2, D))
    pos = jnp.arange(4)[None] + pos0
    y = nn.apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-4
    )
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(KEY, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, D))
    def dot_at(m, n):
        qm = nn.apply_rope(q, jnp.full((1, 1), m), 10000.0)
        kn = nn.apply_rope(k, jnp.full((1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(pos0 + 3, pos0) - dot_at(3, 0)) < 1e-2


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=6)
@given(st.integers(0, 2**31 - 1), st.sampled_from([(4, 1), (4, 2), (8, 2)]))
def test_moe_combine_weights_partition_of_unity(seed, ek):
    """Kept gates sum to ≤ 1 per token; == 1 when nothing overflows."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.nn import ffn as ffn_mod

    E, K = ek
    cfg = reduced(get_config("dbrx-132b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=E, top_k=K, capacity_factor=8.0)
    )
    pf = nn.ValueFactory(jax.random.PRNGKey(seed), jnp.float32)
    p = ffn_mod.moe_init(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model))
    y, aux = ffn_mod.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux["moe_load_balance"]) >= 0.0
    # capacity_factor 8 => no drops => every token fully combined
    # (verified via the dispatch tensor by re-running the routing math)


@settings(deadline=None, max_examples=6)
@given(st.integers(1, 64))
def test_moe_group_size_divides(tokens):
    from repro.nn.ffn import pick_group_size

    g = pick_group_size(tokens * 8, target=16)
    assert (tokens * 8) % g == 0 and 1 <= g <= 16


# ---------------------------------------------------------------------------
# scan-state invariants (rwkv/mamba chunking == arbitrary re-chunking)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=6)
@given(st.sampled_from([8, 16, 32]), st.sampled_from([4, 8, 16]))
def test_rwkv_chunk_size_independence(T, L):
    B, H, K = 1, 2, 4
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, K)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.3))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, K, K)) * 0.1
    o1, s1 = ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=L)
    o2, s2 = ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=T)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(s1, s2, atol=2e-5, rtol=2e-5)


@settings(deadline=None, max_examples=6)
@given(st.sampled_from([16, 32]), st.sampled_from([8, 16]))
def test_mamba_chunk_size_independence(T, L):
    B, DI, N = 1, 6, 3
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (B, T, DI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, DI)))
    A = -jnp.exp(jax.random.normal(ks[2], (DI, N)) * 0.3)
    Bm, C = jax.random.normal(ks[3], (B, T, N)), jax.random.normal(ks[4], (B, T, N))
    D = jax.random.normal(ks[5], (DI,))
    h0 = jax.random.normal(ks[6], (B, DI, N)) * 0.1
    y1, h1 = ref.mamba_scan_chunked(x, dt, A, Bm, C, D, h0, chunk=L)
    y2, h2 = ref.mamba_scan_chunked(x, dt, A, Bm, C, D, h0, chunk=T)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(h1, h2, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# sharding invariants
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(
    st.sampled_from([(2, 2), (4, 2), (4, 16), (16, 16)]),
    st.tuples(st.integers(1, 512), st.integers(1, 512)),
)
def test_spec_dims_always_divisible(mesh_shape, dims):
    """Whatever the shape, emitted specs never violate divisibility."""
    import numpy as np
    from jax.sharding import Mesh

    from repro.distributed import sharding as shd

    devs = np.array(jax.devices() * int(np.prod(mesh_shape)))[: int(np.prod(mesh_shape))]
    mesh = Mesh(devs.reshape(mesh_shape), ("data", "model"))
    spec = shd.spec_for(tuple(dims), "embed,mlp", shd.PARAM_RULES, mesh)
    for dim, part in zip(dims, tuple(spec) + (None,) * (2 - len(spec))):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        total = int(np.prod([mesh.shape[a] for a in parts]))
        assert dim % total == 0
