"""Cross-process tracing: span propagation, session stitching, hop analysis.

Synthetic two-process sessions are built by hand with known span-id
collisions and injected clock skew, so every stitching transformation
(namespacing, NTP-style skew correction, remote re-linking) is asserted
against exact expected values; the end-to-end test runs a real in-process
:class:`ReplicaServer` behind a hand-driven frontdoor side and stitches the
two collectors' sessions.
"""
import json
import urllib.request

import pytest

from repro.core.events import Event, SpanContext, TRACEPARENT_HEADER, remote_ref
from repro.metrics import MetricsPlane
from repro.router.frontdoor import FrontDoorHandler
from repro.router.replica import (
    ReplicaServer,
    SyntheticEngine,
    expected_synthetic_tokens,
)
from repro.trace import (
    Session,
    TraceCollector,
    chain_report,
    hop_rows,
    hop_summary,
    resolve_spans,
    span_tree,
    stitch_sessions,
)
from repro.trace.cli import main as trace_main
from repro.trace.stitch import HOPS, stitch


# ---------------------------------------------------------------------------
# SpanContext wire format
# ---------------------------------------------------------------------------


def test_spancontext_inject_extract_roundtrip():
    ctx = SpanContext(trace="abc123", span=42, origin="frontdoor:999",
                      sent_unix=1234.5678)
    back = SpanContext.extract(ctx.inject())
    assert back == ctx


def test_spancontext_extract_tolerates_garbage():
    assert SpanContext.extract(None) is None
    assert SpanContext.extract("") is None
    assert SpanContext.extract("traceparent-w3c;whatever") is None
    assert SpanContext.extract("repro1;trace=x") is None  # missing span/origin
    assert SpanContext.extract("repro1;trace=x;span=NaNope;origin=y") is None


def test_spancontext_origin_sanitized_on_wire():
    ctx = SpanContext(trace="t", span=1, origin="evil;span=9=x")
    back = SpanContext.extract(ctx.inject())
    assert back is not None and back.span == 1
    assert ";" not in back.origin and "=" not in back.origin


def test_remote_ref_validation():
    ok = {"remote": {"trace": "t", "span": 3, "origin": "fd:1"}}
    assert remote_ref(ok) == ok["remote"]
    assert remote_ref(None) is None
    assert remote_ref({"remote": "3"}) is None
    assert remote_ref({"remote": {"span": "3", "origin": "x"}}) is None
    assert remote_ref({"remote": {"span": 3, "origin": ""}}) is None


def test_resolve_spans_lifts_remote():
    ref = {"trace": "t", "span": 7, "origin": "fd:1"}
    events = [
        Event(1.0, "spawn", "rpc", {"replica": "r0", "remote": ref}, span=2,
              parent=1),
        Event(2.0, "exit", "rpc", {"replica": "r0", "remote": ref}, span=2,
              parent=1),
    ]
    spans = resolve_spans(events)
    assert len(spans) == 1
    assert spans[0].remote == ref
    assert spans[0].parent == 1  # local parent untouched


# ---------------------------------------------------------------------------
# Synthetic two-process sessions: exact stitching arithmetic
# ---------------------------------------------------------------------------

# The "true" timeline, in the frontdoor's wall clock: the request is sent at
# T+0.010, served by the replica over [T+0.012, T+0.052], answered at T+0.054.
T = 5000.0


def _frontdoor_session(replica_origin: str, skew_s: float) -> Session:
    """A frontdoor session whose monotonic epoch is wall - 4000, with the
    handshake stamps a replica whose clock runs ``skew_s`` ahead would have
    produced."""
    hs = {
        "origin": replica_origin, "span": 2, "trace": "tr1",
        "sent_unix": T + 0.010, "recv_unix": T + 0.054,
        "replica_recv_unix": T + 0.012 + skew_s,
        "replica_sent_unix": T + 0.052 + skew_s,
    }
    hops = {"frontdoor_queue": 1.0, "network": 4.0, "replica_queue": 1.0,
            "service": 40.0}
    m = T - 4000.0  # monotonic epoch offset
    events = [
        Event(T - m + 0.000, "spawn", "router_run", None, span=1),
        Event(T - m + 0.008, "spawn", "request", {"class": "short"}, span=2,
              parent=1),
        Event(T - m + 0.009, "route", "route", {"replica": "r0", "trace": "tr1"},
              span=3, parent=2),
        Event(T - m + 0.055, "route", "outcome",
              {"replica": "r0", "outcome": "ok", "latency_ms": 46.0,
               "hops": hops, "hs": hs}, parent=2),
        Event(T - m + 0.056, "exit", "request", {"class": "short"}, span=2,
              parent=1),
        Event(T - m + 0.100, "exit", "router_run", None, span=1),
    ]
    meta = {"origin": "frontdoor:100",
            "clock": {"monotonic": 1000.0, "unix": T - 4000.0 + 1000.0}}
    return Session(meta=meta, events=events)


def _replica_session(origin: str, skew_s: float) -> Session:
    """A replica session whose span ids 1..3 collide with the frontdoor's,
    whose monotonic epoch is true-wall - 4500, and whose *wall clock* (and
    therefore its recorded clock anchor) runs ``skew_s`` ahead of true."""
    remote = {"trace": "tr1", "span": 3, "origin": "frontdoor:100"}
    m = T - 4500.0
    events = [
        Event(T - m + 0.000, "spawn", "serve_run", {"replica": "r0"}, span=1),
        Event(T - m + 0.012, "spawn", "rpc",
              {"replica": "r0", "remote": remote}, span=2, parent=1),
        Event(T - m + 0.013, "spawn", "request", 0, span=3, parent=2),
        Event(T - m + 0.050, "exit", "request", 0, span=3, parent=2),
        Event(T - m + 0.052, "exit", "rpc",
              {"replica": "r0", "remote": remote}, span=2, parent=1),
        Event(T - m + 0.090, "exit", "serve_run", {"replica": "r0"}, span=1),
    ]
    meta = {"origin": origin,
            "clock": {"monotonic": 500.0, "unix": 500.0 + m + skew_s}}
    return Session(meta=meta, events=events)


@pytest.mark.parametrize("skew_s", [0.05, -0.05])
def test_stitch_two_process_sessions_with_skew(skew_s):
    fd = _frontdoor_session("r0:200", skew_s)
    rep = _replica_session("r0:200", skew_s)
    out = stitch_sessions([("fd", fd), ("rep", rep)])

    prov = out.meta["stitch"]
    assert [r["origin"] for r in prov["inputs"]] == ["frontdoor:100", "r0:200"]
    # reference keeps its ids; the replica is shifted above the frontdoor max
    assert prov["inputs"][0]["id_offset"] == 0
    assert prov["inputs"][1]["id_offset"] == 3
    assert prov["inputs"][1]["span_ids"] == [4, 6]
    # the estimated skew recovers the injected value
    assert prov["inputs"][1]["skew_s"] == pytest.approx(skew_s, abs=1e-6)
    assert prov["relinked_spans"] == 1
    assert prov["unmatched_remote"] == 0

    spans = {s.span: s for s in resolve_spans(out.events) if s.span}
    # rpc (replica id 2 -> 5) re-linked under the frontdoor route span (3)
    assert spans[5].name == "rpc" and spans[5].parent == 3
    # engine request (replica id 3 -> 6) kept its local parent (rpc)
    assert spans[6].name == "request" and spans[6].parent == 5

    # skew correction puts the replica subtree inside the frontdoor request
    # window on the shared timeline (monotone parent/child containment)
    req, rpc = spans[2], spans[5]
    assert req.t0 <= rpc.t0 <= rpc.t1 <= req.t1
    assert rpc.t0 == pytest.approx(T + 0.012, abs=1e-6)

    chain = chain_report(out)
    assert chain["completed"] == 1 and chain["chained"] == 1
    assert chain["fraction"] == 1.0 and chain["orphaned_remote"] == 0

    # hop decomposition is duration-only, so it is skew-invariant
    rows = hop_rows(out)
    assert len(rows) == 1
    assert rows[0]["hops"]["network"] >= 0.0
    assert rows[0]["sum_ms"] == pytest.approx(rows[0]["latency_ms"])


def test_stitch_without_skew_correction_breaks_containment():
    fd = _frontdoor_session("r0:200", 0.05)
    rep = _replica_session("r0:200", 0.05)
    out = stitch_sessions([("fd", fd), ("rep", rep)], skew_correct=False)
    assert out.meta["stitch"]["inputs"][1]["skew_s"] == 0.0
    spans = {s.span: s for s in resolve_spans(out.events) if s.span}
    # the 50 ms-fast replica clock pushes its rpc exit past the frontdoor
    # request exit — exactly the artifact skew correction removes
    assert spans[5].t1 > spans[2].t1


def test_stitch_skips_duplicate_origin_and_trees_stay_rooted():
    fd = _frontdoor_session("r0:200", 0.0)
    rep = _replica_session("r0:200", 0.0)
    dup = _replica_session("r0:200", 0.0)
    out = stitch_sessions([("fd", fd), ("rep", rep), ("dup", dup)])
    assert [s["path"] for s in out.meta["stitch"]["skipped"]] == ["dup"]
    # span_tree's parent<child invariant survives namespacing: the replica
    # subtree hangs under the frontdoor request, not orphaned at the root
    roots = span_tree(resolve_spans(out.events))
    names = {r.span.name for r in roots}
    assert "rpc" not in names and "request" not in names


def test_stitch_caps_torn_spans_at_their_own_session_end():
    # a SIGKILLed replica: spans opened, no exits, last observed event at
    # T+0.020 — long before the frontdoor session ends (T+0.100)
    fd = _frontdoor_session("r0:200", 0.0)
    rep = _replica_session("r0:200", 0.0)
    m = T - 4500.0
    killed = Session(meta=rep.meta, events=[
        e for e in rep.events if e.kind == "spawn"
    ] + [Event(T - m + 0.020, "mark", "heartbeat", None, parent=1)])
    out = stitch_sessions([("fd", fd), ("killed", killed)])

    assert out.meta["stitch"]["inputs"][0]["torn_spans"] == 0
    assert out.meta["stitch"]["inputs"][1]["torn_spans"] == 3
    spans = {s.span: s for s in resolve_spans(out.events) if s.span}
    # the torn rpc ends at the dead process's own last event, not at the
    # merged session's end, and is flagged for consumers
    assert spans[5].name == "rpc"
    assert spans[5].t1 == pytest.approx(T + 0.020, abs=1e-6)
    assert spans[5].t1 < max(s.t1 for s in spans.values())
    assert spans[5].payload.get("torn") is True
    # the salvaged chain still counts: request -> route -> rpc -> request
    chain = chain_report(out)
    assert chain["completed"] == 1 and chain["chained"] == 1


def test_stitch_unmatched_remote_counted():
    fd = _frontdoor_session("r0:200", 0.0)
    rep = _replica_session("r0:200", 0.0)
    # the rpc names an origin that is not among the stitched inputs
    alien = {"trace": "tr1", "span": 3, "origin": "elsewhere:1"}
    rep = Session(meta=rep.meta, events=[
        Event(e.t, e.kind, e.name,
              {**e.payload, "remote": alien} if isinstance(e.payload, dict)
              and "remote" in e.payload else e.payload,
              span=e.span, parent=e.parent)
        for e in rep.events])
    out = stitch_sessions([("fd", fd), ("rep", rep)])
    assert out.meta["stitch"]["relinked_spans"] == 0
    assert out.meta["stitch"]["unmatched_remote"] == 1
    assert chain_report(out)["orphaned_remote"] >= 1


# ---------------------------------------------------------------------------
# CLI: stitch / hops / multi-session report
# ---------------------------------------------------------------------------


def test_stitch_and_hops_cli(tmp_path, capsys):
    fd_path = _frontdoor_session("r0:200", 0.05).save(str(tmp_path / "fd.json"))
    rep_path = _replica_session("r0:200", 0.05).save(str(tmp_path / "rep.json"))
    out_path = str(tmp_path / "stitched.json")

    rc = trace_main(["stitch", fd_path, rep_path, "-o", out_path, "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["chain"]["fraction"] == 1.0
    assert len(doc["stitch"]["inputs"]) == 2

    rc = trace_main(["hops", out_path, "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["summary"]["requests"] == 1
    assert doc["summary"]["within_5pct"] == 1
    assert set(doc["summary"]["hops"]) == set(HOPS)

    # human-readable paths render without error
    assert trace_main(["stitch", fd_path, rep_path,
                       "-o", str(tmp_path / "s2.json")]) == 0
    assert trace_main(["hops", out_path]) == 0
    capsys.readouterr()


def test_multi_session_report_namespaces_ids(tmp_path, capsys):
    # two sessions with deliberately colliding span ids in one report call
    fd_path = _frontdoor_session("r0:200", 0.0).save(str(tmp_path / "a.json"))
    rep_path = _replica_session("r0:200", 0.0).save(str(tmp_path / "b.json"))
    rc = trace_main(["report", fd_path, rep_path, "--tree", "--json"])
    assert rc == 0
    rows = json.loads(capsys.readouterr().out)
    # the replica's rpc + engine request nest under the frontdoor request
    # (route -> rpc -> request), which is impossible if ids collided
    depths: dict = {}
    for r in rows:
        depths.setdefault(r["name"], set()).add(r["depth"])
    assert max(depths["request"]) > max(depths["rpc"]) > min(depths["request"])
    # single-session report still works through the same entry point
    assert trace_main(["report", fd_path]) == 0
    capsys.readouterr()


def test_stitch_load_and_discovery_fallback(tmp_path):
    # stitch() loads saved session files and appends nothing when the
    # reference is a plain file with no manifest/replicas layout
    fd_path = _frontdoor_session("r0:200", 0.0).save(str(tmp_path / "fd.json"))
    rep_path = _replica_session("r0:200", 0.0).save(str(tmp_path / "rep.json"))
    out = stitch([fd_path, rep_path])
    assert out.meta["stitch"]["relinked_spans"] == 1


# ---------------------------------------------------------------------------
# Hop aggregation
# ---------------------------------------------------------------------------


def _outcome_event(hops, latency_ms, outcome="ok"):
    return Event(1.0, "route", "outcome",
                 {"replica": "r0", "outcome": outcome,
                  "latency_ms": latency_ms, "hops": hops})


def test_hop_rows_and_summary():
    good = {"frontdoor_queue": 1.0, "network": 2.0, "replica_queue": 3.0,
            "service": 4.0}
    bad = {"frontdoor_queue": 1.0, "network": 2.0, "replica_queue": 3.0,
           "service": 40.0}
    sess = Session(meta={}, events=[
        _outcome_event(good, 10.0),
        _outcome_event(bad, 10.0),          # sum 46 vs latency 10: mismatch
        _outcome_event(good, 10.0, "rejected"),  # no hops filter: has hops
        Event(1.0, "route", "outcome", {"outcome": "error"}),  # no hops
        Event(1.0, "route", "route", {"replica": "r0"}),  # not an outcome
    ])
    rows = hop_rows(sess)
    assert len(rows) == 3
    summary = hop_summary(rows)
    assert summary["requests"] == 3
    assert summary["within_5pct"] == 2
    assert summary["hops"]["service"]["max"] == 40.0


def test_metrics_sink_hop_histograms():
    col = TraceCollector()
    plane = MetricsPlane(col)
    good = {"frontdoor_queue": 1.0, "network": 2.0, "replica_queue": 3.0,
            "service": 4.0}
    col.record("route", "outcome",
               {"replica": "r0", "outcome": "ok", "latency_ms": 10.0,
                "route_ms": 0.1, "hops": good})
    col.record("route", "outcome",
               {"replica": "r0", "outcome": "ok", "latency_ms": 100.0,
                "route_ms": 0.1, "hops": good})  # sum 10 vs 100: mismatch
    summary = plane.summary()
    for hop in HOPS:
        assert summary[f"repro_router_hop_ms_count{{hop={hop}}}"] == 2
    assert summary["repro_router_hop_sum_mismatch_total"] == 1


# ---------------------------------------------------------------------------
# End-to-end: real replica server + hand-driven frontdoor side
# ---------------------------------------------------------------------------


def test_replica_traceparent_end_to_end_stitch(tmp_path):
    from repro.core.events import next_span_id
    from repro.trace.session import run_metadata

    rep_col = TraceCollector()
    eng = SyntheticEngine(max_batch=2, ms_per_token=1.0, log=rep_col)
    srv = ReplicaServer(eng, name="r0", log=rep_col).start()

    fd_col = TraceCollector()
    import time as _time
    try:
        run_span = next_span_id()
        fd_col.record("spawn", "router_run", None, span=run_span)
        t_req0 = _time.perf_counter()
        with fd_col.lifecycle("request", {"class": "short"},
                              parent=run_span) as rspan:
            route_span = next_span_id()
            fd_col.record("route", "route", {"replica": "r0", "trace": "tr9"},
                          span=route_span, parent=rspan)
            ctx = SpanContext(trace="tr9", span=route_span,
                              origin="frontdoor:1", sent_unix=_time.time())
            body = json.dumps({"prompt": [1, 2, 3], "max_new": 4}).encode()
            req = urllib.request.Request(
                f"{srv.url}/v1/generate", data=body, method="POST",
                headers={"Content-Type": "application/json",
                         TRACEPARENT_HEADER: ctx.inject()})
            t_fwd = _time.perf_counter()
            with urllib.request.urlopen(req, timeout=10) as resp:
                reply = json.loads(resp.read())
            recv_unix = _time.time()
            fwd_ms = (_time.perf_counter() - t_fwd) * 1e3
            lat_ms = (_time.perf_counter() - t_req0) * 1e3
            extra = FrontDoorHandler._hop_extra(reply, ctx, recv_unix,
                                                fwd_ms=fwd_ms, lat_ms=lat_ms)
            fd_col.record("route", "outcome",
                          {"replica": "r0", "outcome": "ok", **extra},
                          parent=rspan)
        fd_col.record("exit", "router_run", None, span=run_span)
    finally:
        srv.stop()

    assert reply["tokens"] == expected_synthetic_tokens([1, 2, 3], 4)
    # the replica's reply carries its handshake/decomposition context
    assert reply["ctx"]["origin"] == srv.origin
    assert reply["ctx"]["trace"] == "tr9"
    assert "hops" in extra and extra["hops"]["service"] >= 0.0

    fd = Session(meta=run_metadata({"origin": "frontdoor:1"}),
                 events=fd_col.events())
    rep = Session(meta=run_metadata({"origin": srv.origin}),
                  events=rep_col.events())
    out = stitch_sessions([("fd", fd), ("rep", rep)])
    chain = chain_report(out)
    assert chain["completed"] == 1 and chain["fraction"] == 1.0
    assert chain["orphaned_remote"] == 0
    rows = hop_rows(out)
    assert len(rows) == 1
    # the four duration-only hops telescope to the end-to-end latency
    assert rows[0]["sum_ms"] == pytest.approx(rows[0]["latency_ms"], rel=0.01)
