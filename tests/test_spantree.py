"""Hierarchical span-tree model: parent links end-to-end.

Covers the contextvars span stack (thread/async safety), orphan handling
(parent or exit evicted from the ring), the nested exporters (speedscope
evented + Perfetto async grouping + flow links), the ``report --tree`` view,
and the jax.profiler device-trace merge — with golden exports where the
format is load-bearing for external viewers.
"""
import gzip
import json
import os
import threading

import pytest

from repro.core.events import Event, EventLog, current_span, next_span_id, span_scope
from repro.dispatch import DispatchConfig, Dispatcher
from repro.trace import (
    Session,
    TraceCollector,
    align_device_slices,
    load_profiler_trace,
    merge_device_trace,
    resolve_spans,
    span_tree,
    to_chrome_trace,
    to_folded,
    to_speedscope,
)


# ---------------------------------------------------------------------------
# contextvars span stack: nesting, overrides, thread isolation
# ---------------------------------------------------------------------------


def test_lifecycle_nesting_sets_parents():
    log = EventLog()
    with log.lifecycle("step", 0) as outer:
        assert current_span() == outer
        with log.lifecycle("checkpoint", 0) as inner:
            assert current_span() == inner
            log.record("mark", "m")
        assert current_span() == outer
    assert current_span() == 0
    spawns = {e.name: e for e in log.events(kind="spawn")}
    assert spawns["step"].parent == 0
    assert spawns["checkpoint"].parent == outer
    mark = log.events(kind="mark")[0]
    assert mark.parent == inner


def test_record_explicit_parent_overrides_context():
    log = EventLog()
    with log.lifecycle("step", 0) as s:
        log.record("mark", "ctx")
        log.record("mark", "explicit", parent=999)
        log.record("mark", "root", parent=0)
    by_name = {e.name: e for e in log.events(kind="mark")}
    assert by_name["ctx"].parent == s
    assert by_name["explicit"].parent == 999
    assert by_name["root"].parent == 0


def test_span_scope_reparents_detached_work():
    """A span whose bracket events live elsewhere (serving request) still
    adopts children recorded under its span_scope."""
    log = EventLog()
    rid = next_span_id()
    log.record("spawn", "request", 1, span=rid)
    with span_scope(rid):
        with log.lifecycle("prefill", 1) as pf:
            log.record("dispatch", "serve_prefill", {"backend": "ref"})
    log.record("exit", "request", 1, span=rid)
    spawns = {e.name: e for e in log.events(kind="spawn")}
    assert spawns["prefill"].parent == rid
    assert log.events(kind="dispatch")[0].parent == pf


def test_concurrent_threads_do_not_cross_parent():
    """Each thread's contextvars stack is its own: spans opened concurrently
    on one shared ring must parent only within their own thread."""
    col = TraceCollector(capacity=4096)
    n_threads, per_thread = 8, 25
    errors: list[str] = []

    def work(tid: int) -> None:
        for i in range(per_thread):
            with col.lifecycle("request", (tid, i)) as rid:
                if current_span() != rid:
                    errors.append(f"thread {tid}: context leaked")
                with col.lifecycle("prefill", (tid, i)):
                    col.record("mark", "m", (tid, i))
            if current_span() != 0:
                errors.append(f"thread {tid}: stack not unwound")

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    spawn_parent = {e.span: e.parent for e in col.events(kind="spawn")}
    payload_of = {e.span: e.payload for e in col.events(kind="spawn")}
    prefills = [e for e in col.events(kind="spawn") if e.name == "prefill"]
    assert len(prefills) == n_threads * per_thread
    for e in prefills:
        # the prefill's parent is the request from the SAME (tid, i)
        assert spawn_parent[e.span] != 0
        assert payload_of[e.parent] == e.payload
    marks = col.events(kind="mark")
    for e in marks:
        assert payload_of[e.parent] == e.payload


def test_async_tasks_inherit_and_isolate_context():
    """contextvars are copied into asyncio tasks: concurrent coroutines nest
    under their own lifecycle, not each other's."""
    import asyncio

    log = EventLog()

    async def one_request(i: int) -> None:
        with log.lifecycle("request", i) as rid:
            await asyncio.sleep(0)  # force interleaving
            log.record("mark", "tick", i)
            await asyncio.sleep(0)
            assert current_span() == rid

    async def main() -> None:
        await asyncio.gather(*(one_request(i) for i in range(5)))

    asyncio.run(main())
    payload_of = {e.span: e.payload for e in log.events(kind="spawn")}
    for e in log.events(kind="mark"):
        assert payload_of[e.parent] == e.payload


# ---------------------------------------------------------------------------
# resolve_spans: orphan close + accounting; span_tree fallback
# ---------------------------------------------------------------------------


def test_orphaned_spawn_closes_at_last_event_time_and_is_counted():
    """A spawn whose exit was evicted must not leak: it closes (truncated) at
    the last observed event time and lands in dropped_by_track."""
    evs = [
        Event(1.0, "spawn", "request", "lost-exit", 7),
        Event(2.0, "spawn", "request", "ok", 8),
        Event(3.0, "exit", "request", "ok", 8),
        Event(4.0, "mark", "m", None),
    ]
    orphans: dict = {}
    spans = resolve_spans(evs, orphans=orphans)
    lost = next(s for s in spans if s.payload == "lost-exit")
    assert lost.truncated and lost.t1 == pytest.approx(4.0)
    assert orphans == {"request": 1}
    ok = next(s for s in spans if s.payload == "ok")
    assert not ok.truncated and ok.dur == pytest.approx(1.0)


def test_truncated_spans_excluded_from_latency_report():
    """A force-closed span is a cut artifact, not a measurement: it must not
    inflate the latency tables (and through them the diff CI gate)."""
    evs = [
        Event(0.0, "spawn", "request", "lost", 11),   # exit evicted
        Event(1.0, "spawn", "request", "ok", 12),
        Event(1.5, "exit", "request", "ok", 12),
        Event(100.0, "mark", "late", None),           # would close "lost" at t=100
    ]
    rep = Session(meta={}, events=evs).report()
    row = rep["latency"]["request/request"]
    assert row["count"] == 1
    assert row["max_ms"] == pytest.approx(500.0)  # the 100s orphan excluded
    assert rep["truncated_spans"] == 1


def test_collector_dropped_by_track_includes_orphans():
    col = TraceCollector(capacity=64)
    col.record("spawn", "request", "A", span=next_span_id())  # exit never comes
    with col.lifecycle("request", "B"):
        pass
    col.record("mark", "m")
    assert col.dropped_by_track().get("request") == 1
    assert col.stats()["dropped_by_track"]["request"] == 1


def test_span_tree_orphan_parent_falls_back_to_root():
    """Parent evicted before child: the child keeps its subtree as a new
    root instead of vanishing."""
    pid, cid, gid = next_span_id(), next_span_id(), next_span_id()
    evs = [
        # parent's spawn/exit both evicted: only the child + grandchild remain
        Event(2.0, "spawn", "prefill", 1, cid, pid),
        Event(2.5, "mark", "probe", None, gid, cid),
        Event(3.0, "exit", "prefill", 1, cid, pid),
    ]
    roots = span_tree(resolve_spans(evs))
    assert len(roots) == 1
    assert roots[0].span.span == cid  # orphan promoted to root
    assert [c.span.span for c in roots[0].children] == [gid]


def test_span_tree_nests_and_computes_exclusive():
    log = EventLog()
    with log.lifecycle("step", 0):
        with log.lifecycle("checkpoint", 0):
            pass
    roots = span_tree(resolve_spans(log.events()))
    assert len(roots) == 1
    step = roots[0]
    assert step.span.name == "step" and len(step.children) == 1
    ckpt = step.children[0]
    assert ckpt.span.name == "checkpoint"
    assert step.exclusive == pytest.approx(step.span.dur - ckpt.span.dur)
    assert ckpt.exclusive == pytest.approx(ckpt.span.dur)


# ---------------------------------------------------------------------------
# golden exports: speedscope evented + Perfetto nesting/flows
# ---------------------------------------------------------------------------


def _golden_events() -> list[Event]:
    """Deterministic two-request trace with a dispatch child."""
    return [
        Event(0.0, "spawn", "request", "A", 1),
        Event(1.0, "spawn", "prefill", "A", 2, 1),
        Event(2.0, "exit", "prefill", "A", 2, 1),
        Event(3.0, "spawn", "request", "B", 3),   # overlaps A
        Event(4.0, "dispatch", "serve_decode",
              {"op": "serve_decode", "backend": "ref", "measured_s": 0.5}, 4, 3),
        Event(5.0, "exit", "request", "A", 1),
        Event(6.0, "exit", "request", "B", 3),
    ]


def test_speedscope_evented_golden():
    doc = to_speedscope(_golden_events())
    request = next(p for p in doc["profiles"] if p["name"] == "request")
    frames = [f["name"] for f in doc["shared"]["frames"]]
    named = [(e["type"], frames[e["frame"]], e["at"]) for e in request["events"]]
    # A opens, prefill nests inside it, B opens inside A's window; when A
    # closes while B is on top, B is closed/reopened (rebalancing) so the
    # profile stays a valid strict stack
    assert named == [
        ("O", "request", 0.0),
        ("O", "prefill", 1.0),
        ("C", "prefill", 2.0),
        ("O", "request", 3.0),
        ("C", "request", 5.0),  # B closed to let A pop...
        ("C", "request", 5.0),  # ...A closes...
        ("O", "request", 5.0),  # ...B reopens
        ("C", "request", 6.0),
    ]
    assert all(p["type"] == "evented" for p in doc["profiles"])


def test_chrome_subtree_shares_root_async_id_and_flows():
    doc = to_chrome_trace(_golden_events())
    rows = doc["traceEvents"]
    be = [r for r in rows if r["ph"] in ("b", "e")]
    # request A (span 1) and its prefill (span 2) group under root id "1"
    a_rows = [r for r in be if r["args"].get("span") in (1, 2)]
    assert len(a_rows) == 4 and {r["id"] for r in a_rows} == {"1"}
    # request B groups under its own root
    b_rows = [r for r in be if r["args"].get("span") == 3]
    assert {r["id"] for r in b_rows} == {"3"}
    # parent links surface in args
    prefill = next(r for r in be if r["name"] == "prefill" and r["ph"] == "b")
    assert prefill["args"]["parent"] == 1
    # the dispatch under request B gets a flow arrow from B's spawn
    flows = [r for r in rows if r.get("cat") == "flow"]
    assert {r["ph"] for r in flows} == {"s", "f"}
    s = next(r for r in flows if r["ph"] == "s")
    f = next(r for r in flows if r["ph"] == "f")
    assert s["id"] == f["id"]
    b_spawn = next(r for r in be if r["args"].get("span") == 3 and r["ph"] == "b")
    assert s["ts"] == b_spawn["ts"] and s["tid"] == b_spawn["tid"]


def test_folded_export_uses_ancestor_paths():
    text = to_folded(_golden_events())
    lines = dict(ln.rsplit(" ", 1) for ln in text.splitlines() if ln)
    assert "request;request;prefill" in lines
    assert "request;request;serve_decode;ref" in lines
    # exclusive weighting: request A's self time excludes the 1s prefill
    assert int(lines["request;request;prefill"]) == 1_000_000


# ---------------------------------------------------------------------------
# report --tree
# ---------------------------------------------------------------------------


def test_tree_report_groups_and_depths():
    sess = Session(meta={}, events=_golden_events())
    rows = sess.tree_report()
    by_name = {(r["depth"], r["name"]): r for r in rows}
    req = by_name[(0, "request")]
    assert req["count"] == 2
    assert req["inclusive_ms"] == pytest.approx(8000.0)  # 5s + 3s
    pf = by_name[(1, "prefill")]
    assert pf["count"] == 1 and pf["inclusive_ms"] == pytest.approx(1000.0)
    disp = by_name[(1, "serve_decode")]
    assert disp["track"] == "dispatch"
    # exclusive subtracts children: A(5s) - prefill(1s) + B(3s) - dispatch(.5s)
    assert req["exclusive_ms"] == pytest.approx(6500.0)


def test_cli_report_tree(tmp_path, capsys):
    from repro.trace.cli import main

    path = Session(meta={}, events=_golden_events()).save(str(tmp_path / "s.json"))
    assert main(["report", path, "--tree"]) == 0
    out = capsys.readouterr().out
    assert "request/request" in out
    assert "  dispatch/serve_decode" in out  # indented child
    assert main(["report", path, "--tree", "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    assert any(r["depth"] > 0 for r in rows)


# ---------------------------------------------------------------------------
# acceptance: serving engine produces a real tree (dispatch under request)
# ---------------------------------------------------------------------------


def test_engine_dispatch_decisions_are_children_of_requests(key):
    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.serving.engine import Engine, ServeConfig

    cfg = reduced(get_config("smollm-360m"))
    params = lm.init_params(cfg, key)
    col = TraceCollector()
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=1), log=col)
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_seq=64),
                 log=col, dispatcher=disp)
    with col.lifecycle("serve_run", {"requests": 3}):
        for _ in range(3):
            eng.submit([1, 2, 3, 4], max_new=4)
        eng.run_to_completion()

    spawn_of = {e.span: e for e in col.events(kind="spawn")}
    prefill_dispatches = [e for e in col.events(kind="dispatch")
                          if e.payload["op"] == "serve_prefill"]
    assert prefill_dispatches
    for e in prefill_dispatches:
        # dispatch -> prefill -> request -> serve_run: depth 3
        pf = spawn_of[e.parent]
        assert pf.name == "prefill"
        req = spawn_of[pf.parent]
        assert req.name == "request"
        assert spawn_of[req.parent].name == "serve_run"
    decode_dispatches = [e for e in col.events(kind="dispatch")
                         if e.payload["op"] == "serve_decode"]
    assert decode_dispatches
    for e in decode_dispatches:
        assert spawn_of[e.parent].name == "decode_tick"

    # the tree view agrees: non-zero depth everywhere below the root
    rows = Session.capture(col, dispatcher=disp).tree_report()
    disp_rows = [r for r in rows if r["track"] == "dispatch"]
    assert disp_rows and all(r["depth"] >= 2 for r in disp_rows)


# ---------------------------------------------------------------------------
# device timelines: synthetic jax.profiler dump merged under host spans
# ---------------------------------------------------------------------------


def _write_profiler_dump(tmp_path, rows) -> str:
    """A TensorBoard-style profiler dir holding a gzipped chrome trace."""
    run_dir = tmp_path / "plugins" / "profile" / "2026_07_30_00_00_00"
    run_dir.mkdir(parents=True)
    path = run_dir / "host.trace.json.gz"
    with gzip.open(path, "wt") as f:
        json.dump({"traceEvents": rows}, f)
    return str(tmp_path)


def _device_dump_rows():
    return [
        {"ph": "M", "pid": 10, "name": "process_name",
         "args": {"name": "/device:TPU:0"}},
        {"ph": "M", "pid": 99, "name": "process_name",
         "args": {"name": "python host threads"}},
        # two device ops inside the host prefill window (1.0s..2.0s below),
        # timestamps in µs in the profiler's own clock starting at 0
        {"ph": "X", "pid": 10, "tid": 1, "name": "fusion.1",
         "ts": 1_100_000, "dur": 300_000},
        {"ph": "X", "pid": 10, "tid": 1, "name": "copy.2",
         "ts": 1_500_000, "dur": 100_000, "args": {"bytes": 4096}},
        # a host-side X row that must be filtered out (device_only)
        {"ph": "X", "pid": 99, "tid": 2, "name": "python_gc",
         "ts": 1_200_000, "dur": 50_000},
        # a hinted slice: binds to span 2 regardless of its window
        {"ph": "X", "pid": 10, "tid": 1, "name": "span=2 rms_norm",
         "ts": 5_900_000, "dur": 50_000},
    ]


def test_load_profiler_trace_parses_dump(tmp_path):
    dump = _write_profiler_dump(tmp_path, _device_dump_rows())
    slices = load_profiler_trace(dump)
    assert [s.name for s in slices] == ["fusion.1", "copy.2", "span=2 rms_norm"]
    assert all(s.device == "/device:TPU:0" for s in slices)
    assert slices[0].dur == pytest.approx(0.3)
    assert slices[2].span_hint == 2


def test_device_events_align_under_host_spans(tmp_path):
    host = _golden_events()  # dump shares the host clock -> explicit offset 0
    dump = _write_profiler_dump(tmp_path, _device_dump_rows())
    merged = align_device_slices(host, load_profiler_trace(dump), offset_s=0.0)
    assert len(merged) == 3
    by_name = {e.name: e for e in merged}
    # window containment: both ops sit inside prefill (span 2), the innermost
    assert by_name["fusion.1"].parent == 2
    assert by_name["copy.2"].parent == 2
    # the hint overrides the window (its ts lies outside every span)
    assert by_name["span=2 rms_norm"].parent == 2
    assert all(e.kind == "device" for e in merged)
    assert all(e.span != 0 for e in merged)  # real tree nodes
    # device ids must sit strictly above every host id (the session comes
    # from another process, so this process's span counter is meaningless —
    # colliding ids would trip span_tree's corrupt-parent guard)
    host_max = max(max(e.span, e.parent) for e in host)
    assert all(e.span > host_max for e in merged)
    assert len({e.span for e in merged}) == len(merged)


def test_merge_device_trace_into_session_report_and_export(tmp_path):
    sess = Session(meta={}, events=_golden_events())
    dump = _write_profiler_dump(tmp_path, _device_dump_rows())
    n = merge_device_trace(sess, dump, offset_s=0.0)
    assert n == 3 and sess.meta["device_trace"]["events"] == 3

    rows = sess.tree_report()
    dev_rows = [r for r in rows if r["track"].startswith("device:")]
    assert dev_rows and all(r["depth"] >= 2 for r in dev_rows)

    doc = to_chrome_trace(sess.events)
    names = {r["args"]["name"]: r["tid"] for r in doc["traceEvents"]
             if r["ph"] == "M" and r["name"] == "thread_name"}
    assert "device:/device:TPU:0" in names
    # host tracks render above (lower tid than) device tracks
    assert names["request"] < names["device:/device:TPU:0"]
    dev_x = [r for r in doc["traceEvents"]
             if r["ph"] == "X" and r.get("cat") == "device"]
    assert len(dev_x) == 3 and all(r["dur"] > 0 for r in dev_x)

    # latency tables pick the device track up too
    rep = sess.report()
    assert any(k.startswith("device:") for k in rep["latency"])


def test_cli_report_device_trace_flag(tmp_path, capsys):
    from repro.trace.cli import main

    path = Session(meta={}, events=_golden_events()).save(str(tmp_path / "s.json"))
    dump = _write_profiler_dump(tmp_path, _device_dump_rows())
    assert main(["report", path, "--tree", "--device-trace", dump]) == 0
    out = capsys.readouterr().out
    assert "device:/device:TPU:0" in out


def test_profiler_dump_xplane_only_errors(tmp_path):
    d = tmp_path / "dump" / "plugins" / "profile" / "run"
    d.mkdir(parents=True)
    (d / "host.xplane.pb").write_bytes(b"\x00")
    with pytest.raises(ValueError, match="xplane"):
        load_profiler_trace(str(tmp_path / "dump"))
