"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one train step + prefill/decode on CPU; shape + finiteness asserts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import lm
from repro.training.step import TrainConfig, init_train_state, make_train_step

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 2)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend != "text":
        batch["frontend_embed"] = jax.random.normal(ks[1], (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, key):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig()
    state = init_train_state(cfg, tcfg, key)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, key)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss"
    assert loss < 2.5 * np.log(cfg.vocab_size) + 2, f"{arch}: init loss {loss} unreasonable"
    # params actually moved and stayed finite
    leaves = jax.tree.leaves(state["params"])
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, key):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, caches = jax.jit(
        lambda p, t, f: lm.prefill(p, cfg, t, f, max_seq=S + 8)
    )(params, batch["tokens"], batch.get("frontend_embed"))
    assert logits.shape == (B, cfg.vocab_size)
    nxt = jnp.argmax(logits, -1)
    fe1 = batch.get("frontend_embed")
    fe1 = fe1[:, :1] if fe1 is not None else None
    logits2, caches2 = jax.jit(
        lambda p, t, c, ch, f: lm.decode_step(p, cfg, t, c, ch, f)
    )(params, nxt, jnp.full((B,), S, jnp.int32), caches, fe1)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    # cache structure is preserved (donation-compatible)
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["gemma2-27b", "jamba-1.5-large", "rwkv6-7b", "deepseek-moe-16b", "gemma3-4b"])
def test_decode_matches_full_forward(arch, key):
    """Teacher-forced decode must reproduce the full-sequence logits — the
    strongest cache-correctness property (exercises ring SWA buffers, Mamba
    conv/ssm states, RWKV shift/wkv states, MoE per-token routing)."""
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, key)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    hidden, _, _ = lm.forward(params, cfg, tokens)
    full_logits = lm._logits(params, cfg, hidden)  # (B, S, V)

    # prefill on the first half, then teacher-forced decode of the rest
    half = S // 2
    _, caches = lm.prefill(params, cfg, tokens[:, :half], max_seq=S)
    got = []
    for t in range(half, S):
        logits_t, caches = lm.decode_step(
            params, cfg, tokens[:, t], jnp.full((B,), t, jnp.int32), caches
        )
        got.append(logits_t)
    got = jnp.stack(got, axis=1)  # (B, S-half, V)
    want = full_logits[:, half:]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2, rtol=2e-2
    )


def test_long_500k_skips_are_exactly_the_pure_full_attention_archs():
    from repro.configs import SHAPES, supports_shape

    skipped = {a for a in ARCHS if not supports_shape(get_config(a), SHAPES["long_500k"])[0]}
    assert skipped == {
        "smollm-360m", "qwen2-0.5b", "chameleon-34b",
        "deepseek-moe-16b", "dbrx-132b", "musicgen-large",
    }


def test_scan_period_coverage():
    """Layer bookkeeping: first_k + periods×period + tail == n_layers."""
    for arch in ARCHS:
        cfg = get_config(arch)
        assert (
            cfg.first_k_dense + cfg.n_periods * cfg.period + cfg.n_tail == cfg.n_layers
        ), arch
