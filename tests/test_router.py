"""repro.router: cost model, admission control, exactly-once drain-retry.

Everything here runs accelerator-free: unit tests drive the CostRouter and an
in-process synthetic replica directly; the end-to-end test spawns the real
``python -m repro.router`` front door with synthetic replicas and SIGKILLs one
mid-run (same style as test_stream.py's crash-recovery test).
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time
import urllib.request

import pytest

from repro.dispatch.profiles import ProfileStore
from repro.metrics import MetricsPlane
from repro.router import (
    CostRouter,
    NoReplicaAvailable,
    ReplicaServer,
    RouterBusy,
    SyntheticEngine,
    class_of,
    expected_synthetic_tokens,
    seed_costs_from_store,
)
from repro.router.loadgen import build_specs, run as loadgen_run
from repro.trace import TraceCollector
from repro.utils.ready import read_ready_info, wait_for_ready_file, write_ready_file

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Request classes + fleet-profile seed pricing
# ---------------------------------------------------------------------------


def test_class_of_pow2_buckets():
    assert class_of(8, 16) == "p8/n16"
    assert class_of(9, 16) == "p16/n16"   # rounds up to the next power of two
    assert class_of(16, 17) == "p16/n32"
    assert class_of(1, 1) == "p1/n1"


def _seeded_store(prefill_s: float, decode_s: float, plen: int = 16) -> ProfileStore:
    store = ProfileStore(min_samples=1)
    store.record("serve_prefill", "chunked", f"int32[1,{plen}]", prefill_s)
    store.record("serve_decode", "chunked", "int32[4,1]", decode_s)
    return store


def test_seed_costs_priced_from_profile_store():
    store = _seeded_store(0.010, 0.002, plen=16)
    # a second, slower backend must not win the pricing (min over backends)
    store.record("serve_prefill", "ref", "int32[1,16]", 0.050)
    seed = seed_costs_from_store(store, match="exact")
    assert seed is not None and seed.match == "exact"
    assert seed.prefill_s == {16: pytest.approx(0.010)}
    assert seed.cost("p16/n8") == pytest.approx(0.010 + 8 * 0.002)
    # nearest prompt length is used when the class has no exact entry
    assert seed.cost("p32/n8") == pytest.approx(0.010 + 8 * 0.002)


def test_seed_costs_none_when_unpriceable():
    assert seed_costs_from_store(None) is None
    assert seed_costs_from_store(ProfileStore()) is None
    store = ProfileStore(min_samples=1)
    store.record("serve_prefill", "chunked", "int32[1,16]", 0.01)  # no decode
    assert seed_costs_from_store(store) is None


# ---------------------------------------------------------------------------
# CostRouter: argmin, tie-break, admission, EWMA feedback
# ---------------------------------------------------------------------------


def _router(**kw) -> CostRouter:
    r = CostRouter(**kw)
    for name in ("r0", "r1"):
        r.add_replica(name)
        r.mark_up(name, f"http://{name}")
    return r


def test_route_argmin_over_fleet_seeds():
    r = _router()
    r.seed_replica("r0", _seeded_store(0.010, 0.001))   # cheap chip
    r.seed_replica("r1", _seeded_store(0.040, 0.008))   # slow chip
    picks = {r.route("p16/n16").replica for _ in range(8)}
    assert picks == {"r0"}
    d = r.route("p16/n16")
    assert d.source == "seed" and d.cost_s == pytest.approx(0.010 + 16 * 0.001)


def test_route_least_loaded_tie_break():
    r = _router()  # both cold -> identical default cost -> always a tie
    r.begin("r0")
    r.begin("r0")
    assert all(r.route("p8/n8").replica == "r1" for _ in range(4))
    # balance restored -> round-robin spreads across both again
    r.begin("r1")
    r.begin("r1")
    assert {r.route("p8/n8").replica for _ in range(4)} == {"r0", "r1"}


def test_admission_sheds_when_all_queues_full():
    r = _router(queue_depth=2)
    for _ in range(2):
        r.begin("r0")
        r.begin("r1")
    with pytest.raises(RouterBusy):
        r.route("p8/n8")
    assert r.rejected == 1
    r.end("r1")  # one slot frees -> admits again, onto the freed replica
    assert r.route("p8/n8").replica == "r1"


def test_no_replica_available_when_all_down():
    r = _router()
    r.mark_down("r0")
    r.fail("r1", dead=True)  # dead forward also unroutes the replica
    with pytest.raises(NoReplicaAvailable):
        r.route("p8/n8")
    r.mark_up("r0", "http://r0")
    assert r.route("p8/n8").replica == "r0"


def test_ewma_feedback_overrides_seed():
    r = _router()
    r.seed_replica("r0", _seeded_store(0.001, 0.0001))  # seed says r0 is fast
    r.seed_replica("r1", _seeded_store(0.002, 0.0002))
    # ...but observed service times say the opposite (r0 loaded/thermal)
    for _ in range(4):
        r.complete("r0", "p16/n16", 0.500)
        r.complete("r1", "p16/n16", 0.050)
    d = r.route("p16/n16")
    assert d.replica == "r1" and d.source == "ewma"
    snap = r.snapshot()["replicas"]
    assert snap["r0"]["ewma_ms"]["p16/n16"] > snap["r1"]["ewma_ms"]["p16/n16"]


def test_router_maintains_registry_gauges():
    from repro.metrics.registry import MetricsRegistry

    reg = MetricsRegistry()
    r = CostRouter(registry=reg)
    r.add_replica("r0")
    r.mark_up("r0", "http://r0")
    r.begin("r0")
    text = reg.render()
    assert 'repro_router_replica_queue_depth{replica="r0"} 1' in text
    assert 'repro_router_replica_up{replica="r0"} 1' in text
    r.end("r0")
    r.mark_down("r0")
    text = reg.render()
    assert 'repro_router_replica_queue_depth{replica="r0"} 0' in text
    assert 'repro_router_replica_up{replica="r0"} 0' in text


# ---------------------------------------------------------------------------
# Trace/metrics planes: route events land on the router track and derive
# the repro_router_* series
# ---------------------------------------------------------------------------


def test_route_events_derive_router_metrics():
    col = TraceCollector()
    plane = MetricsPlane(col)
    for outcome, ms in (("ok", 0.2), ("ok", 0.4), ("retried", 0.3)):
        col.record("route", "outcome",
                   {"replica": "r0", "outcome": outcome, "route_ms": ms})
    # per-attempt decision events must NOT count requests (retries overcount)
    col.record("route", "route", {"replica": "r0", "class": "p8/n8"})
    assert all(e.kind == "route" for e in col.tracks()["router"])
    text = plane.render()
    assert 'repro_router_requests_total{outcome="ok",replica="r0"} 2' in text
    assert 'repro_router_requests_total{outcome="retried",replica="r0"} 1' in text
    assert "repro_router_route_ms_count 3" in text


# ---------------------------------------------------------------------------
# Shared ready-file handshake (repro.utils.ready)
# ---------------------------------------------------------------------------


def test_ready_file_roundtrip(tmp_path):
    p = str(tmp_path / "x.ready")
    write_ready_file(p, {"url": "http://127.0.0.1:1234", "pid": 42})
    info = read_ready_info(p)
    assert info["url"] == "http://127.0.0.1:1234" and info["pid"] == 42
    assert json.loads(wait_for_ready_file(p, timeout_s=1.0))["url"] == info["url"]
    # bare-URL form (repro.fleet serve writes this)
    write_ready_file(p, "http://127.0.0.1:9")
    assert read_ready_info(p) == {"url": "http://127.0.0.1:9"}
    with pytest.raises(TimeoutError):
        wait_for_ready_file(str(tmp_path / "never.ready"), timeout_s=0.2)


# ---------------------------------------------------------------------------
# In-process synthetic replica: deterministic tokens over HTTP
# ---------------------------------------------------------------------------


def test_replica_server_roundtrip_and_health():
    col = TraceCollector()
    plane = MetricsPlane(col)
    eng = SyntheticEngine(max_batch=2, ms_per_token=0.0, log=col,
                          metrics=plane.registry)
    srv = ReplicaServer(eng, name="t0", log=col, plane=plane,
                        info={"chip": "test"}).start()
    try:
        body = json.dumps({"prompt": [1, 2, 3], "max_new": 5}).encode()
        req = urllib.request.Request(
            f"{srv.url}/v1/generate", data=body, method="POST",
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=10) as resp:
            doc = json.loads(resp.read())
        assert doc["tokens"] == expected_synthetic_tokens([1, 2, 3], 5)
        assert doc["replica"] == "t0"
        with urllib.request.urlopen(f"{srv.url}/healthz", timeout=10) as resp:
            h = json.loads(resp.read())
        assert h["ok"] and h["completed"] == 1 and h["chip"] == "test"
        with urllib.request.urlopen(f"{srv.url}/metrics", timeout=10) as resp:
            assert b"repro_requests_total" in resp.read()
    finally:
        srv.stop()
    # the engine's request span nests under the handler's rpc span, which
    # nests under the replica's serve_run root
    spawns = {e.span: (e.name, e.parent) for e in col.events() if e.kind == "spawn"}
    req_spans = [s for s, (n, _p) in spawns.items() if n == "request"]
    assert req_spans and all(
        spawns[spawns[s][1]][0] == "rpc" for s in req_spans)
    assert all(
        spawns[spawns[spawns[s][1]][1]][0] == "serve_run" for s in req_spans)


def test_synthetic_engine_concurrent_submit_exactly_once():
    eng = SyntheticEngine(max_batch=4, ms_per_token=0.0)
    rids: list[int] = []
    lock = threading.Lock()

    def submit(i):
        rid = eng.submit([i, i + 1], max_new=3)
        with lock:
            rids.append(rid)

    threads = [threading.Thread(target=submit, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(rids) == list(range(16))  # no rid reuse under contention
    done = []
    while eng.pending():
        done.extend(eng.step())
    assert len(done) == 16
    for r in done:
        assert r.out == expected_synthetic_tokens(r.prompt, r.max_new)


# ---------------------------------------------------------------------------
# End to end: router subprocess, SIGKILL a replica mid-run, exactly-once
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_router_sigkill_replica_exactly_once(tmp_path):
    """The CI router-smoke scenario, as a test: 2 synthetic replicas behind
    the front door, SIGKILL one mid-run, every request completes exactly once
    with verifiably-correct tokens, and the dead replica is restarted."""
    trace_dir = str(tmp_path / "trace")
    ready = str(tmp_path / "router.ready")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.router", "--replicas", "2",
         "--synthetic", "--synthetic-ms-per-token", "5",
         "--port", "0", "--ready-file", ready,
         "--workdir", str(tmp_path / "work"), "--trace-dir", trace_dir],
        env=env, cwd=REPO, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
    )
    report = None
    try:
        wait_for_ready_file(ready, timeout_s=120, proc=proc)
        url = read_ready_info(ready)["url"]

        def healthz():
            with urllib.request.urlopen(f"{url}/healthz", timeout=10) as resp:
                return json.loads(resp.read())

        victim_pid = healthz()["replicas"]["r0"]["pid"]
        specs = build_specs(120, [8, 16, 32], 16, seed=1)
        result: dict = {}

        def drive():
            result["report"] = loadgen_run(url, specs, concurrency=8,
                                           timeout_s=60, verify_synthetic=True)

        t = threading.Thread(target=drive, daemon=True)
        t.start()
        # let some requests land on r0, then kill it mid-run
        deadline = time.time() + 60
        while time.time() < deadline:
            h = healthz()
            if h["router"]["replicas"]["r0"]["completed"] >= 3:
                break
            time.sleep(0.05)
        else:
            pytest.fail("r0 served nothing within 60s")
        os.kill(victim_pid, signal.SIGKILL)
        t.join(timeout=120)
        assert not t.is_alive(), "loadgen did not finish"
        report = result["report"]

        # exactly-once: every request accounted, none duplicated or lost,
        # every completed response carries the deterministic expected tokens
        assert report["completed"] == report["submitted"] == 120
        assert report["duplicates"] == 0 and report["lost"] == 0
        assert report["verify_failures"] == 0 and report["verified"] == 120

        # supervisor restarts the killed replica (new pid, routable again)
        deadline = time.time() + 60
        while time.time() < deadline:
            h = healthz()
            r0 = h["replicas"]["r0"]
            if r0["state"] == "up" and r0["restarts"] >= 1 \
                    and r0["pid"] != victim_pid:
                break
            time.sleep(0.2)
        else:
            pytest.fail(f"r0 not restarted: {healthz()['replicas']}")

        # metrics account for every request: sum over outcomes == submitted
        with urllib.request.urlopen(f"{url}/metrics", timeout=10) as resp:
            text = resp.read().decode()
        total = sum(
            float(line.rsplit(" ", 1)[1])
            for line in text.splitlines()
            if line.startswith("repro_router_requests_total{"))
        assert total == 120
    finally:
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            proc.kill()

    # the streamed trace survives: route spans parent under request spans
    out = str(tmp_path / "session.json")
    from repro.trace.cli import main as trace_main

    assert trace_main(["compact", trace_dir, "-o", out]) == 0
    doc = json.load(open(out))
    evs = doc["trace"]["events"]
    req_spans = {e["span"] for e in evs
                 if e["kind"] == "spawn" and e["name"] == "request"}
    routes = [e for e in evs if e["kind"] == "route"]
    outcomes = [e for e in routes if e["name"] == "outcome"]
    assert len(outcomes) == 120
    assert routes and all(e["parent"] in req_spans for e in routes)
    assert sum(1 for e in outcomes if e["payload"]["outcome"] == "retried") \
        == report["outcomes"]["retried"]
