"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py (a subprocess in test_dryrun.py) forces 512
placeholder devices."""
import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test (dry-run subprocesses)")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_global_event_log():
    """Instrumentation and dispatch state must not leak across tests: any
    events a test records in the shared GLOBAL_LOG are dropped afterwards."""
    from repro.core.events import GLOBAL_LOG

    yield
    GLOBAL_LOG.clear()
