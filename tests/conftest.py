"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py (a subprocess in test_dryrun.py) forces 512
placeholder devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
