"""Static tracepoints (USDT analogue) — the costs the module docstring claims.

core/tracepoints.py promises: disabled markers leave the jitted HLO
*byte-identical* to the uninstrumented program, and tape mode adds only
device-side scalar ops (no host traffic).  This file pins both, plus the
callback-mode contrast (host custom-calls present — the uprobe-style trap).
"""
import re

import jax
import jax.numpy as jnp

from repro.core import tracepoints as tp
from repro.core.events import EventLog


def _workload(x):
    """A small instrumented program (standalone so jit caches stay private).

    Points fire at the jit trace level (markers inside a scan body belong to
    the inner trace — same rule as USDT: probes sit at function scope).
    """
    tp.point("wl.enter", jnp.float32(x.shape[0]))

    def body(c, _):
        return 0.5 * (c + x / c), None

    c = jnp.maximum(x * 0.5, 1.0)
    c, _ = jax.lax.scan(body, c, None, length=8)
    for _ in range(3):
        tp.point("wl.iter", None)  # count agg, fires 3x per trace
    tp.point("wl.exit", c[0])
    return c


def _plain(x):
    def body(c, _):
        return 0.5 * (c + x / c), None

    c = jnp.maximum(x * 0.5, 1.0)
    c, _ = jax.lax.scan(body, c, None, length=8)
    return c


def _strip_meta(hlo: str) -> str:
    # only location/name metadata may differ; computation must not
    return re.sub(r"loc\(.*?\)|metadata=\{[^}]*\}|#loc\d+ = .*|module @\S+", "", hlo)


def test_disabled_markers_leave_hlo_byte_identical():
    x = jnp.arange(1.0, 65.0)
    hlo_inst = jax.jit(_workload).lower(x).as_text()
    hlo_plain = jax.jit(_plain).lower(x).as_text()
    assert _strip_meta(hlo_inst) == _strip_meta(hlo_plain)


def test_tape_mode_adds_only_device_side_scalar_ops():
    """Tape mode must not emit host callbacks: the instrumented HLO contains
    no custom-calls, and the extra outputs are scalars."""
    x = jnp.arange(1.0, 65.0)
    with tp.enable("tape"):
        lowered = jax.jit(tp.collect(_workload)).lower(x)
    hlo = lowered.as_text()
    assert "custom-call" not in hlo and "custom_call" not in hlo
    with tp.enable("tape"):
        out, tape = jax.jit(tp.collect(_workload))(x)
    assert set(tape) == {"wl.enter", "wl.iter", "wl.exit"}
    # outside the enable() context the same wrapper is a no-op
    out2, tape2 = tp.collect(_workload)(x)
    assert tape2 == {}


def test_tape_values_and_fire_counts():
    x = jnp.arange(1.0, 65.0)
    with tp.enable("tape"):
        out, tape = jax.jit(tp.collect(_workload))(x)
    val, fires = tape["wl.enter"]
    assert float(val) == 64.0 and int(fires) == 1
    assert int(tape["wl.iter"][0]) == 3  # count agg accumulates per fire
    assert all(v.ndim == 0 for v, _ in tape.values())  # scalars only


def test_callback_mode_emits_host_custom_call():
    """The contrast case: callback mode is the kernel-trap-style mechanism,
    visible in the HLO as a host custom-call."""
    x = jnp.arange(1.0, 65.0)
    log = EventLog()
    with tp.enable("callback", log=log):
        hlo = jax.jit(lambda v: _workload(v)).lower(x).as_text()
    assert "custom-call" in hlo or "custom_call" in hlo


def test_tape_hlo_size_overhead_is_small():
    """Tape instrumentation adds a handful of scalar ops, not a reflow of the
    program: HLO line count grows by far less than 2x."""
    x = jnp.arange(1.0, 65.0)
    plain_lines = len(jax.jit(_plain).lower(x).as_text().splitlines())
    with tp.enable("tape"):
        inst_lines = len(jax.jit(tp.collect(_workload)).lower(x).as_text().splitlines())
    assert inst_lines < 2 * plain_lines
