"""Profile-guided dispatch subsystem (repro.dispatch).

Covers the ISSUE's acceptance surface: cost-model monotonicity, the
measured-beats-estimated override, argmin placement over SDFG regions
(ref for tiny shapes, Pallas for large — priced on the TPU ChipSpec), and
end-to-end routing through the serving engine with dispatch events logged.
"""
import jax
import jax.numpy as jnp

from repro.core import sdfg
from repro.core.events import EventLog
from repro.core.sdfg import Region
from repro.dispatch import (
    DispatchConfig,
    Dispatcher,
    ProfileStore,
    default_registry,
    estimate_region,
    host_registry,
    signature,
    with_impl,
)
from repro.hw.specs import TPU_V5E


def _region(name: str, flops: float, bytes_: float) -> Region:
    r = Region(name)
    r.flops = flops
    r.bytes = bytes_
    r.nodes = 1
    r.backends[sdfg.MXU] = flops
    return r


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_cost_monotone_in_work():
    """Bigger region (more flops AND more bytes) => cost never decreases."""
    reg = default_registry()
    small = _region("s", 1e9, 1e6)
    for mult in (2.0, 10.0, 1000.0):
        big = _region("b", 1e9 * mult, 1e6 * mult)
        for t in reg.targets():
            assert (
                estimate_region(big, t, TPU_V5E).seconds
                >= estimate_region(small, t, TPU_V5E).seconds
            )


def test_cost_positive_and_has_overhead_floor():
    reg = default_registry()
    empty = _region("e", 0.0, 0.0)
    for t in reg.targets():
        e = estimate_region(empty, t, TPU_V5E)
        assert e.seconds >= t.launch_overhead_s > 0


def test_roofline_tiny_prefers_ref_large_prefers_pallas():
    """The static model's crossover: launch overhead dominates tiny regions
    (naive reference wins), byte amplification dominates large ones (the
    fused Pallas kernel wins)."""
    reg = default_registry()  # includes pallas: priced for the TPU target
    disp = Dispatcher(DispatchConfig(policy="roofline"), registry=reg, log=EventLog())

    tiny = _region("tiny", 1e3, 1e3)
    ests = {b: e.seconds for b, e in disp.estimates_for_region(tiny).items()}
    assert min(ests, key=ests.get) == "ref"

    large = _region("large", 1e12, 1e9)
    ests = {b: e.seconds for b, e in disp.estimates_for_region(large).items()}
    assert min(ests, key=ests.get) == "pallas"


# ---------------------------------------------------------------------------
# profile store
# ---------------------------------------------------------------------------


def test_measured_overrides_estimate():
    store = ProfileStore(min_samples=2)
    assert store.combined_cost("op", "ref", "s", 1.0) == (1.0, "roofline")
    store.record("op", "ref", "s", 5.0)
    # one sample: not warm yet, estimate still wins
    assert store.combined_cost("op", "ref", "s", 1.0) == (1.0, "roofline")
    store.record("op", "ref", "s", 7.0)
    secs, src = store.combined_cost("op", "ref", "s", 1.0)
    # min of {5, 7}: robust to the cold (compile-inflated) first sample
    assert src == "measured" and secs == 5.0


def test_profile_flips_dispatch_decision():
    """Roofline says ref is cheapest; warm measurements say chunked — the
    dispatcher must follow the measurements (Adaptyst feedback loop)."""
    log = EventLog()
    disp = Dispatcher(
        DispatchConfig(policy="profiled", min_samples=1),
        registry=host_registry(),
        log=log,
    )
    ests = {"ref": 1e-6, "chunked": 1e-3}  # a-priori: ref wins by 1000x
    disp.store.record("op", "ref", "sig", 0.5)      # measured: ref is slow
    disp.store.record("op", "chunked", "sig", 0.01)  # measured: chunked fast
    d = disp.choose("op", "sig", ests)
    assert d.backend == "chunked" and d.source == "measured"


def test_profile_store_json_roundtrip():
    store = ProfileStore(min_samples=3)
    for v in (1.0, 2.0, 3.0):
        store.record("op", "ref", "s", v)
    clone = ProfileStore.from_json(store.to_json())
    assert clone.min_samples == 3
    assert clone.lookup("op", "ref", "s") == store.lookup("op", "ref", "s") == 1.0


def test_ingest_event_log_rehydrates_profiles():
    log = EventLog()
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=1), log=log)
    fns = {"chunked": jax.jit(lambda x: x * 2), "ref": jax.jit(lambda x: x + x)}
    for _ in range(4):
        disp.dispatch("toy", fns, jnp.ones((8,)))
    fresh = ProfileStore(min_samples=1)
    assert fresh.ingest_event_log(log) == 4
    sig = signature(jnp.ones((8,)))
    assert fresh.samples("toy", "chunked", sig) + fresh.samples("toy", "ref", sig) == 4


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def test_static_policy_pins_backend():
    disp = Dispatcher(
        DispatchConfig(policy="static", static_backend="ref"),
        registry=host_registry(),
        log=EventLog(),
    )
    for _ in range(3):
        d = disp.choose("op", "s", {"ref": 1.0, "chunked": 0.001})
        assert d.backend == "ref" and d.source == "static"


def test_profiled_explores_every_candidate_then_exploits():
    log = EventLog()
    disp = Dispatcher(
        DispatchConfig(policy="profiled", min_samples=2),
        registry=host_registry(),
        log=log,
    )
    fns = {"chunked": jax.jit(lambda x: x * 2), "ref": jax.jit(lambda x: x + x)}
    x = jnp.ones((16,))
    for _ in range(6):
        disp.dispatch("toy", fns, x)
    by_backend = {}
    for d in disp.decisions:
        by_backend.setdefault(d.backend, 0)
        by_backend[d.backend] += 1
    # both candidates explored to warmth (2 samples each)...
    assert all(v >= 2 for v in by_backend.values())
    # ...and post-warm decisions are measurement-driven
    assert disp.decisions[-1].source == "measured"
    assert len(log.events(kind="dispatch")) == 6


def test_partition_assigns_every_region_and_logs():
    def f(a, b):
        with jax.named_scope("mm"):
            c = a @ b
        with jax.named_scope("norm"):
            return c / (1e-6 + jnp.mean(jnp.abs(c)))

    g = sdfg.extract(f, jnp.ones((128, 256), jnp.bfloat16), jnp.ones((256, 128), jnp.bfloat16))
    log = EventLog()
    disp = Dispatcher(DispatchConfig(policy="roofline"), registry=default_registry(), log=log)
    placement = disp.partition(g)
    assert set(placement) == set(g.regions())
    assert all(d.backend in default_registry().names() for d in placement.values())
    assert len(log.events(kind="dispatch")) == len(placement)


def test_with_impl_bakes_backend_into_trace():
    """with_impl must bind the kernel impl at trace time, not call time."""
    from repro.kernels import ops

    q = jnp.ones((1, 8, 2, 8))
    f_ref = jax.jit(with_impl("ref", lambda q: ops.attention(q, q, q, causal=True)))
    f_chk = jax.jit(with_impl("chunked", lambda q: ops.attention(q, q, q, causal=True)))
    # chunked path lowers a scan over KV blocks; ref path has none
    assert "while" in f_chk.lower(q).as_text()
    assert "while" not in f_ref.lower(q).as_text()


# ---------------------------------------------------------------------------
# end-to-end: serving engine under dispatch
# ---------------------------------------------------------------------------


def test_engine_dispatched_matches_undispatched(key):
    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.serving.engine import Engine, ServeConfig

    cfg = reduced(get_config("smollm-360m"))
    params = lm.init_params(cfg, key)
    scfg = ServeConfig(max_batch=2, max_seq=64)

    log = EventLog()
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=1), log=log)
    eng = Engine(cfg, params, scfg, log=log, dispatcher=disp)
    rids = [eng.submit([1, 2, 3, 4], max_new=4) for _ in range(3)]
    res = eng.run_to_completion()

    eng2 = Engine(cfg, params, scfg, log=EventLog())
    rids2 = [eng2.submit([1, 2, 3, 4], max_new=4) for _ in range(3)]
    res2 = eng2.run_to_completion()

    assert sorted(map(tuple, res.values())) == sorted(map(tuple, res2.values()))
    # decisions were made and recorded for both compiled surfaces
    events = log.events(kind="dispatch")
    assert {e.payload["op"] for e in events} >= {"serve_prefill", "serve_decode"}
    assert disp.summary()["decisions"] == len(events)
