"""Data pipeline: determinism, resumability, sharding, learnability."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def test_deterministic_indexing():
    a = SyntheticLM(DataConfig(1000, 64, 8, seed=1))
    b = SyntheticLM(DataConfig(1000, 64, 8, seed=1))
    for i in (0, 3, 17):
        np.testing.assert_array_equal(a.batch(i)["tokens"], b.batch(i)["tokens"])


def test_seed_changes_data():
    a = SyntheticLM(DataConfig(1000, 64, 8, seed=1))
    b = SyntheticLM(DataConfig(1000, 64, 8, seed=2))
    assert not np.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])


def test_resume_equals_continuous():
    ds = SyntheticLM(DataConfig(500, 32, 4, seed=7))
    run = [ds.batch(i)["tokens"] for i in range(6)]
    it = ds.iterate(start=3)
    resumed = [next(it)["tokens"] for _ in range(3)]
    for a, b in zip(run[3:], resumed):
        np.testing.assert_array_equal(a, b)


def test_shards_are_disjoint_and_cover_batch():
    full = DataConfig(500, 32, 8, seed=9, n_shards=1, shard=0)
    s0 = DataConfig(500, 32, 8, seed=9, n_shards=2, shard=0)
    s1 = DataConfig(500, 32, 8, seed=9, n_shards=2, shard=1)
    b0, b1 = SyntheticLM(s0).batch(0)["tokens"], SyntheticLM(s1).batch(0)["tokens"]
    assert b0.shape == (4, 32) and b1.shape == (4, 32)
    assert not np.array_equal(b0, b1)


def test_labels_are_next_tokens():
    ds = SyntheticLM(DataConfig(500, 32, 4, seed=11))
    b = ds.batch(0)
    # labels[t] continues tokens: tokens[t+1] == labels[t] for t < S-1
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_structure_is_learnable():
    """Most transitions follow the affine chain (else CE could never fall)."""
    cfg = DataConfig(500, 256, 4, seed=13)
    ds = SyntheticLM(cfg)
    b = ds.batch(0)
    t, l = b["tokens"], b["labels"]
    pred = (t.astype(np.int64) * ds.mult + ds.add) % cfg.vocab_size
    frac = (pred == l).mean()
    assert frac > 0.85, frac
