"""Optimizer, schedule, microbatch accumulation, end-to-end loss descent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.training import optim
from repro.training.step import TrainConfig, init_train_state, make_train_step


def test_schedule_warmup_and_decay():
    opt = optim.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    lrs = [float(optim.schedule(opt, jnp.int32(s))) for s in (0, 5, 10, 60, 110, 200)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6 and abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0 and abs(lrs[4] - 0.1) < 1e-6 and abs(lrs[5] - 0.1) < 1e-6


def test_adamw_converges_quadratic():
    opt = optim.AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = optim.init_opt_state(params, opt)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = optim.adamw_update(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_bf16_moments_storage():
    opt = optim.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = optim.init_opt_state(params, opt)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    params2, state2, _ = optim.adamw_update(params, {"w": jnp.ones((4, 4))}, state, opt)
    assert state2["nu"]["w"].dtype == jnp.bfloat16
    assert params2["w"].dtype == params["w"].dtype


def test_grad_clip_metric():
    opt = optim.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = optim.init_opt_state(params, opt)
    _, _, m = optim.adamw_update(params, {"w": jnp.full(3, 100.0)}, state, opt)
    assert float(m["grad_norm"]) > 100.0


def test_microbatch_accumulation_matches_full_batch(key):
    cfg = reduced(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, z_loss_weight=0.0)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    t1 = TrainConfig(microbatches=1)
    t2 = TrainConfig(microbatches=2)
    s1 = init_train_state(cfg, t1, key)
    s2 = jax.tree.map(lambda x: x, s1)
    s1, m1 = jax.jit(make_train_step(cfg, t1))(s1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, t2))(s2, batch)
    # same data, same init -> (near-)identical updated params
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2, rtol=2e-2
        )


def test_loss_descends_20_steps(key):
    from repro.data.pipeline import DataConfig, SyntheticLM

    cfg = reduced(get_config("qwen2-0.5b"))
    tcfg = TrainConfig(opt=optim.AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=100))
    state = init_train_state(cfg, tcfg, key)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=3))
    losses = []
    for i in range(20):
        b = data.batch(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
