"""repro.tune: design-space enumeration, roofline pruning, parallel sweeps,
config-point profile keys, and the fleet round-trip of tuned winners."""
import dataclasses
import json

import pytest

from repro.dispatch.profiles import (
    ProfileStore,
    decode_config,
    encode_config,
    parse_profile_key,
    profile_key,
)
from repro.hw.specs import TPU_V5E, default_chip
from repro.tune import (
    Explorer,
    RooflinePruner,
    SweepSettings,
    apply_winners,
    default_spaces,
    winners_from_store,
)

SCAN_OPS = ["rwkv6_scan", "mamba_scan"]


# ---------------------------------------------------------------------------
# Profile keys: config points + separator escaping (regression)
# ---------------------------------------------------------------------------


def test_profile_key_round_trips_config():
    key = profile_key("op", "be", "f32[4]", "block_k=128,chunk=32")
    assert parse_profile_key(key) == ("op", "be", "f32[4]", "block_k=128,chunk=32")


def test_legacy_three_field_keys_parse_with_empty_config():
    assert parse_profile_key("op|be|f32[4]") == ("op", "be", "f32[4]", "")
    # and an empty config emits the byte-identical legacy key
    assert profile_key("op", "be", "f32[4]", "") == "op|be|f32[4]"


def test_key_separator_cannot_alias_fields():
    """A sig containing the separator must not collide with a (sig, config)
    pair — the crafted-aliasing regression the escaping exists for."""
    crafted = profile_key("op", "be", "sig|x=1")
    honest = profile_key("op", "be", "sig", "x=1")
    assert crafted != honest
    assert parse_profile_key(crafted) == ("op", "be", "sig|x=1", "")
    assert parse_profile_key(honest) == ("op", "be", "sig", "x=1")
    # escape metacharacters themselves survive the round trip
    weird = profile_key("op", "be", "100%|done", "a=%7C")
    assert parse_profile_key(weird) == ("op", "be", "100%|done", "a=%7C")


def test_encode_decode_config_round_trip():
    params = {"block_k": 128, "ratio": 0.5, "mode": "fast"}
    config = encode_config(params)
    assert config == "block_k=128,mode=fast,ratio=0.5"  # sorted, stable
    assert decode_config(config) == params
    assert encode_config({}) == ""
    assert decode_config("") == {}


# ---------------------------------------------------------------------------
# ProfileStore: config points coexist, argmin, JSON round trip
# ---------------------------------------------------------------------------


def _fill(store, op="op", be="be", sig="s"):
    for x in (3e-3, 3e-3):
        store.record(op, be, sig, x)  # default point
    for x in (1e-3, 1e-3):
        store.record(op, be, sig, x, config="chunk=64")
    for x in (2e-3, 2e-3):
        store.record(op, be, sig, x, config="chunk=16")


def test_store_config_points_and_best_config():
    store = ProfileStore(min_samples=2)
    _fill(store)
    points = store.config_points("op", "be", "s")
    assert set(points) == {"", "chunk=64", "chunk=16"}
    config, best_s = store.best_config("op", "be", "s")
    assert config == "chunk=64"
    assert best_s == pytest.approx(1e-3)
    # the default ("") competes on equal terms: make it fastest and it wins
    store.record("op", "be", "s", 1e-5)
    store.record("op", "be", "s", 1e-5)
    assert store.best_config("op", "be", "s")[0] == ""


def test_store_json_round_trip_preserves_config_keys():
    store = ProfileStore(min_samples=2)
    _fill(store)
    back = ProfileStore.from_json(store.to_json())
    assert set(back.config_points("op", "be", "s")) == {"", "chunk=64", "chunk=16"}
    assert back.best_config("op", "be", "s")[0] == "chunk=64"
    # merge keeps config points distinct
    other = ProfileStore()
    other.record("op", "be", "s", 5e-4, config="chunk=64")
    back.merge(other)
    assert back.entry("op", "be", "s", "chunk=64").count == 3


# ---------------------------------------------------------------------------
# Design spaces: constraint-aware enumeration
# ---------------------------------------------------------------------------


def test_enumeration_respects_alignment():
    spaces = default_spaces()
    flash = spaces["flash_attention/pallas"]
    for p in flash.points():
        assert p.params["block_q"] % 128 == 0 and p.params["block_k"] % 128 == 0
    chunked = spaces["flash_attention/chunked"]
    for p in chunked.points():
        assert p.params["block_k"] % 8 == 0


def test_enumeration_respects_divisibility():
    for key in ("rwkv6_scan/chunked", "mamba_scan/chunked"):
        space = default_spaces()[key]
        T = space.workload["T"]
        for p in space.points():
            assert T % min(p.params["chunk"], T) == 0
    # a workload the grid can't tile drops the non-dividing points; values
    # past T clamp to full-T (min(chunk, T)) and so stay feasible
    space = default_spaces()["rwkv6_scan/chunked"]
    odd = dataclasses.replace(space, workload={**space.workload, "T": 24})
    chunks = {p.params["chunk"] for p in odd.points()}
    assert 16 not in chunks  # 24 % 16 != 0
    assert 8 in chunks  # 24 % 8 == 0
    assert 64 in chunks and 128 in chunks  # clamp to T=24, which tiles


def test_enumeration_respects_vmem_budget():
    space = default_spaces()["flash_attention/pallas"]
    full = {p.config for p in space.points(TPU_V5E)}
    # a chip with almost no VMEM rejects every grid point; the hand-picked
    # default is still enumerated (known-good escape hatch)
    tiny = dataclasses.replace(TPU_V5E, vmem_bytes=64 << 10)
    survivors = space.points(tiny)
    assert len(survivors) < len(full)
    assert [p.config for p in survivors] == [space.default_config]


def test_points_deterministic_order_and_include_default():
    for space in default_spaces().values():
        a = [p.config for p in space.points()]
        b = [p.config for p in space.points()]
        assert a == b
        assert space.default_config in a


def test_synthetic_surface_deterministic_and_bounded():
    space = default_spaces()["mamba_scan/chunked"]
    for p in space.points():
        s1, s2 = space.synthetic_s(p.params), space.synthetic_s(p.params)
        assert s1 == s2
        roof = space.roofline_s(p.params)
        assert roof <= s1 <= roof * 1.05


# ---------------------------------------------------------------------------
# Pruner: never cuts the default, never cuts the measured-best
# ---------------------------------------------------------------------------


def test_pruner_never_drops_default_even_at_ratio_one():
    for space in default_spaces().values():
        kept, cut = RooflinePruner(ratio=1.0).prune(space, space.points())
        assert any(p.config == space.default_config for p in kept)
        # ratio 1.0 is maximally aggressive: only the bound point(s) + default
        assert len(kept) < len(space.points()) or len(space.points()) <= 2


def test_pruner_keeps_synthetic_best_at_default_ratio():
    """The measured-best on the synthetic surface must survive pruning: the
    jitter is <=5% while the ratio allows 4x, so a pruned-away winner would
    mean the model and the surface disagree structurally."""
    for space in default_spaces().values():
        points = space.points()
        best = min(points, key=lambda p: space.synthetic_s(p.params))
        kept, _ = RooflinePruner().prune(space, points)
        assert best.config in {p.config for p in kept}, space.key


def test_pruner_validates_ratio_and_handles_empty():
    with pytest.raises(ValueError):
        RooflinePruner(ratio=0.5)
    kept, cut = RooflinePruner().prune(
        default_spaces()["mamba_scan/chunked"], [])
    assert kept == [] and cut == []


# ---------------------------------------------------------------------------
# Explorer: deterministic sweeps, warm skip, events, winners
# ---------------------------------------------------------------------------


def _sweep(store, workers=0, ops=SCAN_OPS, log=None):
    from repro.core.events import EventLog

    explorer = Explorer(
        # `is not None`: an empty EventLog is falsy (len 0) but still the
        # caller's log
        store, log=log if log is not None else EventLog(),
        settings=SweepSettings(mode="synthetic", workers=workers),
    )
    return explorer.sweep(ops)


def test_synthetic_sweep_deterministic_across_worker_counts():
    s0, s2 = ProfileStore(), ProfileStore()
    r0 = _sweep(s0, workers=0)
    r2 = _sweep(s2, workers=2)
    assert r0["sweep_points"] == r2["sweep_points"] > 0
    assert json.loads(s0.to_json()) == json.loads(s2.to_json())
    assert r0["winners"] == r2["winners"]


def test_sweep_skips_warm_points_second_time():
    store = ProfileStore()
    r1 = _sweep(store)
    assert r1["sweep_points"] > 0 and r1["skipped_warm"] == 0
    r2 = _sweep(store)
    assert r2["sweep_points"] == 0
    assert r2["skipped_warm"] == r1["sweep_points"]


def test_sweep_emits_tune_events_under_tune_run_span():
    from repro.core.events import EventLog

    log = EventLog()
    store = ProfileStore()
    summary = _sweep(store, log=log)
    assert summary["pruned"] >= 1
    tune_events = [e for e in log.events(kind="tune")]
    pruned = [e for e in tune_events if e.payload.get("pruned") is True]
    measured = [e for e in tune_events if e.payload.get("pruned") is False]
    winners = [e for e in tune_events if e.payload.get("winner")]
    assert len(pruned) == summary["pruned"]
    assert len(measured) == summary["sweep_points"]
    assert len(winners) == len(summary["winners"]) == 2
    roots = [e for e in log.events(name="tune_run")]
    assert len(roots) == 2  # lifecycle enter/exit bracket


def test_winner_speedup_never_below_one():
    summary = _sweep(ProfileStore())
    for win in summary["winners"].values():
        assert win["speedup"] >= 1.0
        assert win["best_s"] <= win["default_s"]


def test_winners_from_store_apply_and_clear():
    from repro.kernels import ops

    store = ProfileStore()
    _sweep(store)
    table, details = winners_from_store(store)
    assert set(details) == {"rwkv6_scan/chunked", "mamba_scan/chunked"}
    try:
        applied = apply_winners(table)
        assert applied == sum(len(v) for v in table.values())
        for op, impls in table.items():
            for impl, params in impls.items():
                assert ops.tuned_overrides(op, impl) == dict(params)
                assert ops.active_config(op, impl) == encode_config(params)
    finally:
        ops.clear_tuned_configs()
    assert ops.tuned_overrides("rwkv6_scan", "chunked") == {}


def test_default_winner_contributes_no_override():
    """A store where the hand-picked default wins produces an empty table —
    nothing to override, nothing to apply."""
    space = default_spaces()["mamba_scan/chunked"]
    store = ProfileStore(min_samples=2)
    for x in (1e-4, 1e-4):
        store.record(space.op, space.backend, space.sig, x)  # default: fastest
    for x in (5e-4, 5e-4):
        store.record(space.op, space.backend, space.sig, x, config="chunk=64")
    table, details = winners_from_store(store)
    assert table == {}
    assert details["mamba_scan/chunked"]["config"] == ""


# ---------------------------------------------------------------------------
# Fleet round trip: tuned config points survive push/pull
# ---------------------------------------------------------------------------


def test_tuned_store_round_trips_through_fleet(tmp_path):
    from repro.fleet import FleetClient

    store = ProfileStore()
    _sweep(store)
    store.set_stamp(git_sha="sha1", chip="chipA")
    client = FleetClient(str(tmp_path / "fleet"))
    client.push(store, "sha1", "chipA")
    pulled = client.pull("sha1", "chipA")
    assert pulled["match"] == "exact"
    remote = pulled["store"]
    for key in ("rwkv6_scan/chunked", "mamba_scan/chunked"):
        space = default_spaces()[key]
        assert (remote.best_config(space.op, space.backend, space.sig)
                == store.best_config(space.op, space.backend, space.sig))
    # and the pulled store yields the same override table
    assert winners_from_store(remote)[0] == winners_from_store(store)[0]


# ---------------------------------------------------------------------------
# Consumer side: ops override table, dispatcher config keying, metrics
# ---------------------------------------------------------------------------


def test_scan_chunk_guard_rejects_non_dividing_tuned_value():
    from repro.kernels import ops

    try:
        ops.set_tuned_configs({"mamba_scan": {"chunked": {"chunk": 64}}})
        assert ops._scan_chunk("mamba_scan", "chunked", 128, 256) == 64
        # T=100 is not divisible by 64: fall back to the caller's chunk
        assert ops._scan_chunk("mamba_scan", "chunked", 128, 100) == 128
        # untuned (op, impl) passes the caller's value through
        assert ops._scan_chunk("rwkv6_scan", "chunked", 32, 256) == 32
    finally:
        ops.clear_tuned_configs()


def test_tuned_scope_restores_previous_table():
    from repro.kernels import ops

    ops.set_tuned_configs({"mamba_scan": {"chunked": {"chunk": 32}}})
    try:
        with ops.tuned_scope({"mamba_scan": {"chunked": {"chunk": 64}}}):
            assert ops.tuned_overrides("mamba_scan", "chunked") == {"chunk": 64}
        assert ops.tuned_overrides("mamba_scan", "chunked") == {"chunk": 32}
    finally:
        ops.clear_tuned_configs()


def test_dispatch_decision_payload_omits_empty_config():
    from repro.dispatch.dispatcher import DispatchDecision

    bare = DispatchDecision("op", "be", "s", 1e-3, "static", "static")
    assert "config" not in bare.payload()
    tuned = dataclasses.replace(bare, config="chunk=64")
    assert tuned.payload()["config"] == "chunk=64"


def test_dispatcher_keys_samples_by_active_config():
    from repro.dispatch import DispatchConfig, Dispatcher

    d = Dispatcher(DispatchConfig(policy="profiled", record_events=False))
    variants = {b: (lambda x: x) for b in d.backends()}
    configs = {b: "chunk=64" for b in d.backends()}
    d.dispatch("op", variants, 1.0, sig="s", configs=configs)
    used = d.decisions[-1].backend
    assert d.decisions[-1].config == "chunk=64"
    assert d.store.samples("op", used, "s", "chunk=64") == 1
    assert d.store.samples("op", used, "s") == 0  # default bucket untouched


def test_metrics_sink_derives_tune_series():
    from repro.metrics import MetricsPlane
    from repro.trace import TraceCollector

    log = TraceCollector()
    plane = MetricsPlane(log)
    _sweep(ProfileStore(), log=log)
    text = plane.registry.render()
    assert 'repro_tune_points_total{op="mamba_scan",pruned="true"}' in text
    assert 'repro_tune_points_total{op="mamba_scan",pruned="false"}' in text
    assert 'repro_tune_best_speedup{op="rwkv6_scan"}' in text


def test_driver_tune_cached_applies_without_sweeping():
    from repro.dispatch import DispatchConfig, Dispatcher
    from repro.kernels import ops
    from repro.tune import driver_tune

    d = Dispatcher(DispatchConfig(policy="profiled"))
    _sweep(d.store)  # pretend a previous run / fleet pull filled the store
    try:
        rec = driver_tune("cached", d, d.log)
        assert rec["sweep_points"] == 0 and "winners" not in rec
        assert rec["applied"] >= 1
        for op, impls in rec["configs"].items():
            for impl, config in impls.items():
                assert ops.active_config(op, impl) == config
    finally:
        ops.clear_tuned_configs()
