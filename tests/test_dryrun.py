"""Multi-pod dry-run smoke (subprocess: needs 512 placeholder devices, which
must not leak into this test process).  The full 40-cell sweep is run by
benchmarks/roofline_table.py; here one train cell + one decode cell + one
multi-pod cell prove the machinery end-to-end."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dryrun(*args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT,
    )
    lines = [l for l in out.stdout.splitlines() if l.startswith("{")]
    assert lines, f"no records: stdout={out.stdout[-2000:]} stderr={out.stderr[-2000:]}"
    return [json.loads(l) for l in lines], out.returncode


@pytest.mark.slow
def test_single_pod_train_cell():
    recs, rc = run_dryrun("--arch", "qwen2-0.5b", "--shape", "train_4k")
    assert rc == 0
    r = recs[0]
    assert r["status"] == "ok" and r["n_devices"] == 256 and r["step"] == "train_step"
    assert r["hlo_flops_per_dev"] > 0 and r["collective_bytes_per_dev"] > 0
    assert r["bottleneck"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_single_pod_decode_cell():
    recs, rc = run_dryrun("--arch", "qwen2-0.5b", "--shape", "decode_32k")
    assert rc == 0 and recs[0]["status"] == "ok" and recs[0]["step"] == "serve_step"


@pytest.mark.slow
def test_multi_pod_cell():
    recs, rc = run_dryrun("--arch", "qwen2-0.5b", "--shape", "train_4k", "--multi-pod")
    assert rc == 0
    r = recs[0]
    assert r["status"] == "ok" and r["n_devices"] == 512 and r["mesh"] == "2x16x16"


@pytest.mark.slow
def test_long_500k_skip_for_pure_attention():
    recs, rc = run_dryrun("--arch", "qwen2-0.5b", "--shape", "long_500k")
    assert rc == 0
    assert recs[0]["status"] == "skip" and "full-attention" in recs[0]["reason"]
