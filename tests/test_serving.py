"""Serving engine: continuous batching, slot reuse, engine-vs-direct parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.events import EventLog
from repro.models import lm
from repro.serving.engine import Engine, ServeConfig


def _setup(key, arch="smollm-360m", **scfg_kw):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, key)
    scfg = ServeConfig(**{"max_batch": 2, "max_seq": 64, **scfg_kw})
    log = EventLog()
    return cfg, params, Engine(cfg, params, scfg, log=log), log


def test_continuous_batching_more_requests_than_slots(key):
    cfg, params, eng, log = _setup(key)
    rids = [eng.submit([1, 2, 3, 4], max_new=5) for _ in range(5)]
    res = eng.run_to_completion()
    assert set(res) == set(rids)
    assert all(len(v) == 5 for v in res.values())
    # lifecycle: every request spawned and exited
    assert len(log.events("spawn", "request")) == 5
    assert len(log.events("exit", "request")) == 5


def test_identical_prompts_identical_outputs(key):
    """Slot reuse must not leak state between requests (greedy decoding)."""
    cfg, params, eng, _ = _setup(key)
    rids = [eng.submit([5, 6, 7, 8], max_new=6) for _ in range(4)]
    res = eng.run_to_completion()
    outs = [tuple(res[r]) for r in rids]
    assert len(set(outs)) == 1, outs


def test_engine_matches_direct_decode(key):
    """Engine output == hand-rolled prefill+greedy-decode loop."""
    cfg, params, eng, _ = _setup(key)
    prompt = [3, 1, 4, 1, 5, 9]
    rid = eng.submit(list(prompt), max_new=5)
    res = eng.run_to_completion()

    logits, caches = lm.prefill(params, cfg, jnp.asarray([prompt], jnp.int32), max_seq=64)
    toks = [int(jnp.argmax(logits[0]))]
    cur = len(prompt)
    for _ in range(4):
        logits, caches = lm.decode_step(
            params, cfg, jnp.asarray([toks[-1]], jnp.int32), jnp.asarray([cur], jnp.int32), caches
        )
        toks.append(int(jnp.argmax(logits[0])))
        cur += 1
    assert res[rid] == toks, (res[rid], toks)


def test_max_seq_bound_respected(key):
    cfg, params, eng, _ = _setup(key, max_seq=16)
    rid = eng.submit([1] * 8, max_new=100)
    res = eng.run_to_completion()
    assert len(res[rid]) < 16
