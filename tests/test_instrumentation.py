"""The paper's core claims as tests: USDT (tracepoints) + Uprobes semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import microbench
from repro.core import tracepoints as tp
from repro.core import uprobes
from repro.core.events import EventLog


# ---------------------------------------------------------------------------
# USDT: static tracepoints
# ---------------------------------------------------------------------------


def test_disabled_tracepoints_compile_away():
    """USDT's defining property (stronger than a nop sled): with tracing off,
    the instrumented program lowers to *byte-identical* HLO."""
    x = microbench.make_inputs()

    def approx_sqrt_workload(x):  # same name -> same HLO module name
        def step(g, _):
            return 0.5 * (g + x / g), None

        g = jnp.maximum(x * 0.5, 1.0)
        g, _ = jax.lax.scan(step, g, None, length=microbench.NEWTON_ITERS)
        return g

    hlo_plain = jax.jit(approx_sqrt_workload).lower(x).as_text()
    hlo_inst = jax.jit(microbench.approx_sqrt_workload).lower(x).as_text()

    def strip_meta(s):  # location metadata differs trivially
        import re
        return re.sub(r'loc\(.*?\)|metadata=\{[^}]*\}|#loc\d+ = .*', "", s)

    assert strip_meta(hlo_inst) == strip_meta(hlo_plain)


def test_tape_mode_collects_points():
    x = microbench.make_inputs()
    with tp.enable("tape"):
        fn = jax.jit(tp.collect(microbench.approx_sqrt_workload))
        out, tape = fn(x)
    assert set(tape) == {"workload.enter", "workload.exit"}
    val, fires = tape["workload.enter"]
    assert float(val) == x.shape[0] and int(fires) == 1
    np.testing.assert_allclose(out, jnp.sqrt(x), rtol=1e-4)


def test_tape_agg_modes():
    with tp.enable("tape"):

        @tp.collect
        def f(x):
            for i in range(3):
                tp.point("acc", x * (i + 1), agg="sum")
                tp.point("peak", x * (i + 1), agg="max")
                tp.point("hits", None)
            return x

        _, tape = jax.jit(f)(jnp.float32(2.0))
    assert float(tape["acc"][0]) == 2.0 + 4.0 + 6.0
    assert float(tape["peak"][0]) == 6.0
    assert int(tape["hits"][0]) == 3


def test_callback_mode_records_events():
    log = EventLog()
    x = microbench.make_inputs()
    with tp.enable("callback", log=log):
        # fresh lambda: jax.jit memoizes wrappers per function object, and the
        # uninstrumented trace from another test must not be reused (USDT
        # markers are compiled in at trace time).
        fn = jax.jit(lambda v: microbench.approx_sqrt_workload(v))
        jax.block_until_ready(fn(x))
    jax.effects_barrier()
    names = {e.name for e in log.events("probe")}
    assert names == {"workload.enter", "workload.exit"}


def test_disabled_is_noop_outside_context():
    log = EventLog()
    fn = jax.jit(microbench.approx_sqrt_workload)
    jax.block_until_ready(fn(microbench.make_inputs()))
    jax.effects_barrier()
    assert len(log) == 0


# ---------------------------------------------------------------------------
# Uprobes: dynamic probes, no source change
# ---------------------------------------------------------------------------


def test_attach_detach_module_function():
    from repro.configs import microbench as mb_module

    log = EventLog()
    reg = uprobes.ProbeRegistry(log)
    reg.attach(mb_module, "approx_sqrt_workload", tap_output=True)
    try:
        fn = jax.jit(mb_module.approx_sqrt_workload)
        out = fn(mb_module.make_inputs())
        jax.block_until_ready(out)
        jax.effects_barrier()
    finally:
        reg.detach_all()
    names = [e.name for e in log.events("probe")]
    assert any(n.endswith(":enter") for n in names)
    assert any(n.endswith(":ret") for n in names)
    assert any(n.endswith(":exit") for n in names)
    # detached: original restored
    assert not getattr(mb_module.approx_sqrt_workload, "__repro_probe__", False)


def test_inject_probes_preserves_output_and_taps():
    x = microbench.make_inputs()
    want = jax.jit(microbench.approx_sqrt_workload)(x)
    probed = uprobes.inject_probes(
        microbench.approx_sqrt_workload, uprobes.by_primitive("scan"), mode="tap"
    )
    got, taps = probed(x)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert len(taps) >= 1 and all(k.startswith("scan#") for k in taps)


def test_inject_probes_callback_events():
    log = EventLog()
    probed = uprobes.inject_probes(
        microbench.approx_sqrt_workload,
        uprobes.by_primitive("scan"),
        mode="callback",
        log=log,
    )
    fn = jax.jit(probed)
    jax.block_until_ready(fn(microbench.make_inputs()))
    jax.effects_barrier()
    assert len(log.events("probe")) >= 1


def test_by_scope_matcher():
    def f(x):
        with jax.named_scope("hot"):
            y = x @ x
        return y + 1

    x = jnp.ones((8, 8))
    probed = uprobes.inject_probes(f, uprobes.by_scope("hot"), mode="tap")
    out, taps = probed(x)
    np.testing.assert_allclose(out, x @ x + 1)
    assert any("dot_general" in k for k in taps)
