"""repro.metrics: registry primitives, trace→metrics sink, sampling gate,
adaptive controller, HTTP exposition, streaming-session round trips."""
import bisect
import json
import math
import random
import urllib.request

import pytest

from repro.core.overhead import stats_from_samples
from repro.metrics import (
    DEFAULT_BUCKETS_MS,
    AdaptiveController,
    Histogram,
    MetricsPlane,
    MetricsRegistry,
    serve_metrics,
)
from repro.trace.collector import TraceCollector, resolve_spans
from repro.trace.stream import StreamingSession, load_metrics_timeline


# ---------------------------------------------------------------------------
# Counter / Gauge / registry
# ---------------------------------------------------------------------------


def test_counter_monotonic_and_gauge_free():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(7)
    g.dec(2)
    assert g.value == 5.0


def test_registry_get_or_create_and_label_series():
    reg = MetricsRegistry()
    assert reg.counter("x_total") is reg.counter("x_total")
    a = reg.counter("y_total", backend="ref")
    b = reg.counter("y_total", backend="chunked")
    assert a is not b
    a.inc()
    assert b.value == 0
    # label order must not create distinct series
    assert reg.counter("z", a="1", b="2") is reg.counter("z", b="2", a="1")


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")
    with pytest.raises(TypeError):
        reg.histogram("m")


# ---------------------------------------------------------------------------
# Histogram: quantile error bounds, merge algebra, snapshot round trip
# ---------------------------------------------------------------------------


def _bucket_width(bounds, x, lo_obs, hi_obs):
    """Width of the bucket containing x — the quantile's error bound."""
    i = bisect.bisect_left(bounds, x)
    lo = bounds[i - 1] if i > 0 else min(0.0, lo_obs)
    hi = bounds[i] if i < len(bounds) else hi_obs
    return hi - lo


@pytest.mark.parametrize("dist", ["uniform", "lognormal", "bimodal"])
def test_quantile_error_bounded_by_bucket_width(dist):
    rng = random.Random(0)
    if dist == "uniform":
        samples = [rng.uniform(0.05, 80.0) for _ in range(4000)]
    elif dist == "lognormal":
        samples = [math.exp(rng.gauss(0.0, 1.5)) for _ in range(4000)]
    else:
        samples = [rng.gauss(0.3, 0.05) for _ in range(2000)] + \
                  [rng.gauss(200.0, 20.0) for _ in range(2000)]
        samples = [max(s, 1e-3) for s in samples]
    h = Histogram("h_ms", {})
    for s in samples:
        h.observe(s)
    ordered = sorted(samples)
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = ordered[min(len(ordered) - 1, int(q * len(ordered)))]
        est = h.quantile(q)
        width = _bucket_width(h.bounds, exact, min(samples), max(samples))
        assert abs(est - exact) <= width + 1e-9, (q, est, exact, width)
        assert min(samples) <= est <= max(samples)


def test_quantile_edges():
    h = Histogram("h", {})
    assert h.quantile(0.5) is None
    h.observe(3.0)
    assert h.quantile(0.0) == h.quantile(1.0) == 3.0
    with pytest.raises(ValueError):
        h.quantile(1.5)
    # overflow bucket: observations beyond the last bound clamp to max
    h2 = Histogram("h2", {}, bounds=(1.0, 2.0))
    h2.observe(50.0)
    h2.observe(70.0)
    assert h2.quantile(0.99) <= 70.0


def _hist_from(samples, name="h"):
    h = Histogram(name, {})
    for s in samples:
        h.observe(s)
    return h


def _key(h):
    s = h.snapshot()
    return (s["counts"], s["count"], s["sum"], s["min"], s["max"])


def test_merge_commutative_and_associative():
    rng = random.Random(1)
    sa = [rng.uniform(0.01, 10) for _ in range(300)]
    sb = [rng.uniform(5, 500) for _ in range(200)]
    sc = [rng.uniform(0.001, 0.1) for _ in range(100)]
    ab = _hist_from(sa).merge(_hist_from(sb))
    ba = _hist_from(sb).merge(_hist_from(sa))
    assert _key(ab) == _key(ba)
    ab_c = _hist_from(sa).merge(_hist_from(sb)).merge(_hist_from(sc))
    a_bc = _hist_from(sa).merge(_hist_from(sb).merge(_hist_from(sc)))
    assert _key(ab_c) == _key(a_bc)
    # the merge equals observing the concatenation (sum up to float
    # addition order)
    cat = _hist_from(sa + sb + sc)
    assert _key(ab_c)[:2] == _key(cat)[:2]
    assert ab_c.sum == pytest.approx(cat.sum)
    assert _key(ab_c)[3:] == _key(cat)[3:]


def test_merge_rejects_mismatched_bounds():
    a = Histogram("a", {}, bounds=(1.0, 2.0))
    b = Histogram("b", {}, bounds=(1.0, 3.0))
    with pytest.raises(ValueError):
        a.merge(b)


def test_histogram_snapshot_json_round_trip():
    h = _hist_from([0.02, 0.4, 3.3, 900.0, 45000.0])
    snap = json.loads(json.dumps(h.snapshot()))
    back = Histogram.from_snapshot(snap)
    assert _key(back) == _key(h)
    for q in (0.5, 0.95, 0.99):
        assert back.quantile(q) == h.quantile(q)


def test_invalid_bounds_rejected():
    with pytest.raises(ValueError):
        Histogram("h", {}, bounds=())
    with pytest.raises(ValueError):
        Histogram("h", {}, bounds=(1.0, 1.0))


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def test_render_prometheus_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", backend="ref").inc(3)
    reg.counter("req_total", "requests", backend="chunked").inc(1)
    reg.gauge("depth", "queue depth").set(2)
    h = reg.histogram("lat_ms", "latency", bounds=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    text = reg.render()
    # one TYPE block per metric name, even with several labelled series
    assert text.count("# TYPE req_total counter") == 1
    assert '# TYPE depth gauge' in text and "# TYPE lat_ms histogram" in text
    assert 'req_total{backend="chunked"} 1' in text
    assert 'req_total{backend="ref"} 3' in text
    # buckets are cumulative and +Inf equals the count
    assert 'lat_ms_bucket{le="1"} 1' in text
    assert 'lat_ms_bucket{le="10"} 2' in text
    assert 'lat_ms_bucket{le="+Inf"} 3' in text
    assert "lat_ms_count 3" in text and "lat_ms_sum 55.5" in text


# ---------------------------------------------------------------------------
# Trace → metrics sink
# ---------------------------------------------------------------------------


def test_sink_counts_lifecycle_dispatch_straggler():
    log = TraceCollector()
    plane = MetricsPlane(log)
    for i in range(3):
        with log.lifecycle("request", i):
            pass
    log.record("dispatch", "op_a",
               {"backend": "ref", "source": "measured", "measured_s": 0.002})
    log.record("straggler", "step", {"step": 4})
    s = plane.summary()
    assert s["repro_requests_total"] == 3
    assert s["repro_request_ms_count"] == 3
    assert s["repro_dispatch_total{backend=ref,op=op_a,source=measured}"] == 1
    assert s["repro_dispatch_ms_count{backend=ref,op=op_a}"] == 1
    assert s["repro_stragglers_total"] == 1
    assert s["repro_trace_events_total{kind=spawn}"] == 3
    assert s["repro_trace_events_total{kind=exit}"] == 3
    # durations measured by the sink are real (ms-scale, non-negative)
    hists = [m for m in plane.registry.metrics() if m.name == "repro_request_ms"]
    assert hists and hists[0].sum >= 0


def test_plane_requires_sink_fanout():
    from repro.core.events import EventLog

    with pytest.raises(TypeError):
        MetricsPlane(EventLog())


# ---------------------------------------------------------------------------
# Sampling gate (collector side)
# ---------------------------------------------------------------------------


def test_shedding_keeps_metrics_exact_and_pairing_consistent():
    log = TraceCollector()
    plane = MetricsPlane(log)
    log.set_sample_rate(0.0)
    for i in range(20):
        with log.lifecycle("request", i):
            pass
    captured = log.events()
    assert not [e for e in captured if e.name == "request"]  # all shed
    assert log.drop_counters()["sampled_out"] == 40
    # no torn pairs: every captured spawn has its exit
    assert not [s for s in resolve_spans(captured) if s.truncated]
    # the metrics plane saw every event regardless
    s = plane.summary()
    assert s["repro_requests_total"] == 20
    assert s["repro_request_ms_count"] == 20


def test_essential_tracks_never_shed():
    log = TraceCollector()
    log.set_sample_rate(0.0)
    with log.lifecycle("serve_run", 0):
        with log.lifecycle("checkpoint", 1):
            pass
        log.record("dispatch", "op", {"backend": "ref"})
        log.record("mark", "controller", {"rate": 0.5})
        log.record("device", "k", {"device": "tpu0"})
    names = [e.name for e in log.events()]
    assert names.count("serve_run") == 2
    assert names.count("checkpoint") == 2
    assert "op" in names and "controller" in names and "k" in names


def test_captured_spawn_exit_always_passes():
    log = TraceCollector()
    from repro.core.events import next_span_id

    span = next_span_id()
    log.record("spawn", "request", 1, span=span)  # captured at rate 1.0
    log.set_sample_rate(0.0)
    log.record("exit", "request", 1, span=span)
    kinds = [e.kind for e in log.events() if e.name == "request"]
    assert kinds == ["spawn", "exit"]  # the pair survives the rate drop


def test_suppressed_spawn_suppresses_matching_exit():
    log = TraceCollector()
    from repro.core.events import next_span_id

    span = next_span_id()
    log.set_sample_rate(0.0)
    log.record("spawn", "request", 1, span=span)  # shed
    log.set_sample_rate(1.0)
    log.record("exit", "request", 1, span=span)  # must be shed too
    assert not [e for e in log.events() if e.name == "request"]
    assert log.drop_counters()["sampled_out"] == 2


def test_timing_snapshot_reads_and_resets():
    log = TraceCollector()
    for i in range(10):
        log.record("mark", "m", i)
    snap = log.timing_snapshot()
    assert snap["records"] == 10 and snap["timed"] >= 1
    assert snap["timed_s"] > 0
    again = log.timing_snapshot()
    assert again["records"] == 0 and again["timed"] == 0


def test_broken_extra_sink_detaches_without_killing_record(capsys):
    log = TraceCollector()
    seen = []

    def bad(e):
        seen.append(e)
        raise RuntimeError("boom")

    log.add_sink(bad)
    log.record("mark", "a", 0)
    log.record("mark", "b", 1)  # sink already detached
    assert len(seen) == 1
    assert len(log.events()) == 2
    assert "boom" in (log.stats()["sink_error"] or "")


# ---------------------------------------------------------------------------
# Adaptive controller (deterministic, via a fake collector)
# ---------------------------------------------------------------------------


class _FakeCollector:
    def __init__(self):
        self.rate = 1.0
        self.records = []
        self._snap = {"timed": 0, "timed_s": 0.0, "records": 0}

    def feed(self, per_record_s, n, elapsed_hint=None):
        self._snap = {"timed": n, "timed_s": per_record_s * n, "records": n}

    def timing_snapshot(self):
        out, self._snap = self._snap, {"timed": 0, "timed_s": 0.0, "records": 0}
        return out

    def set_sample_rate(self, r):
        self.rate = r

    def record(self, kind, name, payload=None, **kw):
        self.records.append((kind, name, payload))


_NOOP = stats_from_samples("noop", [0.0001])  # 0.1 µs baseline, no calibration


def test_controller_sheds_under_synthetic_overhead():
    col = _FakeCollector()
    reg = MetricsRegistry()
    ctl = AdaptiveController(col, reg, budget_pct=5.0, smooth=1.0, noop=_NOOP)
    import time

    ctl._last_t = time.monotonic() - 1.0  # 1 s window
    col.feed(per_record_s=0.001, n=200)  # 200 ms tracing per second = 20%
    over = ctl.step()
    assert over > 5.0
    assert col.rate < 1.0 and ctl.adjustments == 1
    # the decision trail is a recorded controller event
    assert [r for r in col.records if r[1] == "controller"]
    assert reg.gauge("repro_trace_overhead_pct").value == round(over, 4)
    assert reg.gauge("repro_trace_sample_rate_target").value == col.rate


def test_controller_recovers_when_cheap():
    col = _FakeCollector()
    ctl = AdaptiveController(col, budget_pct=5.0, smooth=1.0, noop=_NOOP)
    import time

    ctl._last_t = time.monotonic() - 1.0
    col.feed(per_record_s=0.001, n=500)  # 50% overhead → hard shed
    ctl.step()
    shed = col.rate
    assert shed < 0.2
    for _ in range(12):  # cheap ticks → multiplicative recovery toward 1.0
        ctl._last_t = time.monotonic() - 1.0
        col.feed(per_record_s=0.000001, n=10)
        ctl.step()
    assert col.rate == 1.0 and ctl.adjustments >= 3


def test_controller_budget_zero_measures_but_never_sheds():
    col = _FakeCollector()
    ctl = AdaptiveController(col, budget_pct=0.0, smooth=1.0, noop=_NOOP)
    import time

    ctl._last_t = time.monotonic() - 1.0
    col.feed(per_record_s=0.01, n=100)  # 100% overhead
    over = ctl.step()
    assert over > 50.0
    assert col.rate == 1.0 and ctl.adjustments == 0


def test_controller_on_real_collector_records_start_event():
    log = TraceCollector()
    ctl = AdaptiveController(log, budget_pct=5.0, noop=_NOOP,
                             interval_s=0.01)
    ctl.start()
    ctl.stop()
    marks = [e for e in log.events() if e.name == "controller"]
    assert marks and marks[0].payload["budget_pct"] == 5.0
    snap = ctl.snapshot()
    assert set(snap) == {"budget_pct", "overhead_pct", "sample_rate",
                         "adjustments", "noop_ms"}


# ---------------------------------------------------------------------------
# HTTP exposition
# ---------------------------------------------------------------------------


def test_metrics_http_server_scrape():
    log = TraceCollector()
    plane = MetricsPlane(log)
    with log.lifecycle("request", 0):
        pass
    server = serve_metrics(plane, port=0)
    try:
        with urllib.request.urlopen(server.url + "/metrics") as r:
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/plain")
            text = r.read().decode()
        assert "repro_requests_total 1" in text
        assert "repro_trace_dropped_total 0" in text
        with urllib.request.urlopen(server.url + "/metrics.json") as r:
            doc = json.loads(r.read())
        assert any(m["name"] == "repro_requests_total" for m in doc["metrics"])
        with urllib.request.urlopen(server.url + "/healthz") as r:
            assert json.loads(r.read())["ok"] is True
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(server.url + "/nope")
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# Streaming-session round trip (per-rotation snapshots)
# ---------------------------------------------------------------------------


def test_stream_metrics_snapshots_round_trip(tmp_path):
    d = str(tmp_path / "run")
    log = TraceCollector()
    plane = MetricsPlane(log)
    stream = StreamingSession(d, rotate_events=4,
                              metrics_provider=plane.snapshot).attach(log)
    for i in range(10):
        with log.lifecycle("request", i):
            pass
    stream.close(stats=log.stats())

    timeline = load_metrics_timeline(d)
    assert len(timeline) >= 2  # rotations + the final snapshot
    assert timeline[-1]["segment"] == "final"
    final = timeline[-1]["metrics"]
    snap = next(m for m in final["metrics"] if m["name"] == "repro_request_ms")
    live = next(m for m in plane.registry.metrics()
                if m.name == "repro_request_ms")
    # count/sum consistency: rebuilt histogram == the live one
    back = Histogram.from_snapshot(snap)
    assert back.count == live.count == 10
    assert back.sum == pytest.approx(live.sum)
    # manifest carries the latest snapshot + the collector's loss counters
    manifest = json.load(open(tmp_path / "run" / "MANIFEST.json"))
    assert manifest["metrics"]["metrics"] and "drops" in manifest
    assert manifest["drops"]["dropped"] == 0


def test_cli_metrics_subcommand(tmp_path, capsys):
    from repro.trace.cli import main

    d = str(tmp_path / "run")
    log = TraceCollector()
    plane = MetricsPlane(log)
    stream = StreamingSession(d, rotate_events=4,
                              metrics_provider=plane.snapshot).attach(log)
    for i in range(6):
        with log.lifecycle("request", i):
            pass
    stream.close(stats=log.stats())

    assert main(["metrics", d, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["final"] and doc["timeline"]
    assert main(["metrics", d]) == 0
    out = capsys.readouterr().out
    assert "repro_requests_total" in out and "p95_ms" in out
    # a directory with no metrics sidecar reports, not crashes
    import os

    empty = str(tmp_path / "empty")
    os.makedirs(empty)
    with open(os.path.join(empty, "MANIFEST.json"), "w") as f:
        json.dump({"schema": "x", "segments": []}, f)
    assert main(["metrics", empty]) == 1


def test_cli_metrics_on_session_file(tmp_path, capsys):
    from repro.trace import Session
    from repro.trace.cli import main

    log = TraceCollector()
    plane = MetricsPlane(log)
    with log.lifecycle("request", 0):
        pass
    sess = Session.capture(log, meta={"metrics": plane.snapshot(),
                                      "drops": log.drop_counters()})
    p = str(tmp_path / "s.json")
    sess.save(p)
    assert main(["metrics", p, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert any(m["name"] == "repro_requests_total"
               for m in doc["final"]["metrics"])
    # a non-session JSON is a usage error
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"nope": 1}, f)
    assert main(["metrics", bad]) == 2


# ---------------------------------------------------------------------------
# core.overhead: the factored sample-stats helper
# ---------------------------------------------------------------------------


def test_stats_from_samples():
    s = stats_from_samples("x", [1.0, 2.0, 3.0, 4.0])
    assert s.mean_ms == pytest.approx(2.5)
    assert s.median_ms == pytest.approx(2.5)
    assert s.min_ms == 1.0 and s.max_ms == 4.0
    with pytest.raises(ValueError):
        stats_from_samples("x", [])


def test_controller_short_window_banks_snapshot():
    # A near-empty window catching one expensive record (the shutdown
    # rotation fsync) must not spike the EWMA; its sample is banked and
    # folded into the next full window instead.
    col = _FakeCollector()
    ctl = AdaptiveController(col, budget_pct=5.0, smooth=1.0, noop=_NOOP)
    import time

    ctl._last_t = time.monotonic() - 1.0
    col.feed(per_record_s=0.00002, n=100)  # cheap steady state
    low = ctl.step()
    assert low < 5.0
    col.feed(per_record_s=0.005, n=4)  # fsync-like burst, ~0 s window
    assert ctl.step() == low  # banked, not computed
    assert col.rate == 1.0
    ctl._last_t = time.monotonic() - 1.0
    col.feed(per_record_s=0.00002, n=100)
    assert ctl.step() > low  # the banked burst lands in the full window
