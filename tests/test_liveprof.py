"""Live device profiling plane: duty-cycled capture under the budget loop.

Covers the second (device-specific) budget loop, the synthetic CI backend
driving the full window/parse/align/merge path, exact span-annotation
alignment (golden: zero window-fallback), the alignment-quality gauge on
mixed traces, streaming-manifest coverage, graceful degradation, the
``repro.trace device`` CLI and the ``--device-trace`` dump-dir fixes.
"""
import contextlib
import gzip
import json
import time

import pytest

from repro.core.events import Event
from repro.metrics import DeviceCaptureBudget, MetricsPlane
from repro.trace import (
    LiveDeviceProfiler,
    Session,
    StreamingSession,
    TraceCollector,
    load_profiler_trace,
    load_stream,
)
from repro.trace.cli import main
from repro.trace.liveprof import (
    DeviceCaptureUnavailable,
    SyntheticProfilerBackend,
    annotations_enabled,
    device_annotation,
    make_backend,
    set_annotations,
)
from repro.trace.stream import MANIFEST_NAME


# ---------------------------------------------------------------------------
# DeviceCaptureBudget: the device-specific budget loop
# ---------------------------------------------------------------------------


def test_budget_zero_runs_one_calibration_window_then_measure_only():
    b = DeviceCaptureBudget(budget_pct=0.0, period_s=1.0)
    on, _ = b.plan()
    assert on > 0  # the calibration window still runs
    b.observe(cost_s=0.01, elapsed_s=1.0)
    assert b.capture_enabled is False
    assert b.overhead_pct == pytest.approx(1.0)  # the measurement survives
    on2, off2 = b.plan()
    assert on2 == 0.0 and off2 == 1.0


def test_budget_narrows_fraction_and_stretches_off_time():
    b = DeviceCaptureBudget(budget_pct=5.0, period_s=1.0)
    f0 = b.on_fraction
    b.observe(cost_s=0.2, elapsed_s=1.0)  # 20% overhead, 4x over budget
    assert b.on_fraction < f0
    assert b.adjustments == 1
    on, off = b.plan()
    # the per-window cost is fixed: the off gap must stretch until it
    # amortises under budget even if narrowing the window saves nothing
    assert on + off >= b.cost_ewma_s * 100.0 / b.budget_pct
    assert off > b.period_s - on  # stretched beyond the nominal period


def test_budget_recovers_multiplicatively_when_cheap():
    b = DeviceCaptureBudget(budget_pct=5.0, period_s=1.0)
    b.on_fraction = 0.1
    b.observe(cost_s=0.0001, elapsed_s=1.0)  # 0.01% << half budget
    assert b.on_fraction == pytest.approx(0.15)  # * grow (1.5)
    assert b.on_fraction <= 1.0


def test_budget_fraction_floors_at_min():
    b = DeviceCaptureBudget(budget_pct=1.0, period_s=1.0, min_fraction=0.05)
    for _ in range(6):
        b.observe(cost_s=0.5, elapsed_s=1.0)  # 50x over budget
    assert b.on_fraction == pytest.approx(0.05)
    assert b.capture_enabled is True  # never self-disables over budget


# ---------------------------------------------------------------------------
# Synthetic backend + LiveDeviceProfiler: the full window path, no hardware
# ---------------------------------------------------------------------------


def _make_prof(tmp_path, col, plane=None, **kw):
    kw.setdefault("backend", "synthetic")
    kw.setdefault("budget_pct", 5.0)
    return LiveDeviceProfiler(
        col, str(tmp_path / "prof"),
        registry=plane.registry if plane is not None else None, **kw)


def test_golden_all_annotated_zero_window_fallback(tmp_path):
    """Every call-site slice binds by span= — no containment fallback."""
    col = TraceCollector()
    plane = MetricsPlane(col)
    prof = _make_prof(tmp_path, col, plane)
    assert prof.open_window()
    for i in range(2):
        with col.lifecycle("prefill", i):
            time.sleep(0.001)
    for i in range(3):
        with col.lifecycle("decode_tick", i):
            time.sleep(0.001)
    merged = prof.close_window()
    assert merged == 5

    devs = [e for e in col.events() if e.kind == "device"]
    assert len(devs) == 5
    assert all(e.payload["align"] == "span" for e in devs)
    host_spans = {e.span for e in col.events() if e.kind == "spawn"}
    assert all(e.parent in host_spans for e in devs)  # exact parents
    assert all(e.span not in host_spans and e.span != 0 for e in devs)

    snap = prof.snapshot()
    assert snap["align"]["annotated_fraction"] == 1.0
    assert snap["align"].get("window", 0) == 0
    assert snap["align"].get("none", 0) == 0
    assert snap["windows"] == 1 and snap["merged_events"] == 5

    s = plane.summary()
    assert s["repro_device_alignment_annotated_fraction"] == 1.0
    # device series label op with the span token stripped
    assert s["repro_device_ms_count{device=/device:SYNTH:0,op=prefill}"] == 2
    assert s["repro_device_ms_count{device=/device:SYNTH:0,op=decode_tick}"] == 3
    assert s["repro_device_slices_total{align=span}"] == 5
    assert s["repro_device_capture_windows_total"] == 1
    assert s["repro_device_capture_overhead_pct"] >= 0


def test_mixed_alignment_gauge_reflects_annotated_fraction(tmp_path):
    """Span-less device work falls back to window containment — and the
    alignment-quality gauge reports exactly the annotated fraction."""
    col = TraceCollector()
    plane = MetricsPlane(col)
    prof = _make_prof(tmp_path, col, plane)
    assert prof.open_window()
    with col.lifecycle("prefill", 0):
        time.sleep(0.001)
    # an un-spanned prefill: the synthetic backend emits an unhinted slice,
    # which can only align by time-window containment under the outer request
    with col.lifecycle("request", 0):
        col.record("spawn", "prefill", None, span=0)
        time.sleep(0.002)
        col.record("exit", "prefill", None, span=0)
    merged = prof.close_window()
    assert merged == 2

    by_align = {}
    for e in col.events():
        if e.kind == "device":
            by_align.setdefault(e.payload["align"], []).append(e)
    assert len(by_align["span"]) == 1 and len(by_align["window"]) == 1

    frac = prof.snapshot()["align"]["annotated_fraction"]
    assert frac == pytest.approx(0.5)
    s = plane.summary()
    assert s["repro_device_alignment_annotated_fraction"] == pytest.approx(frac)
    assert s["repro_device_slices_total{align=span}"] == 1
    assert s["repro_device_slices_total{align=window}"] == 1


def test_stop_force_closes_open_window_short_run(tmp_path):
    col = TraceCollector()
    prof = _make_prof(tmp_path, col)
    assert prof.open_window()
    with col.lifecycle("decode_tick", 0):
        pass
    prof.stop()  # never close_window()ed: stop must flush it
    assert len(prof.windows) == 1
    assert prof.merged_events >= 1
    assert annotations_enabled() is False  # stop() tears annotations down


def test_budget_zero_profiler_calibrates_then_disables(tmp_path):
    col = TraceCollector()
    prof = _make_prof(tmp_path, col, budget_pct=0.0)
    assert prof.open_window()
    with col.lifecycle("prefill", 0):
        pass
    prof.close_window()
    assert prof.budget.capture_enabled is False  # measure-only from here
    assert prof.budget.windows == 1
    assert prof.degraded is None  # not a failure — the run keeps tracing host


def test_thread_loop_produces_windows(tmp_path):
    col = TraceCollector()
    prof = _make_prof(tmp_path, col, budget_pct=50.0, period_s=0.05)
    prof.start()
    try:
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            with col.lifecycle("decode_tick", 0):
                time.sleep(0.002)
            if len(prof.windows) >= 2:
                break
    finally:
        prof.stop()
    assert len(prof.windows) >= 2
    marks = [e for e in col.events()
             if e.name == "device_window" and isinstance(e.payload, dict)
             and "events" in e.payload]
    assert len(marks) == len(prof.windows)
    assert all("overhead_pct" in m.payload for m in marks)


# ---------------------------------------------------------------------------
# Degradation: no backend -> one warning event, the run proceeds
# ---------------------------------------------------------------------------


def test_unknown_backend_degrades_with_single_warning(tmp_path):
    col = TraceCollector()
    prof = LiveDeviceProfiler(col, str(tmp_path / "p"), backend="bogus",
                              budget_pct=5.0)
    assert prof.degraded and "bogus" in prof.degraded
    assert prof.open_window() is False
    prof.start()  # must be a no-op, not a crash
    prof.stop()
    warns = [e for e in col.events()
             if e.name == "device_window" and isinstance(e.payload, dict)
             and "warning" in e.payload]
    assert len(warns) == 1  # exactly one, however often capture is poked
    assert prof.budget.capture_enabled is False
    assert prof.snapshot()["degraded"]


def test_backend_failure_mid_run_degrades_once(tmp_path):
    col = TraceCollector()
    prof = _make_prof(tmp_path, col)

    def boom():
        raise RuntimeError("profiler fell over")

    prof.backend.stop = boom
    assert prof.open_window()
    assert prof.close_window() == 0
    assert prof.degraded and "profiler fell over" in prof.degraded
    assert prof.open_window() is False  # capture stays off
    warns = [e for e in col.events()
             if e.name == "device_window" and isinstance(e.payload, dict)
             and "warning" in e.payload]
    assert len(warns) == 1


def test_make_backend_unknown_kind_raises():
    with pytest.raises(DeviceCaptureUnavailable):
        make_backend("nope", TraceCollector())


# ---------------------------------------------------------------------------
# Annotations: module flag + null context off the hot path
# ---------------------------------------------------------------------------


def test_device_annotation_null_when_inactive_or_spanless():
    set_annotations(False)
    assert isinstance(device_annotation(5), contextlib.nullcontext)
    set_annotations(True)
    try:
        if annotations_enabled():  # jax present in this environment
            cm = device_annotation(7)
            assert not isinstance(cm, contextlib.nullcontext)
            with cm:
                pass
            # span 0 means "not traced": never pay for an annotation
            assert isinstance(device_annotation(0), contextlib.nullcontext)
    finally:
        set_annotations(False)


# ---------------------------------------------------------------------------
# Streaming session integration: live merge + per-window manifest coverage
# ---------------------------------------------------------------------------


def test_stream_manifest_records_device_capture(tmp_path):
    d = str(tmp_path / "run")
    col = TraceCollector()
    prof = _make_prof(tmp_path, col)
    stream = StreamingSession(d, rotate_events=8,
                              device_provider=prof.snapshot).attach(col)
    assert prof.open_window()
    for i in range(3):
        with col.lifecycle("prefill", i):
            time.sleep(0.001)
    assert prof.close_window() == 3
    stream.close(stats=col.stats())

    manifest = json.load(open(tmp_path / "run" / MANIFEST_NAME))
    dc = manifest["device_capture"]
    assert dc["windows"] == 1 and dc["merged_events"] == 3
    assert dc["align"]["annotated_fraction"] == 1.0
    assert dc["window_log"][0]["events"] == 3

    # the merged device events rode the sink into the stream, and the
    # manifest block surfaces as session meta on recovery
    sess = load_stream(d)
    devs = [e for e in sess.events if e.kind == "device"]
    assert len(devs) == 3
    assert sess.meta["device_capture"]["merged_events"] == 3


def test_device_provider_failure_is_best_effort(tmp_path, capsys):
    d = str(tmp_path / "run")
    col = TraceCollector()

    def bad_provider():
        raise RuntimeError("snapshot exploded")

    stream = StreamingSession(d, rotate_events=4,
                              device_provider=bad_provider).attach(col)
    for i in range(6):
        with col.lifecycle("request", i):
            pass
    stream.close(stats=col.stats())  # must not raise
    manifest = json.load(open(tmp_path / "run" / MANIFEST_NAME))
    assert "device_capture" not in manifest
    assert "device-capture refresh failed" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# repro.trace device CLI + --device-trace dump-dir handling
# ---------------------------------------------------------------------------


def _stream_with_capture(tmp_path):
    d = str(tmp_path / "run")
    col = TraceCollector()
    prof = _make_prof(tmp_path, col)
    stream = StreamingSession(d, rotate_events=64,
                              device_provider=prof.snapshot).attach(col)
    assert prof.open_window()
    for i in range(2):
        with col.lifecycle("prefill", i):
            time.sleep(0.001)
    prof.close_window()
    stream.close(stats=col.stats())
    return d, prof


def test_cli_device_reports_coverage_and_alignment(tmp_path, capsys):
    d, _ = _stream_with_capture(tmp_path)
    assert main(["device", d]) == 0
    out = capsys.readouterr().out
    assert "backend=synthetic" in out and "windows=1" in out
    assert "annotated=100.0%" in out
    assert "/device:SYNTH:0" in out
    assert "prefill" in out


def test_cli_device_json(tmp_path, capsys):
    d, _ = _stream_with_capture(tmp_path)
    assert main(["device", d, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["align"]["annotated_fraction"] == 1.0
    assert doc["capture"]["windows"] == 1
    assert doc["by_device"]["/device:SYNTH:0"]["slices"] == 2
    assert "prefill" in doc["by_op"]


def test_cli_device_missing_path_exits_1(tmp_path, capsys):
    assert main(["device", str(tmp_path / "nope")]) == 1
    assert "error" in capsys.readouterr().err


def test_cli_report_accepts_streaming_dir_with_window_dump(tmp_path, capsys):
    """--device-trace on a live-profiler out dir (one trace file per window)
    merges every window, against a streaming segment-dir session."""
    d, prof = _stream_with_capture(tmp_path)
    assert main(["report", d, "--device-trace", prof.out_dir,
                 "--device-offset-s", "0"]) == 0
    out = capsys.readouterr().out
    assert "device:/device:SYNTH:0" in out


def test_cli_device_trace_xplane_only_exits_2(tmp_path, capsys):
    col = TraceCollector()
    with col.lifecycle("prefill", 0):
        pass
    path = Session(meta={}, events=col.events()).save(str(tmp_path / "s.json"))
    xp = tmp_path / "xp" / "plugins" / "profile" / "r"
    xp.mkdir(parents=True)
    (xp / "host.xplane.pb").write_bytes(b"\x00")
    rc = main(["report", path, "--device-trace", str(tmp_path / "xp")])
    assert rc == 2
    err = capsys.readouterr().err
    assert "xplane" in err and "--device-trace" in err
    assert "Traceback" not in err  # helpful error, not a stack dump


def test_load_profiler_trace_merges_all_window_files(tmp_path):
    """A dump root holding several per-window trace files merges them all
    (the live profiler writes one per window)."""
    for i, (name, ts) in enumerate([("fusion.a", 1_000_000),
                                    ("fusion.b", 3_000_000)]):
        run = tmp_path / f"window-{i:04d}" / "plugins" / "profile" / "r"
        run.mkdir(parents=True)
        rows = [
            {"ph": "M", "pid": 7, "name": "process_name",
             "args": {"name": "/device:TPU:0"}},
            {"ph": "X", "pid": 7, "tid": 1, "name": name,
             "ts": ts, "dur": 10_000},
        ]
        with gzip.open(run / "local.trace.json.gz", "wt") as f:
            json.dump({"traceEvents": rows}, f)
    slices = load_profiler_trace(str(tmp_path))
    assert [s.name for s in slices] == ["fusion.a", "fusion.b"]  # time order
