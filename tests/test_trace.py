"""repro.trace subsystem: collector, exporters, sessions, warm-start."""
import json
import threading

import pytest

from repro.core.events import Event, EventLog
from repro.dispatch import DispatchConfig, Dispatcher
from repro.dispatch.profiles import ProfileStore
from repro.dispatch.registry import BackendRegistry, BackendTarget
from repro.trace import (
    Session,
    TraceCollector,
    artifact_meta,
    diff_artifacts,
    load_profile_store,
    resolve_spans,
    to_chrome_trace,
    to_folded,
    to_speedscope,
)
from repro.trace.export import export


# ---------------------------------------------------------------------------
# EventLog: interleaved pairing + ring semantics (the two satellite fixes)
# ---------------------------------------------------------------------------


def test_durations_pairs_interleaved_spans_by_payload():
    """Request A spawns, B spawns, A exits, B exits: the old stack match
    paired A's spawn with B's exit.  Payload identity must fix it."""
    log = EventLog()
    log.record("spawn", "request", payload="A")
    log.record("spawn", "request", payload="B")
    # exits arrive in spawn order (FIFO) — a LIFO stack mis-pairs this
    log.record("exit", "request", payload="A")
    log.record("exit", "request", payload="B")
    evs = log.events(name="request")
    durs = log.durations("request")
    assert len(durs) == 2
    a_dur = evs[2].t - evs[0].t
    b_dur = evs[3].t - evs[1].t
    assert durs == pytest.approx([a_dur, b_dur])
    # the buggy stack pairing would have produced these instead:
    wrong = [evs[2].t - evs[1].t, evs[3].t - evs[0].t]
    assert durs != pytest.approx(wrong) or a_dur == pytest.approx(wrong[0])


def test_durations_pairs_by_span_id():
    log = EventLog()
    with log.lifecycle("step", {"unhashable": True}):  # dict payload: span id carries
        with log.lifecycle("step", {"unhashable": True}):
            pass
    durs = log.durations("step")
    assert len(durs) == 2
    assert durs[0] <= durs[1]  # inner closes first and is shorter


def test_durations_stack_fallback_for_legacy_events():
    log = EventLog()
    log.record("spawn", "op")
    log.record("exit", "op")
    assert len(log.durations("op")) == 1


def test_ring_buffer_bounds_and_counts_drops():
    log = EventLog(maxlen=4)
    for i in range(10):
        log.record("mark", "m", i)
    assert len(log) == 4
    assert log.dropped == 6
    raw = json.loads(log.to_json())
    assert raw["dropped"] == 6 and raw["maxlen"] == 4
    assert [e["payload"] for e in raw["events"]] == [6, 7, 8, 9]
    log.clear()
    assert len(log) == 0 and log.dropped == 0


def test_global_log_is_bounded():
    from repro.core.events import GLOBAL_LOG

    assert GLOBAL_LOG.maxlen is not None


# ---------------------------------------------------------------------------
# Collector: tracks, spans, stats, thread-safety
# ---------------------------------------------------------------------------


def test_collector_tracks_and_stats():
    col = TraceCollector(capacity=128)
    with col.lifecycle("step", 0):
        pass
    col.record("spawn", "request", 1)
    col.record("exit", "request", 1)
    col.record("dispatch", "attention", {"backend": "ref", "measured_s": 0.001})
    col.record("mark", "custom_thing")
    tracks = col.tracks()
    assert [e.name for e in tracks["step"]] == ["step", "step"]
    assert len(tracks["request"]) == 2
    assert len(tracks["dispatch"]) == 1
    assert len(tracks["other"]) == 1
    st = col.stats()
    assert st["events"] == 6 and st["dropped"] == 0 and st["capacity"] == 128
    assert st["per_track"]["request"] == 2


def test_collector_stress_multithreaded():
    col = TraceCollector(capacity=256)
    n_threads, per_thread = 8, 200

    def work(tid: int):
        for i in range(per_thread):
            if i % 3 == 0:
                with col.lifecycle("step", (tid, i)):
                    pass
            else:
                col.record("mark", "m", (tid, i))

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = sum(2 if i % 3 == 0 else 1 for i in range(per_thread)) * n_threads
    assert len(col) == 256  # ring full
    assert len(col) + col.dropped == total
    col.spans()  # resolution over a torn ring must not raise
    col.stats()


def test_reserved_track_ring_survives_request_flood():
    """Track-aware sampling: tiny dispatch events must not be evicted by a
    flood of hot events sharing the main ring."""
    col = TraceCollector(capacity=32, track_capacity={"dispatch": 8})
    for i in range(4):
        col.record("dispatch", "op", {"op": "op", "backend": "ref", "i": i})
    for i in range(500):
        col.record("mark", "m", i)  # "other" flood wraps the main ring 15x
    assert len(col.events(kind="dispatch")) == 4  # all survive
    st = col.stats()
    assert st["dropped"] == 500 - 32
    assert st["dropped_by_track"]["dispatch"] == 0
    assert st["track_capacity"]["dispatch"] == 8
    assert len(col) == 32 + 4


def test_reserved_track_ring_eviction_is_counted():
    col = TraceCollector(capacity=32, track_capacity={"dispatch": 2})
    for i in range(5):
        col.record("dispatch", "op", {"i": i})
    evs = col.events(kind="dispatch")
    assert [e.payload["i"] for e in evs] == [3, 4]  # newest kept
    assert col.stats()["dropped_by_track"]["dispatch"] == 3
    assert col.dropped == 3


def test_default_reserved_tracks_dispatch_and_checkpoint():
    col = TraceCollector(capacity=4)  # tiny main ring, default reservations
    for i in range(20):
        col.record("mark", "m", i)
    col.record("dispatch", "op", {"op": "op"})
    with col.lifecycle("checkpoint", 1):
        pass
    for i in range(20):
        col.record("mark", "m", 100 + i)  # second flood after the events
    assert len(col.events(kind="dispatch")) == 1
    assert len(col.events(name="checkpoint")) == 2
    # clear() resets reserved rings and their drop counters too
    col.clear()
    assert len(col) == 0 and col.dropped == 0


def test_resolve_spans_drops_orphan_exits():
    evs = [
        Event(1.0, "exit", "request", "evicted-spawn"),
        Event(2.0, "spawn", "request", "ok"),
        Event(3.0, "exit", "request", "ok"),
    ]
    spans = resolve_spans(evs)
    assert len(spans) == 1 and spans[0].dur == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def _sample_collector() -> TraceCollector:
    col = TraceCollector(capacity=512)
    for rid in range(3):
        col.record("spawn", "request", rid)
    for rid in range(3):
        col.record("dispatch", "serve_decode",
                   {"op": "serve_decode", "backend": "chunked", "measured_s": 0.002})
        col.record("exit", "request", rid)
    col.record("straggler", "step", {"step": 4, "s": 0.5})
    return col


def test_chrome_trace_export_is_valid_trace_event_json():
    col = _sample_collector()
    text = export(col.events(), "chrome", collector=col)
    doc = json.loads(text)  # must be valid JSON
    rows = doc["traceEvents"]
    assert rows, "no trace events exported"
    for row in rows:
        assert "ph" in row and "pid" in row
        if row["ph"] != "M":
            assert "ts" in row
    phases = {r["ph"] for r in rows}
    # requests carry payload ids -> async b/e pairs (viewer pairs by id, not
    # by per-tid LIFO, so interleaved requests render correctly)
    assert {"b", "e", "X", "M"} <= phases
    # b/e balanced per (tid, name, id); B/E (legacy) balanced per (tid, name)
    depth: dict = {}
    for r in rows:
        if r["ph"] in ("b", "e"):
            assert "id" in r
            k = (r.get("tid"), r["name"], r["id"])
            depth[k] = depth.get(k, 0) + (1 if r["ph"] == "b" else -1)
        elif r["ph"] in ("B", "E"):
            k = (r.get("tid"), r["name"])
            depth[k] = depth.get(k, 0) + (1 if r["ph"] == "B" else -1)
    assert all(v == 0 for v in depth.values())
    # dispatch X events carry a duration in microseconds
    xs = [r for r in rows if r["ph"] == "X"]
    assert all(r["dur"] == pytest.approx(2000, rel=1e-3) for r in xs)
    # thread metadata names the tracks
    names = {r["args"]["name"] for r in rows if r["ph"] == "M" and r["name"] == "thread_name"}
    assert {"request", "dispatch"} <= names


def test_speedscope_export_schema():
    """Evented profiles: balanced O/C per frame, nondecreasing timestamps,
    stack discipline (a close always closes the most recent open)."""
    col = _sample_collector()
    doc = json.loads(export(col.events(), "speedscope", collector=col))
    assert doc["$schema"] == "https://www.speedscope.app/file-format-schema.json"
    assert doc["profiles"], "no profiles"
    frames = doc["shared"]["frames"]
    for p in doc["profiles"]:
        assert p["type"] == "evented"
        assert p["events"], f"empty profile {p['name']}"
        last_at = p["startValue"]
        stack = []
        opens: dict = {}
        for ev in p["events"]:
            assert ev["type"] in ("O", "C")
            assert 0 <= ev["frame"] < len(frames)
            assert ev["at"] >= last_at  # nondecreasing
            last_at = ev["at"]
            if ev["type"] == "O":
                stack.append(ev["frame"])
                opens[ev["frame"]] = opens.get(ev["frame"], 0) + 1
            else:
                assert stack and stack[-1] == ev["frame"]  # strict LIFO
                stack.pop()
                opens[ev["frame"]] -= 1
        assert not stack  # every frame closed
        assert all(v == 0 for v in opens.values())
        assert p["endValue"] >= last_at


def test_folded_export():
    col = _sample_collector()
    text = export(col.events(), "folded", collector=col)
    lines = [ln for ln in text.splitlines() if ln]
    assert lines
    for ln in lines:
        stack, count = ln.rsplit(" ", 1)
        assert int(count) >= 0 and ";" in stack
    assert any(ln.startswith("dispatch;serve_decode;chunked") for ln in lines)


def test_chrome_interleaved_requests_pair_by_id():
    """Overlapping same-name spans must not rely on viewer LIFO pairing."""
    col = TraceCollector()
    col.record("spawn", "request", "A")
    col.record("spawn", "request", "B")
    col.record("exit", "request", "A")
    col.record("exit", "request", "B")
    rows = [r for r in to_chrome_trace(col.events(), collector=col)["traceEvents"]
            if r["ph"] in ("b", "e")]
    assert len(rows) == 4
    by_id: dict = {}
    for r in rows:
        by_id.setdefault(r["id"], []).append(r["ph"])
    assert all(phs == ["b", "e"] for phs in by_id.values())
    assert len(by_id) == 2


def test_partition_decisions_flow_through_trace_pipeline(tmp_path):
    """partition() records unexecuted decisions (no measured_s); report,
    export and profile ingestion must all tolerate them."""
    from repro.core.sdfg import extract
    import jax.numpy as jnp

    col = TraceCollector()
    disp = Dispatcher(DispatchConfig(policy="roofline"), log=col)
    graph = extract(lambda x: jnp.tanh(x @ x.T), jnp.ones((8, 8)))
    disp.partition(graph)
    assert disp.decisions and all(d.measured_s is None for d in disp.decisions)
    assert all("measured_s" not in (e.payload or {}) for e in col.events(kind="dispatch"))
    sess = Session.capture(col, dispatcher=disp)
    rep = sess.report()  # must not raise
    assert rep["dispatch"]["decisions"] == len(disp.decisions)
    json.loads(export(col.events(), "chrome", collector=col))  # must not raise
    assert ProfileStore().ingest_event_log(col) == 0  # nothing measured


def test_cfg_min_samples_governs_provided_store():
    store = ProfileStore(min_samples=2)
    store.record("op", "be", "<s>", 0.001)
    store.record("op", "be", "<s>", 0.001)
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=5),
                      registry=_registry(), store=store, log=TraceCollector())
    assert disp.store.min_samples == 5
    assert not disp.store.warm("op", "be", "<s>")  # 2 samples < cfg's 5


def test_export_unknown_format_raises():
    with pytest.raises(ValueError):
        export([], "perfetto-proto")


# ---------------------------------------------------------------------------
# Sessions: round trip, profiles, diff
# ---------------------------------------------------------------------------


def _variants() -> dict:
    import time as _time

    # deterministic speed gap: "slow" sleeps 2ms, so min-wall-time argmin is
    # always "fast" regardless of scheduler noise
    return {"fast": lambda x: x + 1, "slow": lambda x: _time.sleep(0.002) or x + 1}


def _registry() -> BackendRegistry:
    reg = BackendRegistry()
    reg.register(BackendTarget(name="fast", impl="ref", launch_overhead_s=1e-7))
    reg.register(BackendTarget(name="slow", impl="ref", launch_overhead_s=1e-5))
    return reg


def _cheap_dispatcher(log) -> Dispatcher:
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=2),
                      registry=_registry(), log=log)
    variants = _variants()
    for _ in range(6):
        disp.dispatch("inc", variants, 1.0)
    return disp


def test_session_round_trip_identical_report(tmp_path):
    col = _sample_collector()
    disp = _cheap_dispatcher(col)
    sess = Session.capture(col, dispatcher=disp, meta={"driver": "test"})
    before = sess.report()
    path = sess.save(str(tmp_path / "t.json"))
    loaded = Session.load(path)
    assert loaded.report() == before
    assert loaded.meta["schema"] == "repro.trace.session/v1"
    assert loaded.meta["driver"] == "test"
    assert "git_sha" in loaded.meta and "created_unix" in loaded.meta
    assert len(loaded.store) == len(disp.store)
    assert loaded.chip and loaded.chip["name"] == disp.chip.name


def test_session_report_contents(tmp_path):
    col = _sample_collector()
    disp = _cheap_dispatcher(col)
    rep = Session.capture(col, dispatcher=disp).report()
    assert rep["dispatch"]["decisions"] == 6
    assert "inc" in rep["dispatch"]["by_op"]
    assert rep["dispatch"]["by_source"].get("explore", 0) >= 4  # 2 backends × min_samples
    assert any(k.startswith("request/") for k in rep["latency"])


def test_load_profile_store_from_session_and_bare(tmp_path):
    col = TraceCollector()
    disp = _cheap_dispatcher(col)
    sess_path = Session.capture(col, dispatcher=disp).save(str(tmp_path / "s.json"))
    bare_path = str(tmp_path / "p.json")
    with open(bare_path, "w") as f:
        f.write(disp.store.to_json())
    for path in (sess_path, bare_path):
        store = load_profile_store(path)
        assert len(store) == len(disp.store)
        assert store.warm("inc", "fast", "<scalar>")


def test_warm_start_skips_exploration(tmp_path):
    cold_log = TraceCollector()
    cold = _cheap_dispatcher(cold_log)
    assert cold.summary()["explore_dispatches"] >= 4

    path = Session.capture(cold_log, dispatcher=cold).save(str(tmp_path / "s.json"))
    warm = _cheap_dispatcher_with_store(load_profile_store(path))
    assert warm.summary()["explore_dispatches"] == 0
    # first decision already lands on the steady-state (measured) choice
    assert warm.decisions[0].source == "measured"
    assert warm.decisions[0].backend == cold.decisions[-1].backend


def _cheap_dispatcher_with_store(store: ProfileStore) -> Dispatcher:
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=2),
                      registry=_registry(), store=store, log=TraceCollector())
    variants = _variants()
    for _ in range(4):
        disp.dispatch("inc", variants, 1.0)
    return disp


def test_profile_store_merge_welford_exact():
    a, b, ref = ProfileStore(), ProfileStore(), ProfileStore()
    xs = [0.5, 1.0, 1.5, 2.0, 5.0]
    for i, x in enumerate(xs):
        (a if i % 2 else b).record("op", "be", "<s>", x)
        ref.record("op", "be", "<s>", x)
    a.merge(b)
    ea, er = a.entry("op", "be", "<s>"), ref.entry("op", "be", "<s>")
    assert ea.count == er.count
    assert ea.mean_s == pytest.approx(er.mean_s)
    assert ea.variance == pytest.approx(er.variance)
    assert ea.min_s == er.min_s


def test_load_profile_store_rejects_non_store_json(tmp_path):
    bogus = str(tmp_path / "chrome.json")
    with open(bogus, "w") as f:
        json.dump({"traceEvents": []}, f)
    with pytest.raises(ValueError, match="entries"):
        load_profile_store(bogus)


def test_load_profile_stores_merges_multiple(tmp_path):
    from repro.trace import load_profile_stores

    paths = []
    for i in range(2):
        s = ProfileStore()
        s.record("op", "be", "<s>", 0.001 * (i + 1))
        p = str(tmp_path / f"s{i}.json")
        with open(p, "w") as f:
            f.write(s.to_json())
        paths.append(p)
    merged = load_profile_stores(paths)
    assert merged.entry("op", "be", "<s>").count == 2


def test_profile_stamp_round_trips_json():
    store = ProfileStore()
    store.set_stamp(git_sha="deadbee", chip="tpu-v99")
    store.record("op", "be", "<s>", 0.001)
    restored = ProfileStore.from_json(store.to_json())
    e = restored.entry("op", "be", "<s>")
    assert e.git_sha == "deadbee" and e.chip == "tpu-v99"


def test_age_out_evicts_mismatched_keeps_matching_and_unstamped():
    store = ProfileStore()
    store.set_stamp(git_sha="aaaa", chip="tpu-v5e")
    store.record("stale_op", "be", "<s>", 0.001)
    store.set_stamp(git_sha="bbbb", chip="tpu-v5e")
    store.record("fresh_op", "be", "<s>", 0.001)
    store.set_stamp()  # unstamped legacy entry
    store.record("legacy_op", "be", "<s>", 0.001)
    aged = store.age_out(git_sha="bbbb", chip="tpu-v5e")
    assert [a["key"] for a in aged] == ["stale_op|be|<s>"]
    assert "git_sha changed (aaaa -> bbbb)" in aged[0]["reason"]
    assert store.entry("fresh_op", "be", "<s>") is not None
    assert store.entry("legacy_op", "be", "<s>") is not None
    # chip mismatch ages out independently of git
    aged = store.age_out(git_sha="bbbb", chip="h100")
    assert [a["key"] for a in aged] == ["fresh_op|be|<s>"]
    assert "chip changed" in aged[0]["reason"]


def test_aged_out_profiles_force_re_exploration(tmp_path):
    """The invalidation loop end to end: a warm store stamped by different
    code is aged out at load, and the dispatcher explores again."""
    from repro.trace import age_out_profiles, load_profile_store

    cold_log = TraceCollector()
    cold = _cheap_dispatcher(cold_log)  # warm store, stamped with current env
    path = Session.capture(cold_log, dispatcher=cold).save(str(tmp_path / "s.json"))

    # same code: nothing ages out, warm start skips exploration
    same = load_profile_store(path)
    assert age_out_profiles(same, chip_name=cold.chip.name) == []
    assert _cheap_dispatcher_with_store(same).summary()["explore_dispatches"] == 0

    # "the repo moved on": every entry is stamped with a foreign SHA
    stale = load_profile_store(path)
    for e in stale._entries.values():
        e.git_sha = "0000000"
    aged = age_out_profiles(stale, chip_name=cold.chip.name)
    assert len(aged) == 2 and len(stale) == 0  # both backends evicted
    redisp = _cheap_dispatcher_with_store(stale)
    assert redisp.summary()["explore_dispatches"] >= 4  # re-explores from cold


def test_merge_mixed_provenance_is_conservatively_aged_out():
    """Merging the same key from two environments yields an untrustworthy
    entry: its 'mixed' stamp must never survive an invalidation pass."""
    a, b = ProfileStore(), ProfileStore()
    a.set_stamp(git_sha="aaaa", chip="tpu-v5e")
    a.record("op", "be", "<s>", 0.001)
    b.set_stamp(git_sha="bbbb", chip="tpu-v5e")
    b.record("op", "be", "<s>", 0.002)
    b.record("other", "be", "<s>", 0.003)  # disjoint key keeps its own stamp
    a.merge(b)
    assert a.entry("op", "be", "<s>").git_sha == "mixed"
    assert a.entry("op", "be", "<s>").chip == "tpu-v5e"  # agreeing field kept
    assert a.entry("other", "be", "<s>").git_sha == "bbbb"
    aged = a.age_out(git_sha="bbbb", chip="tpu-v5e")
    assert [x["key"] for x in aged] == ["op|be|<s>"]
    assert a.entry("other", "be", "<s>") is not None


def test_record_cannot_launder_old_samples_under_fresh_stamp():
    """One new sample into an entry of different/unknown provenance must not
    re-stamp the whole (old-sample-dominated) mean as freshly measured."""
    store = ProfileStore()
    store.record("legacy", "be", "<s>", 0.5)  # unstamped old samples
    store.set_stamp(git_sha="bbbb", chip="tpu-v5e")
    store.record("legacy", "be", "<s>", 0.001)
    assert store.entry("legacy", "be", "<s>").git_sha == "mixed"
    assert store.age_out(git_sha="bbbb")  # evicted, not trusted
    # whereas a consistently-stamped entry stays current
    store.record("fresh", "be", "<s>", 0.001)
    store.record("fresh", "be", "<s>", 0.001)
    assert store.entry("fresh", "be", "<s>").git_sha == "bbbb"
    assert store.age_out(git_sha="bbbb", chip="tpu-v5e") == []


def test_dispatcher_stamps_new_measurements():
    disp = _cheap_dispatcher(TraceCollector())
    from repro.trace.session import git_sha

    e = disp.store.entry("inc", "fast", "<scalar>")
    assert e.git_sha == git_sha() and e.chip == disp.chip.name


def test_dispatcher_keeps_provided_empty_store():
    empty = ProfileStore(min_samples=2)
    disp = Dispatcher(DispatchConfig(policy="profiled", min_samples=2),
                      registry=_registry(), store=empty, log=TraceCollector())
    assert disp.store is empty  # truthiness of an empty store must not drop it


def test_diff_artifacts_zero_to_nonzero_is_json_safe():
    a = {"meta": artifact_meta(), "x": {"dropped": 0}}
    b = {"meta": artifact_meta(), "x": {"dropped": 5}}
    out = diff_artifacts(a, b)
    row = next(r for r in out["changed"] if r["key"] == "x.dropped")
    assert row["delta_pct"] is None
    json.dumps(out, allow_nan=False)  # must not contain Infinity/NaN


def test_chrome_trace_no_negative_ts_for_leading_dispatch():
    col = TraceCollector()
    col.record("dispatch", "op", {"op": "op", "backend": "ref", "measured_s": 0.004})
    doc = to_chrome_trace(col.events(), collector=col)
    xs = [r for r in doc["traceEvents"] if r["ph"] == "X"]
    assert xs and all(r["ts"] >= 0 for r in xs)


def test_custom_tracks_get_distinct_tids():
    col = TraceCollector(track_of={"alpha_op": "alpha", "beta_op": "beta"})
    with col.lifecycle("alpha_op"):
        pass
    with col.lifecycle("beta_op"):
        pass
    doc = to_chrome_trace(col.events(), collector=col)
    names = {r["tid"]: r["args"]["name"] for r in doc["traceEvents"]
             if r["ph"] == "M" and r["name"] == "thread_name"}
    assert sorted(names.values()) == ["alpha", "beta"]
    assert len(set(names)) == 2


def test_cli_diff_mixed_types_errors(tmp_path, capsys):
    from repro.trace.cli import main

    col = _sample_collector()
    pa = Session.capture(col).save(str(tmp_path / "a.json"))
    pb = str(tmp_path / "bench.json")
    with open(pb, "w") as f:
        json.dump({"meta": artifact_meta(), "x": 1}, f)
    assert main(["diff", pa, pb]) == 2
    assert "cannot diff" in capsys.readouterr().err


def test_diff_artifacts_on_stamped_bench_json():
    a = {"meta": artifact_meta(), "kernels": {"attention_ms": 2.0, "rwkv_ms": 8.0}}
    b = {"meta": artifact_meta(), "kernels": {"attention_ms": 1.0, "rwkv_ms": 8.0}}
    assert a["meta"]["schema"] == "repro.bench/v1"
    assert a["meta"]["git_sha"] and "chip" in a["meta"]
    out = diff_artifacts(a, b)
    keys = [r["key"] for r in out["changed"]]
    assert "kernels.attention_ms" in keys
    assert "kernels.rwkv_ms" not in keys  # unchanged
    assert not any("meta" in k or "created_unix" in k for k in keys)


# ---------------------------------------------------------------------------
# CLI (in-process)
# ---------------------------------------------------------------------------


def test_cli_report_export_diff(tmp_path, capsys):
    from repro.trace.cli import main

    col = _sample_collector()
    disp = _cheap_dispatcher(col)
    pa = Session.capture(col, dispatcher=disp).save(str(tmp_path / "a.json"))
    col2 = _sample_collector()
    disp2 = _cheap_dispatcher(col2)
    pb = Session.capture(col2, dispatcher=disp2).save(str(tmp_path / "b.json"))

    assert main(["report", pa]) == 0
    out = capsys.readouterr().out
    assert "dispatch" in out and "inc" in out

    chrome = str(tmp_path / "a.chrome.json")
    assert main(["export", pa, "--format", "chrome", "-o", chrome]) == 0
    doc = json.load(open(chrome))
    assert doc["traceEvents"]

    assert main(["diff", pa, pb]) == 0
    out = capsys.readouterr().out
    assert "dispatch choices" in out


# ---------------------------------------------------------------------------
# Drop accounting in report + span-tree path attribution (diff --by-path)
# ---------------------------------------------------------------------------


def test_report_surfaces_drop_accounting_top_level():
    col = _sample_collector()
    sess = Session.capture(col, collector_stats={
        "events": 7, "capacity": 512, "dropped": 3,
        "dropped_by_track": {"request": 3, "dispatch": 0},
        "sampled_out": 5})
    rep = sess.report()
    assert rep["dropped_by_track"] == {"request": 3}  # zero entries filtered
    assert rep["sampled_out"] == 5
    assert "truncated_spans" in rep
    # survives save -> load -> report
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        p = sess.save(os.path.join(d, "s.json"))
        assert Session.load(p).report()["dropped_by_track"] == {"request": 3}


def test_cli_report_warns_on_drops_and_shedding(tmp_path, capsys):
    from repro.trace.cli import main

    col = _sample_collector()
    sess = Session.capture(col, collector_stats={
        "events": 7, "capacity": 512, "dropped": 2,
        "dropped_by_track": {"request": 2}, "sampled_out": 9})
    p = sess.save(str(tmp_path / "s.json"))
    assert main(["report", p]) == 0
    out = capsys.readouterr().out
    assert "drops by track" in out and "request" in out
    assert "sampled out" in out and "9" in out
    assert main(["report", p, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["dropped_by_track"] == {"request": 2}
    assert doc["sampled_out"] == 9


def _path_session(prefill_s: float) -> Session:
    """Two requests of 0.5 s each, one prefill child of ``prefill_s``."""
    from repro.trace.session import SESSION_SCHEMA

    rows = []

    def ev(t, kind, name, span, parent):
        rows.append({"t": t, "kind": kind, "name": name, "payload": None,
                     "span": span, "parent": parent})

    for i in range(2):
        base, rid, pf = i * 1.0, 10 + i * 2, 11 + i * 2
        ev(base + 0.0, "spawn", "request", rid, 0)
        ev(base + 0.01, "spawn", "prefill", pf, rid)
        ev(base + 0.01 + prefill_s, "exit", "prefill", pf, rid)
        ev(base + 0.5, "exit", "request", rid, 0)
    return Session.from_dict({"meta": {"schema": SESSION_SCHEMA},
                              "trace": {"events": rows}})


def test_path_report_exclusive_conserved_under_depth_cap():
    sess = _path_session(0.1)
    rep = sess.path_report(max_depth=4)
    assert rep["request"]["count"] == 2
    assert rep["request/prefill"]["count"] == 2
    # exclusive: the request path excludes its prefill children
    assert rep["request"]["exclusive_ms"] == pytest.approx(2 * 400.0)
    assert rep["request/prefill"]["exclusive_ms"] == pytest.approx(2 * 100.0)
    # depth cap folds child time into the capped ancestor; totals conserved
    capped = sess.path_report(max_depth=1)
    assert capped["request"]["exclusive_ms"] == pytest.approx(2 * 500.0)
    assert "request/prefill" not in capped


def test_path_diff_attributes_regression_to_grown_node():
    from repro.trace import path_diff, path_regressions

    rows = path_diff(_path_session(0.1), _path_session(0.2))
    by = {r["path"]: r for r in rows}
    assert by["request/prefill"]["delta_pct"] == pytest.approx(100.0)
    # request's own exclusive time SHRANK (same total, bigger child): the
    # regression lands on the node that grew, not the whole request
    assert by["request"]["delta_pct"] < 0
    regs = path_regressions(rows, 25.0)
    assert [r["key"] for r in regs] == ["request/prefill"]
    assert regs[0]["kind"] == "path-exclusive"


def test_cli_diff_by_path_gate(tmp_path, capsys):
    from repro.trace.cli import EXIT_REGRESSION, main

    pa = _path_session(0.1).save(str(tmp_path / "a.json"))
    pb = _path_session(0.2).save(str(tmp_path / "b.json"))
    assert main(["diff", pa, pb, "--by-path"]) == 0
    out = capsys.readouterr().out
    assert "request/prefill" in out and "span-tree path" in out

    rc = main(["diff", pa, pb, "--by-path", "--fail-over-pct", "25", "--json"])
    assert rc == EXIT_REGRESSION
    doc = json.loads(capsys.readouterr().out)
    assert any(r["key"] == "request/prefill" and r["kind"] == "path-exclusive"
               for r in doc["regressions"])

    # --by-path is a session-only view: bench artifacts have no span tree
    bench = str(tmp_path / "bench.json")
    with open(bench, "w") as f:
        json.dump({"meta": artifact_meta(), "x": 1}, f)
    assert main(["diff", bench, bench, "--by-path"]) == 2
