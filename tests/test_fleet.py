"""repro.fleet: central cross-run profile aggregation + auto warm-start."""
import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.dispatch.profiles import ProfileEntry, ProfileStore
from repro.fleet import (
    FleetClient,
    FleetError,
    FleetPusher,
    FleetStore,
    declared_stamp,
    make_server,
    warm_start_from_fleet,
)
from repro.fleet.cli import EXIT_MISS
from repro.fleet.cli import main as fleet_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _store(samples, op="op", backend="be", sig="<s>", git_sha="", chip=""):
    s = ProfileStore()
    if git_sha or chip:
        s.set_stamp(git_sha=git_sha, chip=chip)
    for x in samples:
        s.record(op, backend, sig, x)
    return s


# ---------------------------------------------------------------------------
# ProfileStore: merge placeholder fix + delta subtraction
# ---------------------------------------------------------------------------


def test_merge_returns_sample_count_and_skips_placeholders():
    a, b = ProfileStore(), ProfileStore()
    b._entries["op|be|<s>"] = ProfileEntry()  # count=0 placeholder row
    b.record("op2", "be", "<s>", 0.001)
    b.record("op2", "be", "<s>", 0.002)
    assert a.merge(b) == 2  # samples merged, not keys touched
    # the empty row must not materialise as a warm-looking zero-sample entry
    assert len(a) == 1 and a.entry("op", "be", "<s>") is None


def test_merge_placeholder_does_not_pollute_existing_stamp():
    a = _store([0.001], git_sha="aaaa", chip="tpu-x")
    b = ProfileStore()
    b._entries["op|be|<s>"] = ProfileEntry()  # unstamped empty row, same key
    assert a.merge(b) == 0
    e = a.entry("op", "be", "<s>")
    assert e.count == 1
    assert e.git_sha == "aaaa" and e.chip == "tpu-x"  # no 'mixed' laundering


def test_merge_into_placeholder_adopts_incoming_stamp():
    """A sample-less placeholder in *self* must not launder the incoming
    entry's provenance to 'mixed' (age-out would then evict real samples)."""
    a = ProfileStore()
    a._entries["op|be|<s>"] = ProfileEntry()  # unstamped count=0 row
    b = _store([0.001, 0.002], git_sha="aaaa", chip="tpu-x")
    assert a.merge(b) == 2
    e = a.entry("op", "be", "<s>")
    assert e.count == 2 and e.git_sha == "aaaa" and e.chip == "tpu-x"
    assert a.age_out(git_sha="aaaa", chip="tpu-x") == []  # survives


def test_record_into_placeholder_adopts_writer_stamp():
    s = ProfileStore()
    s._entries["op|be|<s>"] = ProfileEntry()  # unstamped count=0 row
    s.set_stamp(git_sha="aaaa", chip="tpu-x")
    s.record("op", "be", "<s>", 0.001)
    e = s.entry("op", "be", "<s>")
    assert e.git_sha == "aaaa" and e.chip == "tpu-x"  # not 'mixed'


def test_delta_since_is_exact_welford_complement():
    s = ProfileStore()
    first, second = [0.5, 1.0, 2.0], [4.0, 0.25, 8.0]
    for x in first:
        s.record("op", "be", "<s>", x)
    base = ProfileStore.from_json(s.to_json())
    for x in second:
        s.record("op", "be", "<s>", x)
    s.record("new", "be", "<s>", 1.0)

    delta = s.delta_since(base)
    e = delta.entry("op", "be", "<s>")
    assert e.count == len(second)
    assert e.mean_s == pytest.approx(sum(second) / len(second))
    assert delta.entry("new", "be", "<s>").count == 1  # new key ships whole
    assert len(s.delta_since(s)) == 0  # no new samples -> empty delta

    # pushing base + delta must equal the full store (no double counting)
    base.merge(delta)
    full, merged = s.entry("op", "be", "<s>"), base.entry("op", "be", "<s>")
    assert merged.count == full.count
    assert merged.mean_s == pytest.approx(full.mean_s)
    assert merged.m2 == pytest.approx(full.m2)
    assert merged.min_s == full.min_s


# ---------------------------------------------------------------------------
# FleetStore: push merge, pull fallback ordering, gc retention
# ---------------------------------------------------------------------------


def test_push_welford_merges_into_bucket(tmp_path):
    fs = FleetStore(str(tmp_path))
    r1 = fs.push(_store([0.001, 0.003]), "sha1", "chipA")
    r2 = fs.push(_store([0.002]), "sha1", "chipA")
    assert (r1["merged_samples"], r2["merged_samples"]) == (2, 1)
    assert r2["samples"] == 3 and r2["pushes"] == 2
    pulled = fs.pull("sha1", "chipA")
    store = ProfileStore.from_json(json.dumps(pulled["store"]))
    e = store.entry("op", "be", "<s>")
    assert e.count == 3 and e.min_s == 0.001
    assert e.mean_s == pytest.approx(0.002)


def test_push_requires_key(tmp_path):
    fs = FleetStore(str(tmp_path))
    with pytest.raises(ValueError):
        fs.push(_store([0.001]), "", "chipA")


def test_push_stamps_unstamped_entries_with_bucket_key(tmp_path):
    """Unstamped samples adopt the declared bucket provenance on push, so a
    later chip-only fallback pull can age them out instead of trusting them
    across code changes."""
    fs = FleetStore(str(tmp_path))
    fs.push(_store([0.001]), "sha1", "chipA")  # _store default: no stamps
    pulled = fs.pull("other_sha", "chipA")  # chip fallback
    store = ProfileStore.from_json(json.dumps(pulled["store"]))
    e = store.entry("op", "be", "<s>")
    assert e.git_sha == "sha1" and e.chip == "chipA"
    aged = store.age_out(git_sha="other_sha", chip="chipA")
    assert len(aged) == 1  # evictable, not silently trusted


def test_push_dedups_on_source_and_seq(tmp_path):
    """Re-sending an already-recorded (source, seq) must not merge twice —
    the retry protocol for pushes whose response was lost."""
    fs = FleetStore(str(tmp_path))
    r1 = fs.push(_store([0.001, 0.002]), "sha1", "chipA", source="run-a", seq=1)
    r2 = fs.push(_store([0.001, 0.002]), "sha1", "chipA", source="run-a", seq=1)
    assert r1["merged_samples"] == 2 and "duplicate" not in r1
    assert r2["merged_samples"] == 0 and r2["duplicate"] is True
    assert fs.pull("sha1", "chipA")["samples"] == 2
    # a new seq (and other sources) merge normally
    assert fs.push(_store([0.003]), "sha1", "chipA",
                   source="run-a", seq=2)["merged_samples"] == 1
    assert fs.push(_store([0.004]), "sha1", "chipA",
                   source="run-b", seq=1)["merged_samples"] == 1


def test_read_verbs_do_not_create_a_store(tmp_path):
    """A mistyped --fleet path must surface, not mint an empty store: ls/gc
    error, pull reports a plain miss (cold-start bootstrap), and only a push
    creates the root."""
    root = str(tmp_path / "typo")
    fs = FleetStore(root)
    assert fs.pull("sha1", "chipA")["match"] == "miss"
    with pytest.raises(ValueError, match="does not exist"):
        fs.ls()
    with pytest.raises(ValueError, match="does not exist"):
        fs.gc(keep_per_chip=1)
    assert not os.path.exists(root)
    fs.push(_store([0.001]), "sha1", "chipA")
    assert os.path.isdir(root) and fs.ls()


def test_pull_fallback_exact_then_chip_then_miss(tmp_path):
    fs = FleetStore(str(tmp_path))
    fs.push(_store([0.001]), "old_sha", "chipA")
    time.sleep(0.01)
    fs.push(_store([0.002]), "new_sha", "chipA")
    fs.push(_store([0.003]), "new_sha", "chipB")

    # exact beats a fresher same-chip bucket
    assert fs.pull("old_sha", "chipA")["match"] == "exact"
    assert fs.pull("old_sha", "chipA")["git_sha"] == "old_sha"
    # unknown sha: freshest same-chip bucket
    chip = fs.pull("unknown", "chipA")
    assert chip["match"] == "chip" and chip["git_sha"] == "new_sha"
    # unknown chip: miss, store is None
    miss = fs.pull("unknown", "chipZ")
    assert miss["match"] == "miss" and miss["store"] is None


def test_mixed_provenance_never_shadows_real_buckets(tmp_path):
    fs = FleetStore(str(tmp_path))
    fs.push(_store([0.001]), "sha1", "chipA")
    time.sleep(0.01)
    fs.push(_store([0.002]), "mixed", "chipA")  # fresher, unknown provenance
    chip = fs.pull("unknown", "chipA")
    assert chip["match"] == "chip" and chip["git_sha"] == "sha1"
    # a fleet holding ONLY mixed buckets yields a miss, not mixed samples
    fs2 = FleetStore(str(tmp_path / "only_mixed"))
    fs2.push(_store([0.001]), "mixed", "chipA")
    assert fs2.pull("unknown", "chipA")["match"] == "miss"


def test_gc_age_and_per_chip_retention(tmp_path):
    fs = FleetStore(str(tmp_path))
    fs.push(_store([0.001]), "s1", "chipA")
    time.sleep(0.01)
    fs.push(_store([0.002]), "s2", "chipA")
    time.sleep(0.01)
    fs.push(_store([0.003]), "s3", "chipA")
    fs.push(_store([0.004]), "s4", "chipB")
    assert len(fs) == 4

    # staleness: everything is "old" relative to a far-future now except
    # nothing — inject now to make only s1 stale
    t1 = [r for r in fs.ls() if r["git_sha"] == "s1"][0]["pushed_unix"]
    removed = fs.gc(max_age_s=0.005, now=t1 + 0.006)
    assert [r["git_sha"] for r in removed] == ["s1"]

    # retention: keep the newest bucket per chip
    removed = fs.gc(keep_per_chip=1)
    assert sorted(r["git_sha"] for r in removed) == ["s2"]
    assert sorted(r["git_sha"] for r in fs.ls()) == ["s3", "s4"]


def test_slug_collision_safe_keys(tmp_path):
    """Keys that sanitise identically must land in distinct buckets."""
    fs = FleetStore(str(tmp_path))
    fs.push(_store([0.001]), "sha/1", "chip A")
    fs.push(_store([0.002]), "sha?1", "chip\tA")
    assert len(fs) == 2
    assert fs.pull("sha/1", "chip A")["match"] == "exact"
    assert fs.pull("sha?1", "chip\tA")["match"] == "exact"


def test_declared_stamp_unanimous_or_empty():
    unanimous = _store([0.001, 0.002], git_sha="aaaa", chip="tpu-x")
    assert declared_stamp(unanimous) == ("aaaa", "tpu-x")
    disagreeing = _store([0.001], git_sha="aaaa", chip="tpu-x")
    disagreeing.set_stamp(git_sha="bbbb", chip="tpu-x")
    disagreeing.record("op2", "be", "<s>", 0.002)
    assert declared_stamp(disagreeing) == ("", "tpu-x")
    # a unanimous 'mixed' stamp is unknown provenance, not agreement
    laundered = _store([0.001], git_sha="mixed", chip="mixed")
    assert declared_stamp(laundered) == ("", "")


# ---------------------------------------------------------------------------
# HTTP daemon + FleetClient (both transports)
# ---------------------------------------------------------------------------


@pytest.fixture()
def fleet_server(tmp_path):
    server = make_server(str(tmp_path / "fleet_root"), port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        yield server
    finally:
        server.shutdown()
        server.server_close()


def test_http_round_trip(fleet_server):
    client = FleetClient(fleet_server.url)
    assert client.health()["ok"] is True
    res = client.push(_store([0.001, 0.002]), "sha1", "chipA")
    assert res["merged_samples"] == 2
    pulled = client.pull("sha1", "chipA")
    assert pulled["match"] == "exact"
    assert pulled["store"].entry("op", "be", "<s>").count == 2
    assert client.ls()[0]["git_sha"] == "sha1"
    assert [r["git_sha"] for r in client.gc(keep_per_chip=0)] == ["sha1"]
    assert client.ls() == []


def test_http_error_paths(fleet_server):
    client = FleetClient(fleet_server.url)
    with pytest.raises(FleetError, match="400"):
        client.push(_store([0.001]), "", "chipA")  # empty key
    with pytest.raises(FleetError, match="unreachable"):
        FleetClient("http://127.0.0.1:9", timeout=0.5).ls()  # discard port


def test_file_and_http_transports_share_format(fleet_server, tmp_path):
    """A bucket pushed over HTTP is pullable via direct file mode (the
    daemon is an optional front end over the same on-disk store)."""
    FleetClient(fleet_server.url).push(_store([0.001]), "sha1", "chipA")
    direct = FleetClient(str(fleet_server.fleet.root))
    assert direct.pull("sha1", "chipA")["match"] == "exact"
    file_url = FleetClient("file://" + str(fleet_server.fleet.root))
    assert file_url.pull("sha1", "chipA")["match"] == "exact"


# ---------------------------------------------------------------------------
# Authn: --token guards push/gc; pull stays open; 401s counted
# ---------------------------------------------------------------------------


@pytest.fixture()
def auth_server(tmp_path):
    server = make_server(str(tmp_path / "fleet_root"), port=0, token="s3cret")
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        yield server
    finally:
        server.shutdown()
        server.server_close()


def test_token_required_on_push_and_gc(auth_server):
    anon = FleetClient(auth_server.url)
    with pytest.raises(FleetError, match="401"):
        anon.push(_store([0.001]), "sha1", "chipA")
    with pytest.raises(FleetError, match="401"):
        anon.gc(keep_per_chip=0)
    wrong = FleetClient(auth_server.url, token="wrong")
    with pytest.raises(FleetError, match="401"):
        wrong.push(_store([0.001]), "sha1", "chipA")
    # every rejection is counted in the daemon stats
    health = anon.health()
    assert health["auth"] is True
    assert health["stats"]["auth_failures"] == 3
    assert health["stats"]["pushes"] == 0  # nothing landed
    assert len(auth_server.fleet) == 0


def test_token_holder_can_push_and_pull_stays_open(auth_server):
    authed = FleetClient(auth_server.url, token="s3cret")
    assert authed.push(_store([0.001, 0.002]), "sha1", "chipA")["merged_samples"] == 2
    # pull/ls/healthz require no token: a shared fleet warm-starts everyone
    anon = FleetClient(auth_server.url)
    assert anon.pull("sha1", "chipA")["match"] == "exact"
    assert anon.ls()[0]["git_sha"] == "sha1"
    assert authed.gc(keep_per_chip=0)
    stats = anon.health()["stats"]
    assert stats["pushes"] == 1 and stats["gcs"] == 1 and stats["pulls"] == 1
    assert stats["auth_failures"] == 0


def test_cli_serve_token_and_push_flag(tmp_path, capsys):
    """End-to-end through the CLIs: a token-protected daemon rejects
    `fleet push` without --token and accepts it with one."""
    profile = str(tmp_path / "p.json")
    with open(profile, "w") as f:
        f.write(_store([0.001], git_sha="sha1", chip="chipA").to_json())

    server = make_server(str(tmp_path / "root"), port=0, token="tok")
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        assert fleet_main(["push", profile, "--fleet", server.url]) == 1
        assert "401" in capsys.readouterr().err
        assert fleet_main(["push", profile, "--fleet", server.url,
                           "--token", "tok"]) == 0
        assert json.loads(capsys.readouterr().out)["merged_samples"] == 1
        assert fleet_main(["ls", "--fleet", server.url]) == 0  # open without token
    finally:
        server.shutdown()
        server.server_close()


def test_unauthorized_pusher_degrades_not_crashes(auth_server):
    """A FleetPusher with a bad token behaves like an unreachable fleet:
    best-effort failure, delta retained for retry."""
    live = ProfileStore()
    pusher = FleetPusher(FleetClient(auth_server.url), live, "sha1", "chipA")
    live.record("op", "be", "<s>", 0.001)
    res = pusher.push()
    assert res["pushed"] is False and "401" in res["error"]
    assert pusher.pushed_samples == 0
    # fixing the token on the same client delivers the retained delta
    pusher.client = FleetClient(auth_server.url, token="s3cret")
    assert pusher.push()["pushed"] is True
    assert pusher.pushed_samples == 1


def test_concurrent_http_pushes_lose_no_samples(fleet_server):
    """The satellite stress test: concurrent overlapping pushes must
    Welford-merge losslessly (count, mean and min all exact)."""
    samples = [0.001, 0.002, 0.003, 0.004, 0.005]
    workers, pushes = 4, 6

    def worker():
        client = FleetClient(fleet_server.url)
        for _ in range(pushes):
            client.push(_store(samples), "sha1", "chipA")

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    pulled = FleetClient(fleet_server.url).pull("sha1", "chipA")
    e = pulled["store"].entry("op", "be", "<s>")
    assert e.count == workers * pushes * len(samples)
    assert e.mean_s == pytest.approx(sum(samples) / len(samples))
    assert e.min_s == min(samples)
    assert pulled["samples"] == e.count


def test_concurrent_direct_clients_lose_no_samples(tmp_path):
    """Direct-path mode from independent clients (separate FleetStore
    instances, so only the advisory flock serialises them)."""
    root = str(tmp_path / "root")
    samples = [0.001, 0.002]
    workers, pushes = 4, 5

    def worker():
        client = FleetClient(root)  # own FleetStore, own threading.Lock
        for _ in range(pushes):
            client.push(_store(samples), "sha1", "chipA")

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    e = FleetClient(root).pull("sha1", "chipA")["store"].entry("op", "be", "<s>")
    assert e.count == workers * pushes * len(samples)


# ---------------------------------------------------------------------------
# FleetPusher: delta pushes never double-count
# ---------------------------------------------------------------------------


def test_pusher_deltas_never_double_count(tmp_path):
    client = FleetClient(str(tmp_path))
    live = _store([0.004])
    pusher = FleetPusher(client, live, "sha1", "chipA")
    # samples present at pusher creation are the baseline (e.g. just pulled
    # from the fleet) and must NOT be echoed back
    assert pusher.push()["pushed"] is False

    live.record("op", "be", "<s>", 0.005)
    live.record("op2", "be", "<s>", 0.006)
    assert pusher.push()["pushed"] is True
    assert pusher.push()["pushed"] is False  # idempotent: no new samples
    live.record("op", "be", "<s>", 0.007)
    assert pusher.push()["merged_samples"] == 1

    pulled = client.pull("sha1", "chipA")
    assert pulled["store"].entry("op", "be", "<s>").count == 2  # 0.005, 0.007
    assert pulled["store"].entry("op2", "be", "<s>").count == 1
    assert pusher.pushed_samples == 3


def test_pusher_retry_after_lost_response_is_exactly_once(tmp_path):
    """A push that LANDED but whose response was lost (timeout) must not be
    Welford-merged twice: the pusher retries the same (delta, seq) and the
    fleet acknowledges it as a duplicate."""

    class LossyClient(FleetClient):
        def __init__(self, target):
            super().__init__(target)
            self.lose_next_response = False

        def push(self, *a, **kw):
            res = super().push(*a, **kw)
            if self.lose_next_response:
                self.lose_next_response = False
                raise FleetError("response lost after the server applied it")
            return res

    client = LossyClient(str(tmp_path / "fleet"))
    live = ProfileStore()
    pusher = FleetPusher(client, live, "sha1", "chipA")

    live.record("op", "be", "<s>", 0.001)
    client.lose_next_response = True
    res = pusher.push()
    assert res["pushed"] is False and "error" in res  # ambiguous outcome

    live.record("op", "be", "<s>", 0.002)  # recorded while delta pending
    assert pusher.push()["pushed"] is True  # retried delta deduped server-side
    assert pusher.push()["pushed"] is True  # then the 0.002 delta

    e = FleetClient(str(tmp_path / "fleet")).pull("sha1", "chipA")["store"] \
        .entry("op", "be", "<s>")
    assert e.count == 2  # exactly once despite the lost response
    assert e.mean_s == pytest.approx(0.0015)


def test_pusher_unreachable_fleet_keeps_baseline(tmp_path):
    live = ProfileStore()
    pusher = FleetPusher(FleetClient("http://127.0.0.1:9", timeout=0.5),
                         live, "sha1", "chipA")
    live.record("op", "be", "<s>", 0.001)
    res = pusher.push()
    assert res["pushed"] is False and "error" in res
    with pytest.raises(FleetError):
        pusher.push(raise_on_error=True)
    # a recovered fleet receives the missed samples on the next push
    pusher.client = FleetClient(str(tmp_path))
    assert pusher.push()["merged_samples"] == 1


def test_file_mode_io_errors_become_fleet_errors(tmp_path):
    """Direct-path verbs must normalise OSErrors to FleetError, so drivers
    degrade (log / start cold / retry next rotation) instead of crashing."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("occupied")  # root path collides with a regular file
    client = FleetClient(str(blocker))
    with pytest.raises(FleetError):
        client.push(_store([0.001]), "sha1", "chipA")
    # a pusher on the same target degrades best-effort instead of raising
    live = _store([0.001])
    pusher = FleetPusher(client, live, "sha1", "chipA")
    live.record("op", "be", "<s>", 0.002)
    res = pusher.push()
    assert res["pushed"] is False and "error" in res


# ---------------------------------------------------------------------------
# Driver wiring (warm_start_from_fleet) + CLI
# ---------------------------------------------------------------------------


def test_warm_start_pull_exact_then_stale_sha_reexplores(tmp_path):
    from repro.dispatch import DispatchConfig, Dispatcher
    from repro.trace.session import git_sha

    root = str(tmp_path / "fleet")
    disp = Dispatcher(DispatchConfig(policy="profiled"))
    sha, chip = git_sha(), disp.chip.name

    # empty fleet: miss, still returns a usable pusher
    rec, pusher = warm_start_from_fleet(root, disp)
    assert rec["pull"]["match"] == "miss"
    disp.store.record("op", "be", "<s>", 0.001)
    assert pusher.push()["merged_samples"] == 1

    # exact match warm start: entries survive age-out
    disp2 = Dispatcher(DispatchConfig(policy="profiled"))
    rec2, _ = warm_start_from_fleet(root, disp2)
    assert rec2["pull"] == {"match": "exact", "bucket_git_sha": sha,
                            "bucket_chip": chip, "entries": 1,
                            "merged_samples": 1, "aged_out": 0}
    assert disp2.store.samples("op", "be", "<s>") == 1

    # stale-SHA bucket: chip fallback pulls it, age-out evicts everything —
    # the dispatcher re-explores rather than trusting stale timings
    stale_root = str(tmp_path / "stale")
    stale = _store([0.002], git_sha="0000000", chip=chip)
    FleetClient(stale_root).push(stale, "0000000", chip)
    disp3 = Dispatcher(DispatchConfig(policy="profiled"))
    rec3, _ = warm_start_from_fleet(stale_root, disp3)
    assert rec3["pull"]["match"] == "chip"
    assert rec3["pull"]["aged_out"] == 1
    assert len(disp3.store) == 0

    # unreachable fleet: cold start, no crash
    disp4 = Dispatcher(DispatchConfig(policy="profiled"))
    rec4, pusher4 = warm_start_from_fleet("http://127.0.0.1:9", disp4)
    assert rec4["pull"]["match"] == "error" and pusher4 is not None


def test_stale_fleet_pull_never_destroys_valid_local_profiles(tmp_path):
    """A chip-only fallback bucket must be age-filtered BEFORE merging:
    merging first would degrade overlapping locally-valid entries (e.g.
    loaded via --profile-in) to 'mixed' and the age-out would then evict the
    driver's own good warm-start data."""
    from repro.dispatch import DispatchConfig, Dispatcher
    from repro.trace.session import git_sha

    disp = Dispatcher(DispatchConfig(policy="profiled"))
    sha, chip = git_sha(), disp.chip.name
    # valid local warm-start samples, stamped with the current environment
    for x in (0.001, 0.002, 0.003, 0.004, 0.005):
        disp.store.record("op", "be", "<s>", x)

    # fleet only holds an older-SHA same-chip bucket sharing the key
    root = str(tmp_path / "fleet")
    FleetClient(root).push(_store([0.9], git_sha="0000000", chip=chip),
                           "0000000", chip)

    rec, _ = warm_start_from_fleet(root, disp)
    assert rec["pull"]["match"] == "chip"
    assert rec["pull"]["aged_out"] == 1  # only the stale fleet entry
    e = disp.store.entry("op", "be", "<s>")
    assert e is not None and e.count == 5  # local samples fully intact
    assert e.git_sha == sha  # never degraded to 'mixed'
    assert e.min_s == 0.001  # the stale 0.9s sample never merged in


def test_cli_push_pull_ls_gc_round_trip(tmp_path, capsys):
    root = str(tmp_path / "fleet")
    src = str(tmp_path / "profiles.json")
    with open(src, "w") as f:
        f.write(_store([0.001, 0.002], git_sha="sha1", chip="chipA").to_json())

    # push derives the bucket key from the store's unanimous stamps
    assert fleet_main(["push", src, "--fleet", root, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["git_sha"] == "sha1" and out["chip"] == "chipA"
    assert out["merged_samples"] == 2

    dst = str(tmp_path / "pulled.json")
    assert fleet_main(["pull", "--fleet", root, "--git-sha", "sha1",
                       "--chip", "chipA", "-o", dst]) == 0
    restored = ProfileStore.from_json(open(dst).read())
    assert restored.entry("op", "be", "<s>").count == 2

    assert fleet_main(["pull", "--fleet", root, "--git-sha", "nope",
                       "--chip", "nochip"]) == EXIT_MISS
    assert "match=exact" in capsys.readouterr().out  # drain the pull chatter

    assert fleet_main(["ls", "--fleet", root, "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)["snapshots"]
    assert len(rows) == 1 and rows[0]["samples"] == 2

    assert fleet_main(["gc", "--fleet", root, "--keep-per-chip", "0",
                       "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["removed"]
    assert fleet_main(["ls", "--fleet", root, "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["snapshots"] == []


def test_cli_push_refuses_ambiguous_provenance(tmp_path, capsys):
    """Foreign/unstamped samples must not be silently keyed to the current
    environment (they would become a trusted exact-match warm start)."""
    src = str(tmp_path / "unstamped.json")
    with open(src, "w") as f:
        f.write(_store([0.001]).to_json())  # no stamps at all
    root = str(tmp_path / "fleet")
    assert fleet_main(["push", src, "--fleet", root]) == 1
    assert "provenance" in capsys.readouterr().err
    # explicit flags resolve the ambiguity
    assert fleet_main(["push", src, "--fleet", root,
                       "--git-sha", "sha1", "--chip", "chipA"]) == 0
    assert FleetClient(root).pull("sha1", "chipA")["match"] == "exact"


def test_push_profiles_refuses_fleet_connected_run_without_force(tmp_path, capsys):
    """An artifact of a run that already fed a fleet live (delta pushes)
    must not be re-pushed wholesale — that would double-count every sample."""
    from repro.trace import StreamingSession, TraceCollector
    from repro.trace.cli import main as trace_main

    store = _store([0.001, 0.002], git_sha="sha1", chip="chipA")
    root = str(tmp_path / "fleet")
    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, meta={"fleet": root},
                              store_provider=lambda: store).attach(col)
    col.record("mark", "m", 0)
    stream.close(stats=col.stats())

    assert trace_main(["push-profiles", d, "--fleet", root]) == 1
    assert "double-count" in capsys.readouterr().err
    assert trace_main(["push-profiles", d, "--fleet", root, "--force",
                       "--git-sha", "sha1", "--chip", "chipA"]) == 0
    assert FleetClient(root).pull("sha1", "chipA")["match"] == "exact"
    # a DIFFERENT fleet never received the live deltas: warn, don't refuse
    other = str(tmp_path / "other_fleet")
    assert trace_main(["push-profiles", d, "--fleet", other,
                       "--git-sha", "sha1", "--chip", "chipA"]) == 0
    assert "warning" in capsys.readouterr().err


def test_cli_push_rejects_profile_free_sources(tmp_path, capsys):
    bogus = str(tmp_path / "chrome.json")
    with open(bogus, "w") as f:
        json.dump({"traceEvents": []}, f)
    assert fleet_main(["push", bogus, "--fleet", str(tmp_path / "r")]) == 1


def test_cli_push_refuses_profile_out_of_fleet_connected_run(tmp_path, capsys):
    """--profile-out files written by a --fleet run carry a 'fleet' marker;
    re-pushing them wholesale is refused (the run already pushed deltas)."""
    root = str(tmp_path / "fleet")
    store = _store([0.001], git_sha="sha1", chip="chipA")
    doc = json.loads(store.to_json())
    doc["fleet"] = root  # what the drivers write
    src = str(tmp_path / "profiles.json")
    with open(src, "w") as f:
        json.dump(doc, f)
    assert fleet_main(["push", src, "--fleet", root]) == 1
    assert "double-count" in capsys.readouterr().err
    assert fleet_main(["push", src, "--fleet", root, "--force"]) == 0


def test_trace_cli_push_profiles_backfills_from_stream_dir(tmp_path, capsys):
    from repro.trace import StreamingSession, TraceCollector
    from repro.trace.cli import main as trace_main

    store = _store([0.001, 0.002], git_sha="sess_sha", chip="sess_chip")
    d = str(tmp_path / "run")
    col = TraceCollector()
    stream = StreamingSession(d, store_provider=lambda: store).attach(col)
    col.record("mark", "m", 0)
    stream.close(stats=col.stats())

    root = str(tmp_path / "fleet")
    assert trace_main(["push-profiles", d, "--fleet", root,
                       "--git-sha", "sess_sha", "--chip", "sess_chip"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["merged_samples"] == 2
    assert FleetClient(root).pull("sess_sha", "sess_chip")["match"] == "exact"


def test_trace_cli_push_profiles_defaults_key_from_session(tmp_path, capsys):
    """Backfilling a --trace-out session uses the session's own git SHA and
    chip as the bucket key."""
    from repro.core.events import EventLog
    from repro.trace import Session
    from repro.trace.cli import main as trace_main

    log = EventLog()
    log.record("mark", "m", 0)
    sess = Session.capture(log, store=_store([0.001]))
    sess.chip = {"name": "tpu_test"}
    p = sess.save(str(tmp_path / "s.json"))

    root = str(tmp_path / "fleet")
    assert trace_main(["push-profiles", p, "--fleet", root]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["chip"] == "tpu_test"
    assert out["git_sha"] == sess.meta["git_sha"]


# ---------------------------------------------------------------------------
# End-to-end: the two-process warm-start demo (acceptance criterion)
# ---------------------------------------------------------------------------


def _run_serve(fleet: str, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-0.5b",
         "--reduced", "--requests", "4", "--max-new", "6",
         "--dispatch", "profiled", "--fleet", fleet, *extra],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout)


def test_two_process_fleet_warm_start(tmp_path):
    """Run 1 (cold) explores and pushes; run 2 pulls an exact match and
    reports zero exploration dispatches in its driver JSON."""
    fleet = str(tmp_path / "fleet_store")
    r1 = _run_serve(fleet)
    assert r1["fleet"]["pull"]["match"] == "miss"
    assert r1["dispatch"]["explore_dispatches"] > 0
    assert r1["fleet"]["push"]["pushed_samples"] > 0

    r2 = _run_serve(fleet)
    assert r2["fleet"]["pull"]["match"] == "exact"
    assert r2["dispatch"]["explore_dispatches"] == 0


def test_healthz_and_metrics_share_one_counter_source(auth_server):
    """After a 401, the /healthz stats and the Prometheus /metrics series
    must agree — both read the same MetricsRegistry counters."""
    import urllib.request

    anon = FleetClient(auth_server.url)
    with pytest.raises(FleetError, match="401"):
        anon.push(_store([0.001]), "sha1", "chipA")
    assert anon.health()["stats"]["auth_failures"] == 1
    with urllib.request.urlopen(auth_server.url + "/metrics") as r:
        assert r.status == 200
        assert r.headers["Content-Type"].startswith("text/plain")
        text = r.read().decode()
    assert "repro_fleet_auth_failures_total 1" in text
    assert "repro_fleet_pushes_total 0" in text
    assert "repro_fleet_snapshots 0" in text
    # a successful authed push moves BOTH surfaces in lockstep
    FleetClient(auth_server.url, token="s3cret").push(
        _store([0.001]), "sha1", "chipA")
    assert anon.health()["stats"]["pushes"] == 1
    with urllib.request.urlopen(auth_server.url + "/metrics") as r:
        text = r.read().decode()
    assert "repro_fleet_pushes_total 1" in text
    assert "repro_fleet_snapshots 1" in text


# ---------------------------------------------------------------------------
# Audit log: every successful push/gc leaves a record
# ---------------------------------------------------------------------------


def test_audit_records_push_and_gc(fleet_server):
    from repro.fleet.service import read_audit

    client = FleetClient(fleet_server.url)
    client.push(_store([0.001, 0.002]), "sha1", "chipA")
    client.gc(keep_per_chip=0)
    recs = read_audit(str(fleet_server.fleet.root))
    assert [r["verb"] for r in recs] == ["push", "gc"]
    push_rec, gc_rec = recs
    assert push_rec["git_sha"] == "sha1" and push_rec["chip"] == "chipA"
    assert push_rec["entries"] == 1 and push_rec["merged_samples"] == 2
    assert push_rec["addr"] == "127.0.0.1"
    assert "token_sha" not in push_rec  # tokenless daemon: no digest
    assert [b["git_sha"] for b in gc_rec["removed"]] == ["sha1"]
    # reads never touch the audit log, and rejected pushes leave no record
    client.pull("sha1", "chipA")
    with pytest.raises(FleetError, match="400"):
        client.push(_store([0.001]), "", "chipA")
    assert len(read_audit(str(fleet_server.fleet.root))) == 2


def test_audit_token_digest_not_secret(auth_server):
    import hashlib

    from repro.fleet.service import read_audit

    FleetClient(auth_server.url, token="s3cret").push(
        _store([0.001]), "sha1", "chipA")
    # a rejected anonymous push must not be audited
    with pytest.raises(FleetError, match="401"):
        FleetClient(auth_server.url).push(_store([0.001]), "sha2", "chipA")
    (rec,) = read_audit(str(auth_server.fleet.root))
    assert rec["token_sha"] == hashlib.sha256(b"s3cret").hexdigest()[:12]
    raw = open(auth_server.audit_path).read()
    assert "s3cret" not in raw  # the secret itself never lands on disk


def test_audit_cli_tails_and_handles_missing(fleet_server, tmp_path, capsys):
    root = str(fleet_server.fleet.root)
    # empty store: friendly message, exit 0
    assert fleet_main(["audit", "--root", root]) == 0
    assert "(no audit records)" in capsys.readouterr().out
    client = FleetClient(fleet_server.url)
    for i in range(3):
        client.push(_store([0.001]), f"sha{i}", "chipA")
    assert fleet_main(["audit", "--root", root, "-n", "2", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert [r["git_sha"] for r in doc["records"]] == ["sha1", "sha2"]
    # human-readable table renders every verb
    client.gc(keep_per_chip=1)
    assert fleet_main(["audit", "--root", root]) == 0
    out = capsys.readouterr().out
    assert "push" in out and "gc" in out and "sha2" in out


# ---------------------------------------------------------------------------
# Per-source rate quotas: token bucket on push/gc; 429s counted + audited
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_rate_quota_bucket_spend_and_refill():
    from repro.fleet.service import RateQuota

    clk = _FakeClock()
    q = RateQuota(rps=1.0, burst=2, clock=clk)
    assert q.allow("a") == (True, False)
    assert q.allow("a") == (True, False)
    # bucket empty: denied, and the FIRST denial starts the audit episode
    assert q.allow("a") == (False, True)
    assert q.allow("a") == (False, False)
    clk.t += 1.0  # one token refilled at 1 req/s
    assert q.allow("a") == (True, False)
    assert q.allow("a")[0] is False


def test_rate_quota_per_source_and_lru_fails_open():
    from repro.fleet.service import RateQuota

    clk = _FakeClock()
    q = RateQuota(rps=1.0, burst=1, clock=clk, max_sources=2)
    assert q.allow("a")[0] is True
    assert q.allow("b")[0] is True  # b's bucket independent of a's spend
    assert q.allow("a") == (False, True)
    # touching two new sources evicts 'a' (LRU); it comes back with a full
    # bucket — eviction fails open, never spuriously throttles
    q.allow("c")
    q.allow("d")
    assert q.allow("a")[0] is True


def test_rate_quota_validates_params():
    from repro.fleet.service import RateQuota

    with pytest.raises(ValueError):
        RateQuota(0)
    with pytest.raises(ValueError):
        RateQuota(-1.0)
    with pytest.raises(ValueError):
        RateQuota(1.0, burst=0.5)


@pytest.fixture()
def quota_server(tmp_path):
    from repro.fleet.service import make_server as mk

    server = mk(str(tmp_path / "fleet_root"), port=0, quota_rps=1.0,
                quota_burst=2)
    server.quota.clock = _FakeClock()  # frozen: no refill unless advanced
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        yield server
    finally:
        server.shutdown()
        server.server_close()


def test_quota_throttles_push_with_429_counted_and_audited(quota_server):
    from repro.fleet.service import read_audit

    client = FleetClient(quota_server.url)
    client.push(_store([0.001, 0.002]), "sha1", "chipA")
    client.push(_store([0.003, 0.004]), "sha1", "chipA")
    for _ in range(2):
        with pytest.raises(FleetError, match="429"):
            client.push(_store([0.005]), "sha1", "chipA")
    health = client.health()
    assert health["stats"]["pushes"] == 2
    assert health["stats"]["throttled"] == 2
    # reads never spend quota: a fleet-warmed driver must always pull
    assert client.pull("sha1", "chipA")["match"] == "exact"
    assert client.ls()
    # one audit record per throttle EPISODE, not per denied request
    throttles = [r for r in read_audit(str(quota_server.fleet.root))
                 if r["verb"] == "throttle"]
    assert len(throttles) == 1
    assert throttles[0]["path"] == "/v1/push"
    assert throttles[0]["rps"] == 1.0
    # refill ends the episode; the next denial starts (and audits) a new one
    quota_server.quota.clock.t += 1.0
    client.push(_store([0.006]), "sha1", "chipA")
    with pytest.raises(FleetError, match="429"):
        client.gc(keep_per_chip=1)  # gc shares the same per-source bucket
    throttles = [r for r in read_audit(str(quota_server.fleet.root))
                 if r["verb"] == "throttle"]
    assert len(throttles) == 2
    assert throttles[1]["path"] == "/v1/gc"
