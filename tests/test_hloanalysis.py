"""Trip-count-aware HLO analyzer: validated against straight-line ground truth
(the analyzer's whole reason to exist is that XLA's cost_analysis prices loop
bodies once; the scanned-vs-unrolled agreement test pins that correction)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hloanalysis import analyze_hlo_text


def test_plain_matmul_flops():
    f = lambda a, b: a @ b
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.bfloat16),
        jax.ShapeDtypeStruct((256, 512), jnp.bfloat16),
    ).compile()
    r = analyze_hlo_text(c.as_text(), 1)
    want = 2 * 128 * 256 * 512
    assert want <= r["flops"] <= want * 1.05


def test_scan_multiplies_body_costs():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze_hlo_text(c.as_text(), 1)
    want = 7 * 2 * 64**3
    assert want <= r["flops"] <= want * 1.1
    # XLA's own analysis counts the body once — i.e. ~7x lower
    xla_cost = c.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):  # older jax returns [dict]
        xla_cost = xla_cost[0]
    assert r["flops"] > 5 * xla_cost["flops"]


def test_scanned_vs_unrolled_model_agree():
    """Lower the same reduced model scanned and unrolled: per-device FLOPs
    from the analyzer must agree within a few percent."""
    from repro.configs import get_config, reduced
    from repro.models import lm

    base = reduced(get_config("smollm-360m"), layers=4)
    tokens = jax.ShapeDtypeStruct((2, 32), jnp.int32)
    params = lm.abstract_params(base)

    flops = {}
    for scan in (True, False):
        cfg = dataclasses.replace(base, scan_layers=scan)

        def step(p, t):
            return lm.loss_fn(p, cfg, t, t)[0]

        c = jax.jit(step).lower(params, tokens).compile()
        flops[scan] = analyze_hlo_text(c.as_text(), 1)["flops"]
    assert flops[True] == pytest.approx(flops[False], rel=0.05), flops


def test_collectives_inside_scan_are_multiplied():
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (covered by dry-run subprocess tests)")
    mesh = Mesh(np.array(jax.devices()[:2]).reshape(1, 2), ("data", "model"))

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=5)
        return c.sum()

    c = jax.jit(
        f,
        in_shardings=(
            NamedSharding(mesh, P(None, "model")),
            NamedSharding(mesh, P("model", None)),
        ),
    ).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
    ).compile()
    r = analyze_hlo_text(c.as_text(), 2)
    # one AR of (64,128) f32 per trip, 2 devices: 2*S*(n-1)/n = S
    per_trip = 64 * 128 * 4
    assert r["coll_by_op"].get("all-reduce", 0) >= 5 * per_trip * 0.9
