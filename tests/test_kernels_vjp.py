"""Flash custom-VJP: forward AND gradients match plain-AD-through-oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_vjp import flash_attention_fused

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize(
    "window,softcap,q_offset",
    [(None, None, 0), (16, None, 0), (None, 30.0, 0), (16, 50.0, 0), (None, None, 24)],
)
def test_flash_vjp_matches_oracle_grads(window, softcap, q_offset):
    B, Sq, Hq, Hkv, D = 2, 40, 4, 2, 16
    Sk = Sq + q_offset
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D))
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D))
    cot = jax.random.normal(ks[3], (B, Sq, Hq, D))

    def loss_ref(q, k, v):
        o = ref.mha_ref(q, k, v, causal=True, window=window, softcap=softcap, q_offset=q_offset)
        return jnp.sum(o * cot)

    def loss_flash(q, k, v):
        o = flash_attention_fused(
            q, k, v, True, window, softcap, None, q_offset, 16
        )
        return jnp.sum(o * cot)

    o_ref = ref.mha_ref(q, k, v, causal=True, window=window, softcap=softcap, q_offset=q_offset)
    o_fl = flash_attention_fused(q, k, v, True, window, softcap, None, q_offset, 16)
    np.testing.assert_allclose(o_fl, o_ref, atol=2e-5, rtol=2e-5)

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_fl, g_ref, "qkv"):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4, err_msg=f"d{name}")


def test_flash_vjp_no_quadratic_residuals():
    """The point of the custom VJP: no (Sq, Sk) tensor survives to backward.
    Verified structurally: residual sizes scale O(S·D), not O(S²)."""
    B, S, H, D = 1, 256, 2, 8

    def run(S):
        q = jnp.ones((B, S, H, D))
        out, vjp = jax.vjp(
            lambda q: flash_attention_fused(q, q, q, True, None, None, None, 0, 64), q
        )
        res_bytes = sum(
            np.prod(x.shape) * x.dtype.itemsize
            for x in jax.tree.leaves(vjp)
            if hasattr(x, "shape")
        )
        return res_bytes

    b1, b2 = run(S), run(2 * S)
    assert b2 < b1 * 3, (b1, b2)  # linear-ish growth, not 4x (quadratic)
