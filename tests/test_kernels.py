"""Per-kernel allclose sweeps: Pallas (interpret=True) vs. the pure-jnp oracle,
plus the chunked production paths vs. the same oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_gmm import gmm
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(42)


def tols(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,D,window,softcap",
    [
        (2, 64, 64, 4, 4, 16, None, None),      # MHA
        (2, 64, 64, 4, 2, 16, None, None),      # GQA
        (1, 96, 96, 4, 1, 32, None, None),      # MQA, non-pow2 seq
        (2, 64, 64, 4, 2, 16, 16, None),        # sliding window
        (2, 64, 64, 4, 2, 16, None, 30.0),      # softcap (gemma2)
        (2, 64, 64, 4, 2, 16, 16, 50.0),        # both
        (1, 40, 40, 2, 2, 8, None, None),       # ragged (padding path)
    ],
)
def test_flash_attention_vs_oracle(B, Sq, Sk, Hq, Hkv, D, window, softcap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    want = ref.mha_ref(q, k, v, causal=True, window=window, softcap=softcap)
    got = flash_attention(
        q, k, v, causal=True, window=window, softcap=softcap,
        block_q=32, block_k=32, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tols(dtype)
    )


def test_flash_attention_q_offset():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 16, 4, 16))
    k = jax.random.normal(ks[1], (2, 80, 2, 16))
    v = jax.random.normal(ks[2], (2, 80, 2, 16))
    want = ref.mha_ref(q, k, v, causal=True, q_offset=64)
    got = flash_attention(q, k, v, causal=True, q_offset=64, block_q=16, block_k=32, interpret=True)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_chunked_and_local_vs_oracle():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    for window, cap in [(None, None), (24, None), (24, 40.0)]:
        want = ref.mha_ref(q, k, v, causal=True, window=window, softcap=cap)
        got = ref.flash_attention_chunked(q, k, v, causal=True, window=window, softcap=cap, block_k=24)
        np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)
        if window:
            got2 = ref.local_window_attention(q, k, v, window=window, softcap=cap, block_q=16)
            np.testing.assert_allclose(got2, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(None, None), (8, None), (None, 30.0)])
def test_decode_attention_vs_oracle(window, softcap, dtype):
    B, Hq, Hkv, D, S = 2, 4, 2, 16, 40
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cur = jnp.array([S - 1, 17])
    want = ref.decode_attention_ref(q, kc, vc, pos, cur, window=window, softcap=softcap)
    got = decode_attention(
        q, kc, vc, pos, cur, window=window, softcap=softcap, block_s=16, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tols(dtype)
    )


def test_decode_attention_ring_buffer_semantics():
    """Slot-position masking must equal attention over the positions present."""
    B, Hq, Hkv, D, S, W = 1, 2, 1, 8, 8, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    # ring holding positions 10..17 in wrapped order, cur=17, window 6
    pos = jnp.asarray([[16, 17, 10, 11, 12, 13, 14, 15]])
    cur = jnp.array([17])
    got = ref.decode_attention_ref(q, kc, vc, pos, cur, window=6)
    # manual: valid slots are pos in (11..17]
    mask = (pos[0] > 17 - 6)
    qf = q.reshape(B, Hkv, 2, D) / np.sqrt(D)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kc)
    s = jnp.where(mask[None, None, None], s, -1e30)
    want = jnp.einsum("bhgs,bshd->bhgd", jax.nn.softmax(s, -1), vc).reshape(B, Hq, D)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(4, 16, 32, 24), (2, 20, 24, 12), (8, 8, 8, 8)])
def test_gmm_vs_oracle(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    want = ref.gmm_ref(x, w)
    got = gmm(x, w, block_c=8, block_f=8, block_d=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tols(dtype)
    )


def test_gmm_fused_epilogue():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (2, 8, 16))
    w = jax.random.normal(ks[1], (2, 16, 8))
    want = jax.nn.silu(ref.gmm_ref(x, w).astype(jnp.float32))
    got = gmm(x, w, block_c=8, block_f=8, block_d=8, epilogue="silu", interpret=True)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,K,chunk", [(2, 64, 3, 8, 16), (1, 32, 2, 16, 32), (2, 48, 1, 8, 16)])
def test_rwkv6_vs_oracle(B, T, H, K, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, K), dtype)
    k = jax.random.normal(ks[1], (B, T, H, K), dtype)
    v = jax.random.normal(ks[2], (B, T, H, K), dtype)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.5)).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, K)) * 0.5).astype(dtype)
    s0 = (jax.random.normal(ks[5], (B, H, K, K)) * 0.1).astype(jnp.float32)
    want_o, want_s = ref.rwkv6_scan_ref(r, k, v, w.astype(dtype), u, s0)
    got_o, got_s = rwkv6_scan(r, k, v, w.astype(dtype), u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_o, np.float32), np.asarray(want_o, np.float32), **tols(dtype)
    )
    np.testing.assert_allclose(got_s, want_s, **tols(dtype))
    # chunked jnp production path too
    got2_o, got2_s = ref.rwkv6_scan_chunked(r, k, v, w.astype(dtype), u, s0, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(got2_o, np.float32), np.asarray(want_o, np.float32), **tols(dtype)
    )


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,DI,N,chunk,bdi", [(2, 64, 12, 4, 16, 4), (1, 32, 8, 8, 32, 8)])
def test_mamba_vs_oracle(B, T, DI, N, chunk, bdi, dtype):
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (B, T, DI), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, DI))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (DI, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N), dtype)
    C = jax.random.normal(ks[4], (B, T, N), dtype)
    D = jax.random.normal(ks[5], (DI,), jnp.float32)
    h0 = (jax.random.normal(ks[6], (B, DI, N)) * 0.1).astype(jnp.float32)
    want_y, want_h = ref.mamba_scan_ref(x, dt, A, Bm, C, D, h0)
    got_y, got_h = mamba_scan(x, dt, A, Bm, C, D, h0, chunk=chunk, block_di=bdi, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), **tols(dtype)
    )
    np.testing.assert_allclose(got_h, want_h, **tols(dtype))
    got2_y, got2_h = ref.mamba_scan_chunked(x, dt, A, Bm, C, D, h0, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(got2_y, np.float32), np.asarray(want_y, np.float32), **tols(dtype)
    )


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 6, 32), (16, 128), (3, 7)])
def test_rmsnorm_vs_oracle(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    s = (jax.random.normal(ks[1], (shape[-1],)) * 0.1).astype(jnp.float32)
    want = ref.rmsnorm_ref(x, s)
    got = rmsnorm(x, s, block_rows=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tols(dtype)
    )


# ---------------------------------------------------------------------------
# ops dispatch + decode single-step helpers
# ---------------------------------------------------------------------------


def test_ops_decode_steps_match_scans():
    from repro.kernels import ops

    B, T, H, K = 2, 8, 2, 8
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, K)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.5))
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    s = jnp.zeros((B, H, K, K))
    want, want_s = ref.rwkv6_scan_ref(r, k, v, w, u, s)
    out = []
    st = s
    for t in range(T):
        o, st = ops.rwkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, st)
        out.append(o)
    np.testing.assert_allclose(jnp.stack(out, 1), want, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(st, want_s, atol=2e-5, rtol=2e-5)

    DI, N = 8, 4
    x = jax.random.normal(ks[0], (B, T, DI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, DI)))
    A = -jnp.exp(jax.random.normal(ks[2], (DI, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    C = jax.random.normal(ks[4], (B, T, N))
    D = jax.random.normal(ks[5], (DI,))
    h = jnp.zeros((B, DI, N))
    want_y, want_h = ref.mamba_scan_ref(x, dt, A, Bm, C, D, h)
    ys = []
    for t in range(T):
        y, h = ops.mamba_step(x[:, t], dt[:, t], A, Bm[:, t], C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), want_y, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(h, want_h, atol=2e-5, rtol=2e-5)
