"""SDFG IR extraction + backend assignment (the Fig. 1 machinery)."""
import jax
import jax.numpy as jnp

from repro.core import sdfg
from repro.hw.specs import TPU_V5E


def test_backend_classification():
    assert sdfg.classify("dot_general") == sdfg.MXU
    assert sdfg.classify("add") == sdfg.VPU
    assert sdfg.classify("gather") == sdfg.HBM
    assert sdfg.classify("psum") == sdfg.ICI
    assert sdfg.classify("debug_callback") == sdfg.HOST


def test_extract_matmul_region_assignment():
    def f(a, b):
        with jax.named_scope("mm"):
            c = jnp.einsum("ij,jk->ik", a, b)
        with jax.named_scope("norm"):
            return c / (1e-6 + jnp.mean(jnp.abs(c)))

    # big enough that intensity beats the machine balance -> MXU match
    a = jnp.ones((512, 4096), jnp.bfloat16)
    b = jnp.ones((4096, 1024), jnp.bfloat16)
    g = sdfg.extract(f, a, b)
    assert len(g.nodes) >= 3 and len(g.edges) >= 2
    regions = g.regions()
    mm = next(r for name, r in regions.items() if "mm" in name)
    assert mm.match(TPU_V5E) == sdfg.MXU
    assert mm.flops == 2.0 * 512 * 4096 * 1024


def test_extract_descends_scan_with_trip_count():
    def f(x):
        def body(c, _):
            return c * 1.1 + 1.0, None

        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    g = sdfg.extract(f, jnp.ones((16,)))
    # scan body ops appear with 7x multiplier on costs
    muls = [n for n in g.nodes if n.primitive == "mul"]
    assert muls and muls[0].flops == 7 * 16


def test_summary_and_dot():
    def f(x, w):
        return jax.nn.relu(x @ w)

    g = sdfg.extract(f, jnp.ones((8, 8)), jnp.ones((8, 8)))
    s = g.summary()
    assert s[sdfg.MXU]["nodes"] == 1
    dot = g.to_dot()
    assert dot.startswith("digraph") and "MXU" in dot


def test_model_step_sdfg_has_all_compute_classes():
    """The whole point: one IR pass over a real train step classifies work
    across heterogeneous components (paper §I 'architecture-agnostic')."""
    from repro.configs import get_config, reduced
    from repro.models import lm

    cfg = reduced(get_config("deepseek-moe-16b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, 16), jnp.int32)

    def step(p, t):
        return lm.loss_fn(p, cfg, t, t)[0]

    g = sdfg.extract(step, params, tokens)
    s = g.summary()
    assert s[sdfg.MXU]["nodes"] > 0
    assert s[sdfg.VPU]["nodes"] > 0
    assert s[sdfg.HBM]["nodes"] > 0
    regions = g.regions()
    assert len(regions) > 3  # named_scope blocks resolved
