"""Checkpoint store + supervisor: restart determinism, async, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config, reduced
from repro.core.events import EventLog
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime.supervisor import FailureInjector, NodeFailure, Supervisor, SupervisorConfig
from repro.training.step import TrainConfig, init_train_state, make_train_step


def test_save_restore_roundtrip(tmp_path, key):
    state = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.int32(7)}}
    save(str(tmp_path), 3, state)
    assert latest_step(str(tmp_path)) == 3
    got = restore(str(tmp_path), 3, jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(got["a"], state["a"])
    assert int(got["b"]["c"]) == 7


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.full((8,), float(s))})
    ck.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    got = restore(str(tmp_path), 4, jax.eval_shape(lambda: {"x": jnp.zeros(8)}))
    np.testing.assert_array_equal(got["x"], np.full(8, 4.0))


def test_atomic_write_no_partial_visible(tmp_path):
    save(str(tmp_path), 1, {"x": jnp.zeros(4)})
    # a stale tmp dir from a "killed writer" must not count as a checkpoint
    os.makedirs(tmp_path / ".tmp_step_00000002")
    assert latest_step(str(tmp_path)) == 1


def _mk(key, arch="smollm-360m", steps=20, **sup_kw):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig()
    state = init_train_state(cfg, tcfg, key)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=5))

    def batch_fn(i):
        b = data.batch(i)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return cfg, tcfg, state, step, batch_fn


def test_supervisor_restart_is_deterministic(tmp_path, key):
    """Same data + restored state ⇒ the replayed run converges to the same
    params as a failure-free run (stateless-indexed pipeline property)."""
    cfg, tcfg, state0, step, batch_fn = _mk(key)
    log = EventLog()
    sup_a = Supervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5, max_steps=12),
        step, batch_fn, jax.tree.map(jnp.copy, state0), log=log,
    )
    out_a = sup_a.run()
    sup_b = Supervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5, max_steps=12),
        step, batch_fn, jax.tree.map(jnp.copy, state0), log=log,
        failures=FailureInjector((7,)),
    )
    out_b = sup_b.run()
    assert out_b["restarts"] == 1
    for a, b in zip(jax.tree.leaves(sup_a.state["params"]), jax.tree.leaves(sup_b.state["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5, rtol=1e-5
        )


def test_supervisor_gives_up_after_max_restarts(tmp_path, key):
    cfg, tcfg, state, step, batch_fn = _mk(key)
    sup = Supervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=100, max_steps=10, max_restarts=2),
        step, batch_fn, state,
        failures=FailureInjector((1, 2, 3, 4)),
    )
    # failures at steps 1..4 but restart restores to step 0 and _already-fired
    # steps don't refire; with max_restarts=2 the 3rd failure raises
    with pytest.raises(NodeFailure):
        sup.run()


def test_elastic_reshard_across_meshes(tmp_path, key):
    """A checkpoint written under one sharding restores onto a different mesh
    (the 16×16 → 8×16 elastic-resize story, at 1-device scale: 1x1 -> CPU)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    save(str(tmp_path), 1, state)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shd = {"w": NamedSharding(mesh, P("data", "model"))}
    got = restore(str(tmp_path), 1, jax.eval_shape(lambda: state), shardings=shd)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(state["w"]))
    assert got["w"].sharding == shd["w"]


def test_straggler_detection(tmp_path, key):
    import time

    cfg, tcfg, state, step, batch_fn = _mk(key)
    slow = {15}

    def slow_batch(i):
        if i in slow:
            time.sleep(1.0)  # injected host-level straggle
        return batch_fn(i)

    sup = Supervisor(
        SupervisorConfig(
            ckpt_dir=str(tmp_path), ckpt_every=100, max_steps=18, straggler_factor=3.0
        ),
        step, slow_batch, state,
    )
    out = sup.run()
    assert out["stragglers"] >= 1
    assert sup.log.events("straggler")
