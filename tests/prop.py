"""Property-test harness: uses `hypothesis` when installed, else a seeded
mini fallback with the same surface (the container is offline; the tests are
written against hypothesis' API and run unchanged under either backend)."""
from __future__ import annotations

import itertools
import random
from functools import wraps

try:  # pragma: no cover - prefer real hypothesis when available
    from hypothesis import given, settings, strategies as st  # type: ignore

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> value

        def map(self, f):
            return _Strategy(lambda rng: f(self.sample(rng)))

        def filter(self, pred):
            def sample(rng):
                for _ in range(1000):
                    v = self.sample(rng)
                    if pred(v):
                        return v
                raise RuntimeError("filter failed to find a value")

            return _Strategy(sample)

    class st:  # type: ignore[no-redef]
        @staticmethod
        def integers(min_value=0, max_value=100):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=-1e3, max_value=1e3, allow_nan=False, allow_infinity=False, width=64):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def lists(elem, min_size=0, max_size=8):
            return _Strategy(
                lambda rng: [elem.sample(rng) for _ in range(rng.randint(min_size, max_size))]
            )

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.sample(rng) for e in elems))

    def settings(max_examples=25, **_kw):  # type: ignore[no-redef]
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):  # type: ignore[no-redef]
        def deco(fn):
            @wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 25)
                for i in range(n):
                    rng = random.Random(0xC0FFEE + i)
                    vals = [s.sample(rng) for s in strategies]
                    kvals = {k: s.sample(rng) for k, s in kw_strategies.items()}
                    fn(*args, *vals, **kwargs, **kvals)

            # pytest resolves fixture names through __wrapped__; the original
            # fn's strategy parameters must not be mistaken for fixtures.
            del wrapper.__wrapped__
            return wrapper

        return deco
