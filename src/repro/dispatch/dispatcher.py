"""The dispatcher: argmin-cost placement, every decision an EventLog event.

Three policies (the ``--dispatch`` flag on serve/train):

    static     always the configured backend (the baseline everyone ships)
    roofline   argmin over a-priori cost-model estimates (act on analysis)
    profiled   roofline to open, then measured-beats-estimated: each candidate
               is explored until warm, after which the measured mean decides
               (the Adaptyst loop — analysis seeds, profiles correct)

``dispatch()`` both *decides* and *executes*: it runs the chosen variant,
blocks to completion, feeds the wall-time back into the
:class:`~repro.dispatch.profiles.ProfileStore`, and records a ``dispatch``
event whose payload carries op, backend, estimate, measurement and policy —
the paper's "performance analysis determines the dispatch platform", with a
paper-trail.  Each dispatch event carries its own span id and inherits the
current span context as parent, so decisions land in the span tree as
children of the request/step that caused them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping, Optional

import jax

from repro.core.events import GLOBAL_LOG, EventLog, next_span_id
from repro.core.sdfg import SDFG, Region
from repro.dispatch.cost import CostEstimate, estimate_region
from repro.dispatch.profiles import ProfileStore, signature
from repro.dispatch.registry import BackendRegistry, host_registry
from repro.hw.specs import ChipSpec

POLICIES = ("static", "roofline", "profiled")

_annotation_fn: Optional[Callable[[int], Any]] = None


def _device_annotation(span_id: int) -> Any:
    """Profiler annotation for the executed variant (free null context when
    no live device profiler is active).  Imported lazily: pulling
    ``repro.trace.liveprof`` in at module scope would cycle through
    ``repro.trace`` → ``session`` → ``dispatch.profiles`` back into this
    package mid-import."""
    global _annotation_fn
    if _annotation_fn is None:
        from repro.trace.liveprof import device_annotation

        _annotation_fn = device_annotation
    return _annotation_fn(span_id)


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    policy: str = "profiled"
    static_backend: str = "chunked"  # used by policy="static"
    min_samples: int = 2  # profile warmth threshold
    record_events: bool = True

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {self.policy!r}")


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    op: str
    backend: str
    sig: str
    est_s: float
    source: str  # static | roofline | measured | explore
    policy: str
    measured_s: Optional[float] = None  # wall-time of the executed call
    config: str = ""  # active config point ("" = backend defaults)

    def payload(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        if d["measured_s"] is None:  # unexecuted decision (partition/choose)
            del d["measured_s"]
        if not d["config"]:  # default point: keep the legacy payload shape
            del d["config"]
        return d


class Dispatcher:
    """Routes ops / requests / steps to the argmin-cost backend target."""

    def __init__(
        self,
        cfg: Optional[DispatchConfig] = None,
        *,
        registry: Optional[BackendRegistry] = None,
        store: Optional[ProfileStore] = None,
        log: Optional[EventLog] = None,
    ) -> None:
        self.cfg = cfg or DispatchConfig()
        self.registry = registry if registry is not None else host_registry()
        # `is not None`, not truthiness: an empty provided store (len 0) must
        # still be used — it may be shared with a session writer or filled by
        # a later merge
        self.store = store if store is not None else ProfileStore(min_samples=self.cfg.min_samples)
        # warmth is a dispatch-policy knob, not a property of the loaded file:
        # a --profile-in store restored with a different min_samples would
        # silently override cfg.min_samples otherwise
        self.store.min_samples = self.cfg.min_samples
        # new measurements are stamped with the environment that produced
        # them, so a later --profile-in can age out entries whose code or
        # hardware no longer matches (profile invalidation)
        from repro.trace.session import git_sha

        self.store.set_stamp(git_sha=git_sha(), chip=self.registry.chip.name)
        self.log = GLOBAL_LOG if log is None else log
        self.decisions: list[DispatchDecision] = []

    @property
    def chip(self) -> ChipSpec:
        return self.registry.chip

    def backends(self) -> list[str]:
        return self.registry.names()

    def active_configs(self) -> dict[str, str]:
        """Per-backend active tuned-config tags for the ``configs=`` params.

        When ``repro.tune`` winners are installed in ``kernels.ops``, each
        backend's compiled variants execute under those overrides — its
        samples must land in the matching config-point bucket, not the
        default one.  All-empty (no tuning) reproduces legacy keys.
        """
        from repro.kernels import ops

        return {t.name: ops.config_tag(t.impl) for t in self.registry.targets()}

    # -- decision ------------------------------------------------------------

    def choose(
        self,
        op: str,
        sig: str,
        estimates: Mapping[str, float],
        configs: Optional[Mapping[str, str]] = None,
    ) -> DispatchDecision:
        """Pick a backend given per-backend a-priori estimates (seconds).

        ``estimates`` keys restrict the candidate set (callers pass only the
        variants they actually compiled).  ``configs`` maps a backend to the
        config point its variant executes under (``kernels.ops.config_tag``
        when tuned overrides are installed); warmth, lookup, and recording
        then use the full ``(op, backend, sig, config)`` key, so tuned and
        default samples never pollute each other's buckets and the argmin
        runs over *config points*, not just backends.
        """
        candidates = [b for b in estimates if b in self.registry]
        if not candidates:
            raise ValueError(f"no registered candidates among {sorted(estimates)}")
        cfg_of = (configs or {}).get
        policy = self.cfg.policy
        if policy == "static":
            if self.cfg.static_backend in candidates:
                backend, source = self.cfg.static_backend, "static"
            else:  # pinned backend unavailable here (e.g. pallas off-TPU)
                backend, source = candidates[0], "static-fallback"
            decision = DispatchDecision(op, backend, sig, estimates[backend],
                                        source, policy, config=cfg_of(backend, ""))
        elif policy == "roofline":
            backend = min(candidates, key=lambda b: estimates[b])
            decision = DispatchDecision(op, backend, sig, estimates[backend],
                                        "roofline", policy, config=cfg_of(backend, ""))
        else:  # profiled
            cold = [
                b for b in candidates
                if not self.store.warm(op, b, sig, cfg_of(b, ""))
            ]
            if cold:
                # explore the least-sampled cold candidate (roofline order
                # breaks ties so the best a-priori guess is measured first)
                backend = min(
                    cold,
                    key=lambda b: (
                        self.store.samples(op, b, sig, cfg_of(b, "")), estimates[b]
                    ),
                )
                decision = DispatchDecision(op, backend, sig, estimates[backend],
                                            "explore", policy, config=cfg_of(backend, ""))
            else:
                costs = {
                    b: self.store.combined_cost(op, b, sig, estimates[b],
                                                cfg_of(b, ""))
                    for b in candidates
                }
                backend = min(candidates, key=lambda b: costs[b][0])
                decision = DispatchDecision(
                    op, backend, sig, costs[backend][0], costs[backend][1],
                    policy, config=cfg_of(backend, ""),
                )
        self.decisions.append(decision)
        return decision

    # -- decide + execute + feed back -----------------------------------------

    def dispatch(
        self,
        op: str,
        variants: Mapping[str, Callable],
        *args: Any,
        estimates: Optional[Mapping[str, float]] = None,
        sig: Optional[str] = None,
        configs: Optional[Mapping[str, str]] = None,
        **kwargs: Any,
    ) -> Any:
        """Route one call: choose a variant, run it, profile it, log it.

        ``sig`` lets hot callers supply a cheap profile key (e.g. the token
        array's shape) instead of walking a large params/state pytree.
        ``configs`` (per-backend active config point) flows through to
        :meth:`choose` and keys the recorded sample.
        """
        sig = sig if sig is not None else signature(*args)
        if estimates is None:
            # no analysis supplied: flat priors, registry-order exploration
            estimates = {
                b: self.registry.get(b).launch_overhead_s
                for b in variants
                if b in self.registry
            }
        decision = self.choose(
            op, sig, {b: estimates[b] for b in variants if b in estimates},
            configs=configs,
        )
        idx = len(self.decisions) - 1  # choose() appended; backfill measurement
        fn = variants[decision.backend]
        # span id allocated BEFORE execution so an active device profiler can
        # annotate the launched work with it — the profiler's slices then bind
        # to this exact decision instead of a fuzzy time window
        span_id = next_span_id() if self.cfg.record_events else 0
        t0 = time.perf_counter()
        with _device_annotation(span_id):
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self.store.record(op, decision.backend, sig, dt, config=decision.config)
        decision = dataclasses.replace(decision, measured_s=dt)
        self.decisions[idx] = decision
        if self.cfg.record_events:
            # own span id + context parent: the decision is a span-tree node
            # under the request/step whose span_scope is active right now
            self.log.record("dispatch", op, decision.payload(), span=span_id)
        return out

    # -- whole-graph placement -------------------------------------------------

    def estimates_for_region(
        self, region: Region, backends: Optional[list[str]] = None
    ) -> dict[str, CostEstimate]:
        targets = self.registry.targets(backends)
        return {t.name: estimate_region(region, t, self.chip) for t in targets}

    def partition(
        self, graph: SDFG, *, backends: Optional[list[str]] = None
    ) -> dict[str, DispatchDecision]:
        """Assign every SDFG region to its argmin-cost backend.

        Uses the same choose() path as runtime dispatch, so profiled mode
        honours any warm measurements keyed by region name, and every
        assignment lands in the EventLog.
        """
        placement: dict[str, DispatchDecision] = {}
        for name, region in graph.regions().items():
            ests = {b: e.seconds for b, e in self.estimates_for_region(region, backends).items()}
            decision = self.choose(f"region:{name}", "<sdfg>", ests)
            placement[name] = decision
            if self.cfg.record_events:
                self.log.record("dispatch", f"region:{name}", decision.payload(),
                                span=next_span_id())
        return placement

    # -- reporting -------------------------------------------------------------

    def summary(self) -> dict[str, Any]:
        """Decision counts per (op, backend) — for driver JSON output.

        ``by_source`` separates exploration dispatches (``explore``) from
        steady-state ones (``measured``/``roofline``/``static``): a
        warm-started dispatcher (``--profile-in``) shows explore≈0.
        """
        by_op: dict[str, dict[str, int]] = {}
        by_source: dict[str, int] = {}
        for d in self.decisions:
            by_op.setdefault(d.op, {}).setdefault(d.backend, 0)
            by_op[d.op][d.backend] += 1
            by_source[d.source] = by_source.get(d.source, 0) + 1
        return {
            "policy": self.cfg.policy,
            "decisions": len(self.decisions),
            "by_op": by_op,
            "by_source": by_source,
            "explore_dispatches": by_source.get("explore", 0),
            "profiled_keys": len(self.store),
        }


def with_impl(impl: str, fn: Callable) -> Callable:
    """Bind a kernels.ops impl choice into ``fn`` at trace time.

    ``jax.jit(with_impl("ref", step))`` bakes the reference kernels into that
    compiled variant: the wrapper body runs while JAX traces, so the impl
    override is live exactly when :func:`repro.kernels.ops._resolve` reads it.
    """
    from repro.kernels import ops

    def wrapped(*args: Any, **kwargs: Any):
        prev = ops._IMPL
        ops.set_default_impl(impl)
        try:
            return fn(*args, **kwargs)
        finally:
            ops.set_default_impl(prev)

    wrapped.__name__ = f"{getattr(fn, '__name__', 'fn')}__{impl}"
    return wrapped
