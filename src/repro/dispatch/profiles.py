"""Online profile store: measured samples override a-priori estimates.

This is the Adaptyst feedback loop.  The cost model in
:mod:`repro.dispatch.cost` prices every (op, backend, shape) a priori; each
real execution the dispatcher routes is timed and folded back in here.  Once
a key is *warm* (``min_samples`` observations) the measured mean beats the
estimate — the dispatcher stops trusting the model and starts trusting the
hardware.

Samples arrive from three directions:

* :meth:`ProfileStore.record` — the dispatcher's own timed executions;
* :meth:`ProfileStore.observe_timing` — an :class:`repro.core.overhead.TimingStats`
  from the hyperfine harness (1000-run benchmark protocols);
* :meth:`ProfileStore.ingest_event_log` — ``dispatch`` events recorded in an
  :class:`repro.core.events.EventLog` by a previous run (profiles persist
  across processes via :meth:`to_json` / :meth:`from_json`).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import TYPE_CHECKING, Any, Optional

from repro.core.events import EventLog

if TYPE_CHECKING:  # annotation-only: repro.core.overhead imports jax, and
    # the ProfileStore must stay loadable from jax-free processes (fleet
    # daemon/client, trace session loader, router cost seeding)
    from repro.core.overhead import TimingStats


def signature(*args: Any) -> str:
    """Shape/dtype signature of a call's array arguments (pytrees allowed)."""
    import jax

    parts: list[str] = []
    for leaf in jax.tree.leaves(args):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is not None and dtype is not None:
            parts.append(f"{dtype}[{','.join(map(str, shape))}]")
    sig = ";".join(parts) if parts else "<scalar>"
    if len(sig) > 256:  # train-state pytrees: stable digest instead of a novel
        import hashlib

        sig = f"tree:{len(parts)}leaves:{hashlib.sha1(sig.encode()).hexdigest()[:16]}"
    return sig


def encode_config(params: Any) -> str:
    """Canonical string form of a kernel config point: ``"k=v,k2=v2"``.

    Sorted by key so two dicts with the same content encode identically —
    the encoding IS the profile-bucket identity.  Empty dict encodes to
    ``""`` (the default/legacy point).
    """
    return ",".join(f"{k}={params[k]}" for k in sorted(params))


def decode_config(config: str) -> dict[str, Any]:
    """Inverse of :func:`encode_config`; values parse as int, float, or str."""
    out: dict[str, Any] = {}
    if not config:
        return out
    for part in config.split(","):
        k, _, v = part.partition("=")
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def _esc(field: str) -> str:
    """Escape the key separator (and the escape char itself) inside a field.

    A crafted ``sig`` like ``"x|pallas|y"`` must not alias a different
    bucket's key — without escaping, ``profile_key("op", "ref", "x|pallas|y")``
    and ``profile_key("op|ref|x", "pallas", "y")`` collide silently.  Real
    signatures (``float32[1,16]``-style) contain neither ``%`` nor ``|``, so
    keys written by previous versions round-trip unchanged.
    """
    return field.replace("%", "%25").replace("|", "%7C")


def _unesc(field: str) -> str:
    return field.replace("%7C", "|").replace("%25", "%")


def profile_key(op: str, backend: str, sig: str, config: str = "") -> str:
    """Key of one profile bucket: a full *config point*.

    ``config`` is the canonical encoding of the kernel configuration the
    samples were measured under (block/tile sizes, batch/padding choices —
    see :mod:`repro.tune.space`); the empty string means "backend defaults"
    and yields the legacy three-field key, so existing fleet buckets and
    session snapshots keep their key strings byte-for-byte.
    """
    parts = [_esc(op), _esc(backend), _esc(sig)]
    if config:
        parts.append(_esc(config))
    return "|".join(parts)


def parse_profile_key(key: str) -> tuple[str, str, str, str]:
    """Inverse of :func:`profile_key`: ``(op, backend, sig, config)``.

    Legacy three-field keys parse with ``config == ""``.  Raises ValueError
    on keys with the wrong field count rather than guessing.
    """
    parts = key.split("|")
    if len(parts) == 3:
        parts.append("")
    if len(parts) != 4:
        raise ValueError(f"malformed profile key {key!r}: "
                         f"expected 3 or 4 |-separated fields, got {len(parts)}")
    op, backend, sig, config = (_unesc(p) for p in parts)
    return op, backend, sig, config


def _combine_stamp(a: str, b: str) -> str:
    """Provenance of samples from two environments: agreement persists,
    disagreement (including stamped vs unstamped) degrades to ``"mixed"``,
    which never matches a real SHA/chip so age_out evicts it."""
    return a if a == b else "mixed"


@dataclasses.dataclass
class ProfileEntry:
    """Welford running stats over observed wall-times for one key.

    ``git_sha``/``chip`` stamp where the samples came from: a measurement is
    only trustworthy on the code and hardware that produced it, and
    :meth:`ProfileStore.age_out` evicts entries whose stamp no longer matches
    the current environment (profile invalidation).  Empty = legacy/unknown.
    """

    count: int = 0
    mean_s: float = 0.0
    m2: float = 0.0
    min_s: float = float("inf")
    git_sha: str = ""
    chip: str = ""

    def add(self, seconds: float) -> None:
        self.count += 1
        delta = seconds - self.mean_s
        self.mean_s += delta / self.count
        self.m2 += delta * (seconds - self.mean_s)
        self.min_s = min(self.min_s, seconds)

    @property
    def variance(self) -> float:
        return self.m2 / (self.count - 1) if self.count > 1 else 0.0


class ProfileStore:
    def __init__(self, min_samples: int = 2) -> None:
        self.min_samples = min_samples
        self._entries: dict[str, ProfileEntry] = {}
        # guards mutation vs serialisation: ProfileEntry.add() updates
        # count/mean/m2 in several steps, and a snapshot taken mid-add (e.g.
        # a fleet push on the streaming-rotation thread while the dispatcher
        # records) would serialise a torn Welford state
        self._lock = threading.RLock()
        # provenance applied to entries as they receive samples; set via
        # set_stamp() (the Dispatcher stamps with its chip + the repo SHA)
        self._stamp_git = ""
        self._stamp_chip = ""

    # -- provenance ----------------------------------------------------------

    def set_stamp(self, git_sha: str = "", chip: str = "") -> None:
        """Declare the environment new samples are measured in."""
        self._stamp_git = git_sha
        self._stamp_chip = chip

    def age_out(self, git_sha: str = "", chip: str = "") -> list[dict[str, str]]:
        """Evict entries stamped with a *different* git SHA or chip.

        Stored profiles are only valid on the code + hardware that measured
        them; a mismatched entry is dropped so the dispatcher re-explores
        instead of trusting stale timings.  Unstamped (legacy) entries are
        kept.  Returns one ``{"key", "reason"}`` record per eviction so
        callers can log why warm-start data disappeared.
        """
        aged: list[dict[str, str]] = []
        with self._lock:
            for key, e in list(self._entries.items()):
                reason = None
                if git_sha and e.git_sha and e.git_sha != git_sha:
                    reason = f"git_sha changed ({e.git_sha} -> {git_sha})"
                elif chip and e.chip and e.chip != chip:
                    reason = f"chip changed ({e.chip} -> {chip})"
                if reason is not None:
                    del self._entries[key]
                    aged.append({"key": key, "reason": reason})
        return aged

    # -- writers -------------------------------------------------------------

    def _entry_for_write(self, key: str) -> ProfileEntry:
        """Get-or-create an entry about to receive current-environment samples.

        A fresh entry takes the store's stamp outright.  An existing entry's
        stamp may only persist if it agrees with the current environment —
        overwriting would launder old samples under a fresh stamp, hiding
        them from age_out (same rule as merge(): disagreement means
        'mixed', which never survives an invalidation pass).
        """
        e = self._entries.get(key)
        if e is None:
            e = self._entries[key] = ProfileEntry(
                git_sha=self._stamp_git, chip=self._stamp_chip
            )
        elif e.count == 0:
            # a sample-less placeholder has no provenance to defend: adopt
            # the writer's stamp instead of laundering it to 'mixed'
            e.git_sha, e.chip = self._stamp_git, self._stamp_chip
        else:
            e.git_sha = _combine_stamp(e.git_sha, self._stamp_git)
            e.chip = _combine_stamp(e.chip, self._stamp_chip)
        return e

    def record(self, op: str, backend: str, sig: str, seconds: float,
               config: str = "") -> None:
        with self._lock:
            self._entry_for_write(profile_key(op, backend, sig, config)).add(seconds)

    def observe_timing(self, op: str, backend: str, sig: str, stats: TimingStats,
                       config: str = "") -> None:
        """Fold a hyperfine benchmark result in as ``stats.runs`` samples."""
        with self._lock:
            e = self._entry_for_write(profile_key(op, backend, sig, config))
            mean_s = stats.mean_ms / 1e3
            for _ in range(max(stats.runs, 1)):
                e.add(mean_s)
            e.min_s = min(e.min_s, stats.min_ms / 1e3)

    def ingest_event_log(self, log: EventLog) -> int:
        """Replay ``dispatch`` events (payload dicts) from a previous run."""
        n = 0
        for ev in log.events(kind="dispatch"):
            p = ev.payload
            if not isinstance(p, dict) or not isinstance(p.get("measured_s"), (int, float)):
                continue
            self.record(p["op"], p["backend"], p.get("sig", "<scalar>"),
                        p["measured_s"], config=p.get("config", ""))
            n += 1
        return n

    # -- readers -------------------------------------------------------------

    def entry(self, op: str, backend: str, sig: str,
              config: str = "") -> Optional[ProfileEntry]:
        return self._entries.get(profile_key(op, backend, sig, config))

    def samples(self, op: str, backend: str, sig: str, config: str = "") -> int:
        e = self.entry(op, backend, sig, config)
        return e.count if e else 0

    def warm(self, op: str, backend: str, sig: str, config: str = "") -> bool:
        return self.samples(op, backend, sig, config) >= self.min_samples

    def lookup(self, op: str, backend: str, sig: str,
               config: str = "") -> Optional[float]:
        """Measured seconds, or None if the key is not warm yet.

        Uses the *minimum* observed wall-time (hyperfine's robust statistic):
        the first sample of a jitted variant includes compilation, and a mean
        polluted by one cold call would mis-rank backends for the rest of the
        run.  With ``min_samples >= 2`` the minimum is a warm execution.
        """
        e = self.entry(op, backend, sig, config)
        if e is None or e.count < self.min_samples:
            return None
        return e.min_s

    def combined_cost(self, op: str, backend: str, sig: str, estimate_s: float,
                      config: str = "") -> tuple[float, str]:
        """Measured-beats-estimated: (seconds, source)."""
        measured = self.lookup(op, backend, sig, config)
        if measured is not None:
            return measured, "measured"
        return estimate_s, "roofline"

    def config_points(self, op: str, backend: str, sig: str) -> dict[str, ProfileEntry]:
        """All measured config points of one (op, backend, sig), keyed by the
        canonical config encoding (``""`` = backend defaults / legacy keys).

        This is the read side of the design-space sweep: the tuner records
        each point as an ordinary sample, and consumers (dispatcher,
        ``repro.tune show``, the drivers' ``--tune cached``) argmin over what
        came back — from this run, a ``--profile-in`` file, or a fleet pull.
        """
        out: dict[str, ProfileEntry] = {}
        with self._lock:
            for key, e in self._entries.items():
                try:
                    k_op, k_backend, k_sig, k_config = parse_profile_key(key)
                except ValueError:
                    continue
                if k_op == op and k_backend == backend and k_sig == sig:
                    out[k_config] = e
        return out

    def best_config(self, op: str, backend: str,
                    sig: str) -> Optional[tuple[str, float]]:
        """Argmin-cost *warm* config point: ``(config, min_s)`` or None.

        The default point (``config == ""``) competes on equal terms, so a
        tuned config is only ever preferred when its measured minimum beats
        the hand-picked default's.
        """
        best: Optional[tuple[str, float]] = None
        for config, e in self.config_points(op, backend, sig).items():
            if e.count < self.min_samples:
                continue
            if best is None or e.min_s < best[1]:
                best = (config, e.min_s)
        return best

    def merge(self, other: "ProfileStore") -> int:
        """Fold another store's samples in (warm-start across runs).

        Welford states combine exactly (Chan et al. parallel variance), so
        merging N per-run stores equals one store that saw every sample.
        Entries merged from *different* environments get a ``"mixed"`` stamp:
        it never matches a real SHA/chip, so :meth:`age_out` conservatively
        evicts them — samples of unknown provenance must not survive an
        invalidation pass.  ``count == 0`` placeholder rows in ``other`` are
        skipped outright: they carry no samples, and materialising them here
        would create warm-looking empty entries (inflating ``profiled_keys``
        and polluting stamps).  Returns the number of samples merged.
        """

        merged = 0
        with self._lock:
            for k, o in list(other._entries.items()):
                if o.count == 0:  # placeholder row: no samples to fold in
                    continue
                e = self._entries.get(k)
                if e is None or e.count == 0:
                    # absent or a sample-less placeholder: take the incoming
                    # entry wholesale — combining stamps with a placeholder
                    # would launder real provenance to 'mixed' and get the
                    # samples evicted by the next age-out pass
                    self._entries[k] = ProfileEntry(
                        o.count, o.mean_s, o.m2, o.min_s, o.git_sha, o.chip
                    )
                    merged += o.count
                    continue
                n = e.count + o.count
                delta = o.mean_s - e.mean_s
                e.m2 = e.m2 + o.m2 + delta * delta * e.count * o.count / n
                e.mean_s = e.mean_s + delta * o.count / n
                e.count = n
                e.min_s = min(e.min_s, o.min_s)
                e.git_sha = _combine_stamp(e.git_sha, o.git_sha)
                e.chip = _combine_stamp(e.chip, o.chip)
                merged += o.count
        return merged

    def delta_since(self, baseline: "ProfileStore") -> "ProfileStore":
        """Samples added to this store since ``baseline`` (an earlier
        snapshot of the *same* store).

        Welford states subtract exactly as they merge: for every key the
        returned store holds a state D such that ``baseline.merge(D)``
        reproduces this store's count/mean/m2.  ``min_s`` is carried whole —
        min-merging is idempotent, so re-pushing it is harmless.  Keys with
        no new samples are omitted.  This is what lets a long-lived run push
        per-rotation snapshots to a fleet store without double-counting the
        samples it already pushed.
        """
        out = ProfileStore(min_samples=self.min_samples)
        with self._lock:
            for k, e in list(self._entries.items()):
                if e.count == 0:  # placeholder row: nothing to push
                    continue
                b = baseline._entries.get(k)
                if b is None or b.count == 0:
                    out._entries[k] = ProfileEntry(
                        e.count, e.mean_s, e.m2, e.min_s, e.git_sha, e.chip
                    )
                    continue
                n = e.count - b.count
                if n <= 0:  # no new samples (counts never shrink in place)
                    continue
                mean = (e.count * e.mean_s - b.count * b.mean_s) / n
                delta = mean - b.mean_s
                m2 = e.m2 - b.m2 - delta * delta * b.count * n / e.count
                out._entries[k] = ProfileEntry(
                    n, mean, max(m2, 0.0), e.min_s, e.git_sha, e.chip
                )
        return out

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> str:
        def row(e: ProfileEntry) -> dict[str, Any]:
            d: dict[str, Any] = {"count": e.count, "mean_s": e.mean_s,
                                 "m2": e.m2, "min_s": e.min_s}
            if e.git_sha:
                d["git_sha"] = e.git_sha
            if e.chip:
                d["chip"] = e.chip
            return d

        # under the store lock: a concurrent record() (streaming rotation on
        # another thread serialising mid-run) must neither break iteration
        # nor expose a mid-add torn Welford state
        with self._lock:
            return json.dumps(
                {
                    "min_samples": self.min_samples,
                    "entries": {k: row(e) for k, e in list(self._entries.items())},
                },
                indent=1,
            )

    @classmethod
    def from_json(cls, text: str) -> "ProfileStore":
        raw = json.loads(text)
        store = cls(min_samples=raw.get("min_samples", 2))
        for k, d in raw.get("entries", {}).items():
            store._entries[k] = ProfileEntry(
                count=d["count"], mean_s=d["mean_s"], m2=d.get("m2", 0.0),
                min_s=d.get("min_s", float("inf")),
                git_sha=d.get("git_sha", ""), chip=d.get("chip", ""),
            )
        return store

    def __len__(self) -> int:
        return len(self._entries)
