"""Online profile store: measured samples override a-priori estimates.

This is the Adaptyst feedback loop.  The cost model in
:mod:`repro.dispatch.cost` prices every (op, backend, shape) a priori; each
real execution the dispatcher routes is timed and folded back in here.  Once
a key is *warm* (``min_samples`` observations) the measured mean beats the
estimate — the dispatcher stops trusting the model and starts trusting the
hardware.

Samples arrive from three directions:

* :meth:`ProfileStore.record` — the dispatcher's own timed executions;
* :meth:`ProfileStore.observe_timing` — an :class:`repro.core.overhead.TimingStats`
  from the hyperfine harness (1000-run benchmark protocols);
* :meth:`ProfileStore.ingest_event_log` — ``dispatch`` events recorded in an
  :class:`repro.core.events.EventLog` by a previous run (profiles persist
  across processes via :meth:`to_json` / :meth:`from_json`).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

from repro.core.events import EventLog
from repro.core.overhead import TimingStats


def signature(*args: Any) -> str:
    """Shape/dtype signature of a call's array arguments (pytrees allowed)."""
    import jax

    parts: list[str] = []
    for leaf in jax.tree.leaves(args):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is not None and dtype is not None:
            parts.append(f"{dtype}[{','.join(map(str, shape))}]")
    sig = ";".join(parts) if parts else "<scalar>"
    if len(sig) > 256:  # train-state pytrees: stable digest instead of a novel
        import hashlib

        sig = f"tree:{len(parts)}leaves:{hashlib.sha1(sig.encode()).hexdigest()[:16]}"
    return sig


def profile_key(op: str, backend: str, sig: str) -> str:
    return f"{op}|{backend}|{sig}"


@dataclasses.dataclass
class ProfileEntry:
    """Welford running stats over observed wall-times for one key."""

    count: int = 0
    mean_s: float = 0.0
    m2: float = 0.0
    min_s: float = float("inf")

    def add(self, seconds: float) -> None:
        self.count += 1
        delta = seconds - self.mean_s
        self.mean_s += delta / self.count
        self.m2 += delta * (seconds - self.mean_s)
        self.min_s = min(self.min_s, seconds)

    @property
    def variance(self) -> float:
        return self.m2 / (self.count - 1) if self.count > 1 else 0.0


class ProfileStore:
    def __init__(self, min_samples: int = 2) -> None:
        self.min_samples = min_samples
        self._entries: dict[str, ProfileEntry] = {}

    # -- writers -------------------------------------------------------------

    def record(self, op: str, backend: str, sig: str, seconds: float) -> None:
        key = profile_key(op, backend, sig)
        self._entries.setdefault(key, ProfileEntry()).add(seconds)

    def observe_timing(self, op: str, backend: str, sig: str, stats: TimingStats) -> None:
        """Fold a hyperfine benchmark result in as ``stats.runs`` samples."""
        key = profile_key(op, backend, sig)
        e = self._entries.setdefault(key, ProfileEntry())
        mean_s = stats.mean_ms / 1e3
        for _ in range(max(stats.runs, 1)):
            e.add(mean_s)
        e.min_s = min(e.min_s, stats.min_ms / 1e3)

    def ingest_event_log(self, log: EventLog) -> int:
        """Replay ``dispatch`` events (payload dicts) from a previous run."""
        n = 0
        for ev in log.events(kind="dispatch"):
            p = ev.payload
            if not isinstance(p, dict) or not isinstance(p.get("measured_s"), (int, float)):
                continue
            self.record(p["op"], p["backend"], p.get("sig", "<scalar>"), p["measured_s"])
            n += 1
        return n

    # -- readers -------------------------------------------------------------

    def entry(self, op: str, backend: str, sig: str) -> Optional[ProfileEntry]:
        return self._entries.get(profile_key(op, backend, sig))

    def samples(self, op: str, backend: str, sig: str) -> int:
        e = self.entry(op, backend, sig)
        return e.count if e else 0

    def warm(self, op: str, backend: str, sig: str) -> bool:
        return self.samples(op, backend, sig) >= self.min_samples

    def lookup(self, op: str, backend: str, sig: str) -> Optional[float]:
        """Measured seconds, or None if the key is not warm yet.

        Uses the *minimum* observed wall-time (hyperfine's robust statistic):
        the first sample of a jitted variant includes compilation, and a mean
        polluted by one cold call would mis-rank backends for the rest of the
        run.  With ``min_samples >= 2`` the minimum is a warm execution.
        """
        e = self.entry(op, backend, sig)
        if e is None or e.count < self.min_samples:
            return None
        return e.min_s

    def combined_cost(self, op: str, backend: str, sig: str, estimate_s: float) -> tuple[float, str]:
        """Measured-beats-estimated: (seconds, source)."""
        measured = self.lookup(op, backend, sig)
        if measured is not None:
            return measured, "measured"
        return estimate_s, "roofline"

    def merge(self, other: "ProfileStore") -> int:
        """Fold another store's samples in (warm-start across runs).

        Welford states combine exactly (Chan et al. parallel variance), so
        merging N per-run stores equals one store that saw every sample.
        Returns the number of keys touched.
        """
        for k, o in other._entries.items():
            e = self._entries.get(k)
            if e is None:
                self._entries[k] = ProfileEntry(o.count, o.mean_s, o.m2, o.min_s)
                continue
            n = e.count + o.count
            if n == 0:
                continue
            delta = o.mean_s - e.mean_s
            e.m2 = e.m2 + o.m2 + delta * delta * e.count * o.count / n
            e.mean_s = e.mean_s + delta * o.count / n
            e.count = n
            e.min_s = min(e.min_s, o.min_s)
        return len(other._entries)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {
                "min_samples": self.min_samples,
                "entries": {
                    k: {"count": e.count, "mean_s": e.mean_s, "m2": e.m2, "min_s": e.min_s}
                    for k, e in self._entries.items()
                },
            },
            indent=1,
        )

    @classmethod
    def from_json(cls, text: str) -> "ProfileStore":
        raw = json.loads(text)
        store = cls(min_samples=raw.get("min_samples", 2))
        for k, d in raw.get("entries", {}).items():
            store._entries[k] = ProfileEntry(
                count=d["count"], mean_s=d["mean_s"], m2=d.get("m2", 0.0),
                min_s=d.get("min_s", float("inf")),
            )
        return store

    def __len__(self) -> int:
        return len(self._entries)
