"""Profile-guided heterogeneous dispatch — closing the paper's loop.

The source paper motivates performance analysis as the input to *placement*:
"determining the most suitable platform for dispatching tasks, ensuring that
workloads are allocated to the processing units where they can execute most
effectively".  The rest of this repo measures (uprobes, tracepoints, SDFG,
roofline); this package acts on the measurements:

    registry.py    dispatchable backend targets (Pallas / chunked / ref /
                   interpret) with ChipSpec-derived static cost parameters
    cost.py        a-priori pricing of an SDFG region per backend (roofline)
    profiles.py    online profile store — measured samples override estimates
                   once warm (the Adaptyst feedback loop)
    dispatcher.py  argmin-cost routing of ops / serving requests / train
                   steps, every decision recorded as a ``dispatch`` event

Typical use::

    from repro.dispatch import Dispatcher, DispatchConfig, default_registry

    disp = Dispatcher(DispatchConfig(policy="profiled"), log=log)
    out = disp.dispatch("decode_step", {"chunked": f1, "ref": f2}, *args)
"""
from repro.dispatch.profiles import ProfileStore, signature

# Everything else imports jax at module level; re-export lazily (PEP 562) so
# jax-free consumers of ProfileStore — the trace session loader, the fleet
# client/daemon, the router's cost seeding — don't drag jax in.  The actual
# dispatcher always runs next to an engine, which already paid for jax.
_LAZY = {
    "CostEstimate": "repro.dispatch.cost",
    "estimate_callable": "repro.dispatch.cost",
    "estimate_region": "repro.dispatch.cost",
    "estimate_sdfg": "repro.dispatch.cost",
    "DispatchConfig": "repro.dispatch.dispatcher",
    "DispatchDecision": "repro.dispatch.dispatcher",
    "Dispatcher": "repro.dispatch.dispatcher",
    "with_impl": "repro.dispatch.dispatcher",
    "BackendRegistry": "repro.dispatch.registry",
    "BackendTarget": "repro.dispatch.registry",
    "default_registry": "repro.dispatch.registry",
    "host_registry": "repro.dispatch.registry",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


__all__ = [
    "BackendRegistry",
    "BackendTarget",
    "CostEstimate",
    "DispatchConfig",
    "DispatchDecision",
    "Dispatcher",
    "ProfileStore",
    "default_registry",
    "estimate_callable",
    "estimate_region",
    "estimate_sdfg",
    "host_registry",
    "signature",
    "with_impl",
]
