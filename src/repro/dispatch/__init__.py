"""Profile-guided heterogeneous dispatch — closing the paper's loop.

The source paper motivates performance analysis as the input to *placement*:
"determining the most suitable platform for dispatching tasks, ensuring that
workloads are allocated to the processing units where they can execute most
effectively".  The rest of this repo measures (uprobes, tracepoints, SDFG,
roofline); this package acts on the measurements:

    registry.py    dispatchable backend targets (Pallas / chunked / ref /
                   interpret) with ChipSpec-derived static cost parameters
    cost.py        a-priori pricing of an SDFG region per backend (roofline)
    profiles.py    online profile store — measured samples override estimates
                   once warm (the Adaptyst feedback loop)
    dispatcher.py  argmin-cost routing of ops / serving requests / train
                   steps, every decision recorded as a ``dispatch`` event

Typical use::

    from repro.dispatch import Dispatcher, DispatchConfig, default_registry

    disp = Dispatcher(DispatchConfig(policy="profiled"), log=log)
    out = disp.dispatch("decode_step", {"chunked": f1, "ref": f2}, *args)
"""
from repro.dispatch.cost import CostEstimate, estimate_callable, estimate_region, estimate_sdfg
from repro.dispatch.dispatcher import DispatchConfig, DispatchDecision, Dispatcher, with_impl
from repro.dispatch.profiles import ProfileStore, signature
from repro.dispatch.registry import (
    BackendRegistry,
    BackendTarget,
    default_registry,
    host_registry,
)

__all__ = [
    "BackendRegistry",
    "BackendTarget",
    "CostEstimate",
    "DispatchConfig",
    "DispatchDecision",
    "Dispatcher",
    "ProfileStore",
    "default_registry",
    "estimate_callable",
    "estimate_region",
    "estimate_sdfg",
    "host_registry",
    "signature",
    "with_impl",
]
