"""A-priori cost model: price an SDFG region per backend target.

The estimate reuses the roofline decomposition (compute vs memory vs
interconnect terms against ChipSpec peaks) with the target's static factors
from :mod:`repro.dispatch.registry` applied on top — so before anything has
ever run, every (region, backend) pair has a defensible seconds figure.
These estimates seed the dispatcher; measured profiles replace them once warm
(see :mod:`repro.dispatch.profiles`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core import sdfg as sdfg_mod
from repro.core.sdfg import HBM, HOST, ICI, MXU, SDFG, Region, VPU
from repro.dispatch.registry import BackendTarget
from repro.hw.specs import ChipSpec, default_chip


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Priced execution of one region (or whole graph) on one backend."""

    backend: str
    seconds: float
    t_compute: float
    t_memory: float
    t_collective: float
    t_host: float
    source: str = "roofline"  # roofline | measured

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
            "host": self.t_host,
        }
        return max(terms, key=terms.get)


def estimate_region(
    region: Region,
    target: BackendTarget,
    chip: Optional[ChipSpec] = None,
) -> CostEstimate:
    """Roofline pricing of ``region`` on ``target``.

    max(compute, memory) + collective + host + launch overhead.  The compute
    term uses the efficiency of the component class that *bounds* the region
    (its Adaptyst match); the memory term applies the target's byte
    amplification (reference paths materialise intermediates the fused paths
    never write).
    """
    chip = chip or default_chip()
    match = region.match(chip)
    eff = max(target.efficiency(match), 1e-3)
    t_compute = region.flops / (chip.peak_flops_bf16 * eff)
    t_memory = region.bytes * target.byte_amplification / chip.hbm_bw
    ici_bytes = float(region.backends.get(ICI, 0.0))
    t_collective = ici_bytes / chip.ici_bisection_bw
    host_bytes = float(region.backends.get(HOST, 0.0))
    t_host = host_bytes / chip.host_bw
    seconds = target.launch_overhead_s + max(t_compute, t_memory) + t_collective + t_host
    return CostEstimate(
        backend=target.name,
        seconds=seconds,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        t_host=t_host,
    )


def estimate_sdfg(
    graph: SDFG,
    target: BackendTarget,
    chip: Optional[ChipSpec] = None,
) -> dict[str, CostEstimate]:
    """Per-region estimates for a whole extracted graph."""
    chip = chip or default_chip()
    return {name: estimate_region(r, target, chip) for name, r in graph.regions().items()}


def total_seconds(estimates: dict[str, CostEstimate]) -> float:
    return sum(e.seconds for e in estimates.values())


def estimate_callable(
    fn: Callable,
    *args,
    target: BackendTarget,
    chip: Optional[ChipSpec] = None,
    **kwargs,
) -> CostEstimate:
    """Price a whole callable on ``target`` as a single fused region.

    The jaxpr is extracted from the *canonical* formulation of the op (the
    caller should trace the reference/chunked path — a Pallas ``pallas_call``
    is opaque to the jaxpr walk); the target factors then differentiate the
    implementation variants over identical abstract work.
    """
    chip = chip or default_chip()
    graph = sdfg_mod.extract(fn, *args, **kwargs)
    merged = Region("<callable>")
    for r in graph.regions().values():
        merged.flops += r.flops
        merged.bytes += r.bytes
        merged.nodes += r.nodes
        for k, v in r.backends.items():
            merged.backends[k] += v
    return estimate_region(merged, target, chip)
