"""Backend registry: the dispatchable implementation variants.

Every op in :mod:`repro.kernels.ops` already exists in several
implementations — the Pallas kernel, the chunked/production jnp path, and the
naive full-materialisation reference.  This module names those variants as
*dispatch targets* and attaches a static cost model to each, derived from the
:class:`~repro.hw.specs.ChipSpec` constants (the Adaptyst "backend module"
idea: one model per system component, priced a priori, corrected by profiles).

The static model per target is three numbers applied on top of the chip's
roofline terms:

    ``flop_efficiency``     fraction of peak FLOP/s the variant sustains
                            (per SDFG component class — MXU work runs closer
                            to peak in a fused Pallas kernel than in the
                            reference einsum chain)
    ``byte_amplification``  multiplier on HBM traffic (the reference paths
                            materialise O(S²) score matrices the fused paths
                            never write)
    ``launch_overhead_s``   fixed per-call cost (grid setup, chunk-loop
                            bookkeeping) — dominates for tiny shapes, which
                            is exactly why the *reference* path wins there
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Optional

import jax

from repro.hw.specs import ChipSpec, default_chip

# SDFG component classes (mirrors repro.core.sdfg constants; string-typed to
# avoid importing jax-heavy modules at registry-definition time).
MXU, VPU, HBM, ICI, HOST = "MXU", "VPU", "HBM", "ICI", "HOST"


@dataclasses.dataclass(frozen=True)
class BackendTarget:
    """One dispatchable implementation variant with its static cost factors."""

    name: str  # registry key, e.g. "pallas"
    impl: str  # repro.kernels.ops impl string this target maps to
    description: str = ""
    flop_efficiency: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {MXU: 0.7, VPU: 0.5}
    )
    byte_amplification: float = 1.0
    launch_overhead_s: float = 1e-6
    requires_tpu: bool = False  # Pallas→Mosaic only lowers on real TPU

    def efficiency(self, component: str) -> float:
        """Sustained fraction of peak for work bound by ``component``."""
        return float(self.flop_efficiency.get(component, self.flop_efficiency.get(VPU, 0.5)))

    def available(self) -> bool:
        return not self.requires_tpu or jax.default_backend() == "tpu"


class BackendRegistry:
    """Named set of dispatch targets bound to one chip model."""

    def __init__(self, chip: Optional[ChipSpec] = None) -> None:
        self.chip = chip or default_chip()
        self._targets: dict[str, BackendTarget] = {}

    def register(self, target: BackendTarget) -> BackendTarget:
        if target.name in self._targets:
            raise ValueError(f"backend {target.name!r} already registered")
        self._targets[target.name] = target
        return target

    def get(self, name: str) -> BackendTarget:
        try:
            return self._targets[name]
        except KeyError:
            raise KeyError(
                f"unknown backend {name!r}; registered: {sorted(self._targets)}"
            ) from None

    def names(self) -> list[str]:
        return list(self._targets)

    def targets(self, names: Optional[Iterable[str]] = None) -> list[BackendTarget]:
        if names is None:
            return list(self._targets.values())
        return [self.get(n) for n in names]

    def available(self) -> list[BackendTarget]:
        """Targets executable in this process (Pallas excluded off-TPU)."""
        return [t for t in self._targets.values() if t.available()]

    def __contains__(self, name: str) -> bool:
        return name in self._targets

    def __len__(self) -> int:
        return len(self._targets)


def default_registry(chip: Optional[ChipSpec] = None) -> BackendRegistry:
    """The three implementation tiers that exist for every hot-spot op.

    Factor rationale (priced against the TPU-v5e ChipSpec):

    * ``pallas`` — fused VMEM-resident kernels: near-peak MXU, no score
      materialisation, but a per-call grid-launch cost.
    * ``chunked`` — the production jnp fallback: same asymptotic bytes as the
      kernels (chunked softmax never materialises S²) with a small constant
      re-read amplification and per-chunk loop overhead.
    * ``ref`` — naive full-materialisation oracle: negligible launch cost
      (one einsum chain), heavy byte amplification — the right choice only
      for tiny shapes, which is precisely the dispatcher's opening move.
    """
    reg = BackendRegistry(chip)
    reg.register(
        BackendTarget(
            name="pallas",
            impl="pallas",
            description="fused Pallas kernels (Mosaic; TPU-only lowering)",
            flop_efficiency={MXU: 0.85, VPU: 0.6, HBM: 0.6, HOST: 0.1, ICI: 0.6},
            byte_amplification=1.0,
            launch_overhead_s=2e-6,
            requires_tpu=True,
        )
    )
    reg.register(
        BackendTarget(
            name="chunked",
            impl="chunked",
            description="chunked pure-jnp production path (lowers everywhere)",
            flop_efficiency={MXU: 0.65, VPU: 0.45, HBM: 0.5, HOST: 0.1, ICI: 0.5},
            byte_amplification=1.15,
            launch_overhead_s=4e-6,
        )
    )
    reg.register(
        BackendTarget(
            name="ref",
            impl="ref",
            description="naive full-materialisation oracle (tiny shapes only)",
            flop_efficiency={MXU: 0.6, VPU: 0.4, HBM: 0.4, HOST: 0.1, ICI: 0.4},
            byte_amplification=6.0,
            launch_overhead_s=2e-7,
        )
    )
    return reg


def host_registry(chip: Optional[ChipSpec] = None) -> BackendRegistry:
    """Registry restricted to targets that execute on this process's devices.

    On the CPU container that is {chunked, ref}; on TPU all three.  Used by
    the runtime integrations (serving engine / train supervisor) so the
    dispatcher never routes a request to a backend that cannot run.
    """
    full = default_registry(chip)
    reg = BackendRegistry(full.chip)
    for t in full.available():
        reg.register(t)
    return reg
