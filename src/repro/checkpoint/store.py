"""Checkpoint store: manifest + npz payloads, async writer, elastic restore.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json     tree structure, per-leaf path/shape/dtype
        arrays.npz        one entry per flattened leaf

Properties needed at cluster scale, preserved here:
* **Async save** — the train loop is blocked only for the device→host
  snapshot; serialisation/fsync happens on a writer thread
  (:class:`AsyncCheckpointer`), overlapping the next steps.
* **Elastic restore** — payloads are stored *unsharded* (host-gathered);
  restore ``device_put``s against whatever sharding the *new* mesh dictates,
  so a 16×16 checkpoint restores onto 8×16 unchanged (tested in
  tests/test_runtime.py).  A production deployment would swap the payload
  format for per-shard files (e.g. OCDBT) without touching this interface.
* **Atomicity** — writes land in ``<dir>/.tmp_stepN`` and are renamed only
  after fsync, so a killed writer never leaves a half checkpoint visible.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save(directory: str, step: int, state: PyTree) -> str:
    """Synchronous checkpoint write.  Returns the final path."""
    host_state = jax.device_get(state)
    return _write(directory, step, host_state)


def _write(directory: str, step: int, host_state: PyTree) -> str:
    flat, _ = _flatten(host_state)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp_step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "leaves": [
            {"key": k, "shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
            for k, v in flat
        ],
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **{k: np.asarray(v) for k, v in flat})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    step: int,
    like: PyTree,
    shardings: Optional[PyTree] = None,
) -> PyTree:
    """Restore into the structure of ``like``; re-shard onto ``shardings``.

    ``like`` may be abstract (ShapeDtypeStructs) — only its treedef is used.
    Elastic: the stored payload is unsharded, so any target mesh works.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    flat_like, treedef = _flatten(like)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        leaves = [z[k] for k, _ in flat_like]
    if shardings is not None:
        flat_shd = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, flat_shd)]
    else:
        leaves = [jax.numpy.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Overlaps checkpoint serialisation with training.

    ``save()`` snapshots to host (blocking, bounded by PCIe) and hands the
    write to a daemon thread; ``wait()`` joins the in-flight write.  One
    in-flight checkpoint at a time (back-pressure, matching real stores).
    """

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: PyTree) -> None:
        self.wait()
        host_state = jax.device_get(state)

        def _run():
            try:
                _write(self.directory, step, host_state)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True, name="ckpt-writer")
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.directory) if d.startswith("step_")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
