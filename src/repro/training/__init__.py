"""Training substrate: AdamW, LR schedules, microbatched train-step builder."""
