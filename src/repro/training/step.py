"""Train-step builder: microbatch accumulation + AdamW + sharding constraints.

The returned ``train_step(state, batch)`` is pure and jit/pjit-ready:
* microbatch gradient accumulation via lax.scan (accumulator dtype is
  configurable — bf16 accumulation is the gradient-compression knob that
  halves accumulation HBM and cross-pod all-reduce bytes);
* static tracepoints fire at step level (the USDT analogue);
* lifecycle events (step spawn/exit) are recorded by the caller
  (repro.runtime.supervisor), keeping the step function pure.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import tracepoints as tp
from repro.models import lm
from repro.training import optim

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: optim.AdamWConfig = optim.AdamWConfig()
    microbatches: int = 1
    grad_accum_dtype: str = "float32"  # 'bfloat16' = compressed accumulation


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key: jax.Array) -> dict:
    params = lm.init_params(cfg, key)
    opt_cfg = dataclasses.replace(tcfg.opt, moment_dtype=cfg.moment_dtype)
    return {"params": params, "opt": optim.init_opt_state(params, opt_cfg)}


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig) -> dict:
    return jax.eval_shape(lambda k: init_train_state(cfg, tcfg, k), jax.random.PRNGKey(0))


def train_state_axes(cfg: ModelConfig) -> dict:
    """Logical axes for the whole train state (opt moments mirror params)."""
    p_axes = lm.param_axes(cfg)
    return {
        "params": p_axes,
        "opt": {"mu": p_axes, "nu": p_axes, "step": ""},
    }


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": (B, S) int32, "labels": (B, S) int32,
            optional "frontend_embed": (B, S, D)}.
    """
    opt_cfg = dataclasses.replace(tcfg.opt, moment_dtype=cfg.moment_dtype)
    n_micro = tcfg.microbatches
    acc_dtype = jnp.dtype(tcfg.grad_accum_dtype)

    def loss_for(params, tokens, labels, fe):
        loss, metrics = lm.loss_fn(params, cfg, tokens, labels, fe)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        tokens, labels = batch["tokens"], batch["labels"]
        fe = batch.get("frontend_embed")

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels, fe)
        else:
            B = tokens.shape[0]
            assert B % n_micro == 0, f"batch {B} % microbatches {n_micro}"
            mb = B // n_micro

            def split(x):
                return x.reshape((n_micro, mb) + x.shape[1:])

            mb_batch = jax.tree.map(split, {"t": tokens, "l": labels, "f": fe})

            def body(carry, xs):
                acc, loss_sum = carry
                (loss, _m), g = grad_fn(params, xs["t"], xs["l"], xs.get("f"))
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(acc_dtype), acc, g
                )
                return (acc, loss_sum + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mb_batch
            )
            grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32), grads)
            loss = loss_sum / n_micro
            metrics = {"ce": loss, "z_loss": jnp.zeros(()), "aux": jnp.zeros(()),
                       "tokens": jnp.float32(tokens.size)}

        new_params, new_opt, opt_metrics = optim.adamw_update(
            params, grads, state["opt"], opt_cfg
        )
        tp.point("train.loss", loss)
        tp.point("train.grad_norm", opt_metrics["grad_norm"])
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step
