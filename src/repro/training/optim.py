"""AdamW with configurable moment dtype (fp32 math on the fly).

``moment_dtype='bfloat16'`` halves optimizer-state HBM — the distributed-
optimization trick that lets jamba-1.5-large (398B params) train on 16 GiB
v5e chips at 256-way sharding (DESIGN.md §6): bf16 params (2B) + 2×bf16
moments (4B) = 6 B/param vs. 14 B/param for the fp32-everything layout.
All update arithmetic runs in f32; only storage is compressed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(opt: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, opt.warmup_steps)
    frac = (step - opt.warmup_steps) / jnp.maximum(
        1.0, opt.total_steps - opt.warmup_steps
    )
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = opt.min_lr_ratio + (1 - opt.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return opt.peak_lr * jnp.where(step < opt.warmup_steps, warm, cos)


def init_opt_state(params: PyTree, opt: AdamWConfig) -> dict:
    dt = jnp.dtype(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params: PyTree, grads: PyTree, state: dict, opt: AdamWConfig
) -> tuple[PyTree, dict, dict[str, jax.Array]]:
    """One AdamW step; returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(opt, step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(opt.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + opt.eps)
        if opt.weight_decay and p.ndim >= 2:  # no decay on norms/biases/scalars
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu32.astype(dt), nu32.astype(dt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
