"""RWKV6 "Finch" block: data-dependent decay + token-shift (attention-free).

Time-mix uses the chunked WKV scan (kernels.ops.rwkv6_scan) in full-sequence
mode and the O(1) per-step recurrence in decode mode.  State per sequence:
one shifted-token vector per mix point plus the (H, K, V) WKV state.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.nn import core as nn

Cache = dict[str, jax.Array]

_TARGETS = ("r", "k", "v", "w", "g")  # ddlerp mix targets


def _dims(cfg: ModelConfig) -> tuple[int, int]:
    K = cfg.rwkv.head_dim
    H = cfg.d_model // K
    return H, K


def time_mix_init(pf: nn.ParamFactory, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, K = _dims(cfg)
    r = cfg.rwkv
    out_scale = 0.02 / max(1, 2 * cfg.n_layers) ** 0.5

    def decay_init(key, shape):
        # per-channel base decay in [-7, ~0): slow..fast forget
        n = shape[-1]
        return jnp.broadcast_to(
            -6.0 + 5.0 * (jnp.arange(n, dtype=jnp.float32) / max(1, n - 1)) ** 0.7, shape
        )

    p = {
        "mu_base": pf.param("mu_base", (D,), ("embed",), init="zeros"),
        "mu": pf.param("mu", (len(_TARGETS), D), (None, "embed"), init="zeros"),
        "mix_w1": pf.param(
            "mix_w1", (D, len(_TARGETS), r.mix_lora), ("embed", None, None)
        ),
        "mix_w2": pf.param(
            "mix_w2", (len(_TARGETS), r.mix_lora, D), (None, None, "embed"), init="zeros"
        ),
        "recv": nn.linear_init(pf, "recv", (D,), (H, K), ("embed",), ("heads", "head_dim")),
        "key": nn.linear_init(pf, "key", (D,), (H, K), ("embed",), ("heads", "head_dim")),
        "value": nn.linear_init(pf, "value", (D,), (H, K), ("embed",), ("heads", "head_dim")),
        "gate": nn.linear_init(pf, "gate", (D,), (H, K), ("embed",), ("heads", "head_dim")),
        "w0": pf.param("w0", (H, K), ("heads", "head_dim"), init=decay_init, dtype=jnp.float32),
        "decay_w1": pf.param("decay_w1", (D, r.decay_lora), ("embed", None)),
        "decay_w2": pf.param(
            "decay_w2", (r.decay_lora, H, K), (None, "heads", "head_dim"), init="zeros"
        ),
        "u": pf.param("u", (H, K), ("heads", "head_dim"), scale=0.5),
        "ln_scale": pf.param("ln_scale", (H, K), ("heads", "head_dim"), init="ones", dtype=jnp.float32),
        "ln_bias": pf.param("ln_bias", (H, K), ("heads", "head_dim"), init="zeros", dtype=jnp.float32),
        "out": nn.linear_init(
            pf, "out", (H, K), (D,), ("heads", "head_dim"), ("embed",), scale=out_scale
        ),
    }
    return p


def channel_mix_init(pf: nn.ParamFactory, cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    out_scale = 0.02 / max(1, 2 * cfg.n_layers) ** 0.5
    return {
        "mu_k": pf.param("mu_k", (D,), ("embed",), init="zeros"),
        "mu_r": pf.param("mu_r", (D,), ("embed",), init="zeros"),
        "wk": nn.linear_init(pf, "wk", (D,), (F,), ("embed",), ("mlp",)),
        "wv": nn.linear_init(pf, "wv", (F,), (D,), ("mlp",), ("embed",), scale=out_scale),
        "wr": nn.linear_init(pf, "wr", (D,), (D,), ("embed",), ("embed_out",)),
    }


def _shifted(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x_{t-1} (zeros / cached last token at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: jax.Array, sx: jax.Array) -> list[jax.Array]:
    """Data-dependent token-shift interpolation (RWKV6), one mix per target."""
    dx = sx - x
    xx = x + dx * p["mu_base"].astype(x.dtype)
    lo = jnp.tanh(jnp.einsum("bsd,dnr->bsnr", xx.astype(jnp.float32), p["mix_w1"].astype(jnp.float32)))
    delta = jnp.einsum("bsnr,nrd->bsnd", lo, p["mix_w2"].astype(jnp.float32))  # (B,S,n,D)
    outs = []
    for i, _t in enumerate(_TARGETS):
        mu_i = p["mu"][i].astype(jnp.float32) + delta[:, :, i]
        outs.append(x + dx * mu_i.astype(x.dtype))
    return outs


def time_mix_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str = "full",
    cache: Optional[Cache] = None,
) -> tuple[jax.Array, Optional[Cache]]:
    B, S, D = x.shape
    H, K = _dims(cfg)
    prev = cache["shift"][:, None] if cache is not None else None
    sx = _shifted(x, prev)
    xr, xk, xv, xw, xg = _ddlerp(p, x, sx)

    r = nn.linear(p["recv"], xr)  # (B,S,H,K)
    k = nn.linear(p["key"], xk)
    v = nn.linear(p["value"], xv)
    g = nn.linear(p["gate"], xg)
    lw = jnp.tanh(xw.astype(jnp.float32) @ p["decay_w1"].astype(jnp.float32))
    lw = jnp.einsum("bsr,rhk->bshk", lw, p["decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + lw))  # (B,S,H,K) in (0,1)

    state0 = (
        cache["wkv"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((B, H, K, K), jnp.float32)
    )
    if mode == "full":
        out, state = ops.rwkv6_scan(
            r, k, v, w.astype(jnp.float32), p["u"], state0,
            chunk=cfg.rwkv.chunk, remat_chunks=cfg.chunk_scan_remat,
        )
    else:
        assert S == 1
        out, state = ops.rwkv6_step(
            r[:, 0], k[:, 0], v[:, 0], w[:, 0].astype(r.dtype), p["u"], state0
        )
        out = out[:, None]

    # per-head group-norm, then gate and project
    of = out.astype(jnp.float32)
    mean = of.mean(axis=-1, keepdims=True)
    var = of.var(axis=-1, keepdims=True)
    of = (of - mean) * jax.lax.rsqrt(var + 64e-5) * p["ln_scale"] + p["ln_bias"]
    y = of.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = nn.linear(p["out"], y, n_in=2)
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1].astype(cache["shift"].dtype), "wkv": state}
    return y, new_cache


def channel_mix_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[Cache] = None,
) -> tuple[jax.Array, Optional[Cache]]:
    prev = cache["shift"][:, None] if cache is not None else None
    sx = _shifted(x, prev)
    dx = sx - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(nn.linear(p["wk"], xk).astype(jnp.float32)))
    y = jax.nn.sigmoid(nn.linear(p["wr"], xr).astype(jnp.float32)) * nn.linear(
        p["wv"], kk.astype(x.dtype)
    ).astype(jnp.float32)
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1].astype(cache["shift"].dtype)}
    return y.astype(x.dtype), new_cache


def init_time_cache(cfg: ModelConfig, batch: int, dtype: Any) -> Cache:
    H, K = _dims(cfg)
    return {
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
    }


def init_channel_cache(cfg: ModelConfig, batch: int, dtype: Any) -> Cache:
    return {"shift": jnp.zeros((batch, cfg.d_model), dtype)}
