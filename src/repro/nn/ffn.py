"""FFN blocks: dense gated-GLU and GShard-style MoE with capacity routing.

MoE is TPU-idiomatic (MXU-friendly dense dispatch, not GPU scatter-gather):
tokens are grouped, each group routes top-k into per-expert capacity buckets
via one-hot dispatch/combine einsums, and the expert compute itself is a
grouped matmul (kernels.ops.moe_ffn / the moe_gmm Pallas kernel).  Experts are
sharded over the 'model' mesh axis (expert parallelism); GSPMD materialises
the token all-to-all from the dispatch einsum's shardings.

Aux losses (load-balance + router z-loss) are returned functionally and
accumulated through the layer scan.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.kernels import ops
from repro.nn import core as nn


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def ffn_init(pf: nn.ParamFactory, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    out_scale = 0.02 / max(1, 2 * cfg.n_layers) ** 0.5
    return {
        "w1": nn.linear_init(pf, "w1", (D,), (F,), ("embed",), ("mlp",)),
        "w3": nn.linear_init(pf, "w3", (D,), (F,), ("embed",), ("mlp",)),
        "w2": nn.linear_init(pf, "w2", (F,), (D,), ("mlp",), ("embed",), scale=out_scale),
    }


def ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = nn.ACTIVATIONS[cfg.act]
    h = act(nn.linear(p["w1"], x).astype(jnp.float32)) * nn.linear(p["w3"], x).astype(
        jnp.float32
    )
    return nn.linear(p["w2"], h.astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_init(pf: nn.ParamFactory, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert or cfg.d_ff
    out_scale = 0.02 / max(1, 2 * cfg.n_layers) ** 0.5
    p = {
        "router": nn.linear_init(
            pf, "router", (D,), (E,), ("embed",), ("experts",), scale=0.02
        ),
        "w1": pf.param("w1", (E, D, F), ("experts", "embed", "expert_mlp")),
        "w3": pf.param("w3", (E, D, F), ("experts", "embed", "expert_mlp")),
        "w2": pf.param(
            "w2", (E, F, D), ("experts", "expert_mlp", "embed"), scale=out_scale
        ),
    }
    if m.n_shared:
        p["shared"] = ffn_init(pf, cfg, d_ff=m.n_shared * F)
    return p


def _capacity(group: int, m: MoEConfig) -> int:
    c = math.ceil(group * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)  # pad to sublane multiple


def pick_group_size(n_tokens: int, target: int = 2048) -> int:
    """Largest divisor of n_tokens that is <= target (prefer big groups)."""
    g = min(n_tokens, target)
    while n_tokens % g:
        g -= 1
    return g


def moe_apply(
    p: dict, x: jax.Array, cfg: ModelConfig, *, group_size: Optional[int] = None
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """x: (B, S, D) -> (y, aux_losses).

    GShard top-k capacity routing with deterministic (position-priority)
    overflow dropping; gates renormalised over the kept assignments.
    """
    assert cfg.moe is not None
    m = cfg.moe
    B, S, D = x.shape
    E = m.n_experts
    T = B * S
    G = group_size or pick_group_size(T)
    n_g = T // G
    C = _capacity(G, m)
    xg = x.reshape(n_g, G, D)

    logits = nn.linear(p["router"], xg).astype(jnp.float32)  # (n_g, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)  # (n_g, G, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Position-in-expert, priority = (choice rank, token position).
    dispatch = jnp.zeros((n_g, G, E, C), x.dtype)
    combine = jnp.zeros((n_g, G, E, C), jnp.float32)
    counts = jnp.zeros((n_g, E), jnp.int32)
    for kk in range(m.top_k):
        e_k = idx[:, :, kk]  # (n_g, G)
        onehot_e = jax.nn.one_hot(e_k, E, dtype=jnp.int32)  # (n_g, G, E)
        pos_k = counts[:, None, :] + jnp.cumsum(onehot_e, axis=1) - onehot_e
        pos_in_e = jnp.take_along_axis(pos_k, e_k[..., None], axis=2)[..., 0]  # (n_g, G)
        keep = pos_in_e < C
        counts = counts + onehot_e.sum(axis=1)
        oh_ec = jax.nn.one_hot(e_k, E)[..., None] * jax.nn.one_hot(
            jnp.where(keep, pos_in_e, C), C + 1
        )[..., None, :-1]  # (n_g, G, E, C); overflow row C sliced off
        dispatch = dispatch + oh_ec.astype(x.dtype)
        combine = combine + oh_ec * (gates[:, :, kk] * keep)[..., None, None]

    # Dense dispatch: (n_g, G, E, C) x (n_g, G, D) -> (E, n_g*C, D)
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, x.reshape(n_g, G, D))
    expert_in = expert_in.transpose(1, 0, 2, 3).reshape(E, n_g * C, D)
    expert_out = ops.moe_ffn(expert_in, p["w1"], p["w3"], p["w2"], act=cfg.act)
    expert_out = expert_out.reshape(E, n_g, C, D).transpose(1, 0, 2, 3)  # (n_g,E,C,D)
    y = jnp.einsum(
        "gtec,gecd->gtd", combine.astype(jnp.float32), expert_out.astype(jnp.float32)
    )
    y = y.reshape(B, S, D).astype(x.dtype)

    if "shared" in p:
        y = y + ffn_apply(p["shared"], x, cfg)

    # Aux losses (Switch/GShard load-balance + z-loss), f32.
    me = probs.mean(axis=(0, 1))  # (E,) mean router prob
    ce = (dispatch.sum(axis=(1, 3)) / G).mean(axis=0).astype(jnp.float32)  # frac routed
    aux = {
        "moe_load_balance": E * jnp.sum(me * ce) * m.router_aux_weight,
        "moe_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        * m.router_z_weight,
    }
    return y, aux
