"""Modality frontend STUBS (per the assignment brief).

``[vlm]`` (chameleon) and ``[audio]`` (musicgen) specify the transformer
backbone only; the VQ-VAE image tokenizer / EnCodec neural codec are stubs:
``input_specs()`` provides precomputed patch/frame embeddings as an extra
``(B, S, d_model)`` input stream.  The stub applies a learned projection and
adds the result to the token embeddings (early fusion).
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.nn import core as nn


def frontend_init(pf: nn.ParamFactory, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    return {
        "proj": nn.linear_init(pf, "proj", (D,), (D,), ("embed",), ("embed_out",), scale=0.02)
    }


def frontend_apply(p: dict, emb: jax.Array) -> jax.Array:
    """emb: precomputed (B, S, d_model) frame/patch embeddings."""
    return nn.linear(p["proj"], emb)
