"""Pure-JAX neural-network module layer.

Modules are plain functions over nested param dicts.  Every parameter is
created through a :class:`~repro.nn.core.ParamFactory`, so a single builder
definition yields (a) initialized values, (b) logical sharding axes, and
(c) allocation-free ShapeDtypeStructs for the multi-pod dry-run.
"""
