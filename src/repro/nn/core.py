"""Param factories + basic modules (Linear, RMSNorm, Embedding, RoPE).

Parameters are nested dicts of arrays.  A module is two functions:

* a *builder* ``foo_init(pf, ...)`` that declares every parameter through the
  :class:`ParamFactory` (name, shape, **logical axes**, init law), and
* an *apply* ``foo(params, x, ...)`` that consumes the dict.

Because the builder is the single source of truth, running it under a
:class:`ValueFactory` yields initialized arrays, under an :class:`AxesFactory`
the logical-axis tree (consumed by ``repro.distributed.sharding``), and under
``jax.eval_shape`` the allocation-free param skeleton used by the dry-run.
"""
from __future__ import annotations

import zlib
from contextlib import contextmanager
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple[str | None, ...]


class ParamFactory:
    """Base: tracks a '/'-joined scope path; subclasses realise params."""

    def __init__(self) -> None:
        self._scope: list[str] = []

    @contextmanager
    def scope(self, name: str) -> Iterator[None]:
        self._scope.append(name)
        try:
            yield
        finally:
            self._scope.pop()

    def _path(self, name: str) -> str:
        return "/".join(self._scope + [name])

    def param(
        self,
        name: str,
        shape: Sequence[int],
        axes: Axes,
        init: str | Callable = "normal",
        scale: float | None = None,
        dtype: Any = None,
    ) -> Any:
        if len(shape) != len(axes):
            raise ValueError(
                f"{self._path(name)}: shape {tuple(shape)} has {len(shape)} dims "
                f"but axes {axes} has {len(axes)}"
            )
        return self._make(self._path(name), tuple(shape), axes, init, scale, dtype)

    def _make(self, path, shape, axes, init, scale, dtype):  # pragma: no cover
        raise NotImplementedError


class ValueFactory(ParamFactory):
    """Realises initialized arrays.  Keys are derived from the param path
    (crc32 fold-in) so initialization is order- and refactor-independent."""

    def __init__(self, key: jax.Array, param_dtype: Any = jnp.bfloat16) -> None:
        super().__init__()
        self._key = key
        self.param_dtype = param_dtype

    def _make(self, path, shape, axes, init, scale, dtype):
        dtype = dtype or self.param_dtype
        key = jax.random.fold_in(self._key, zlib.crc32(path.encode()))
        if callable(init):
            return init(key, shape).astype(dtype)
        if init == "normal":
            std = scale if scale is not None else 0.02
            return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        raise ValueError(f"unknown init {init!r} at {path}")


class AxesFactory(ParamFactory):
    """Realises the logical-axes tree.

    Leaves are comma-joined strings ("embed,heads,head_dim"; '' = replicated
    dim) — strings are pytree *leaves*, so the axes tree maps/flattens in
    lockstep with the value tree (tuples would be descended into).
    """

    def _make(self, path, shape, axes, init, scale, dtype):
        return axes_str(axes)


def axes_str(axes: Axes) -> str:
    return ",".join(a if a else "" for a in axes)


def parse_axes(s: str) -> tuple[str | None, ...]:
    if s == "":
        return ()
    return tuple(a if a else None for a in s.split(","))


class ShapeFactory(ParamFactory):
    """Realises ShapeDtypeStructs without touching any device (dry-run)."""

    def __init__(self, param_dtype: Any = jnp.bfloat16) -> None:
        super().__init__()
        self.param_dtype = param_dtype

    def _make(self, path, shape, axes, init, scale, dtype):
        dtype = dtype or self.param_dtype
        if callable(init):  # special inits may fix their own dtype
            spec = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), shape))
            return jax.ShapeDtypeStruct(spec.shape, dtype)
        return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Basic modules
# ---------------------------------------------------------------------------


def linear_init(
    pf: ParamFactory,
    name: str,
    in_shape: Sequence[int],
    out_shape: Sequence[int],
    in_axes: Axes,
    out_axes: Axes,
    *,
    bias: bool = False,
    scale: float | None = None,
) -> dict:
    """General (possibly multi-dim) linear: contracts all of ``in_shape``."""
    with pf.scope(name):
        p = {
            "w": pf.param(
                "w",
                tuple(in_shape) + tuple(out_shape),
                tuple(in_axes) + tuple(out_axes),
                init="normal",
                scale=scale,
            )
        }
        if bias:
            p["b"] = pf.param("b", tuple(out_shape), tuple(out_axes), init="zeros")
    return p


def linear(p: dict, x: jax.Array, n_in: int = 1) -> jax.Array:
    """Contract the last ``n_in`` dims of x with the first ``n_in`` of w."""
    w = p["w"]
    n_out = w.ndim - n_in
    x_dims = tuple(range(x.ndim - n_in, x.ndim))
    w_dims = tuple(range(n_in))
    out = jax.lax.dot_general(
        x, w, (((x_dims), (w_dims)), ((), ())), preferred_element_type=x.dtype
    )
    if "b" in p:
        out = out + p["b"].astype(out.dtype)
    del n_out
    return out


def rmsnorm_init(pf: ParamFactory, name: str, dim: int, axis: str | None = "embed") -> dict:
    with pf.scope(name):
        # Norm scales live in f32: tiny and precision-critical.
        return {"scale": pf.param("scale", (dim,), (axis,), init="zeros", dtype=jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """(1 + scale)-parameterised RMSNorm (Gemma convention), f32 math."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(dtype)


def embedding_init(
    pf: ParamFactory, name: str, vocab: int, dim: int, *, scale: float | None = None
) -> dict:
    # std 1/sqrt(dim): unit-norm rows, so tied-unembed logits start at O(1)
    # (scale_by_dim archs multiply by sqrt(dim) on lookup, recovering unit std).
    scale = dim**-0.5 if scale is None else scale
    with pf.scope(name):
        return {"table": pf.param("table", (vocab, dim), ("vocab", "embed"), scale=scale)}


def embed(p: dict, ids: jax.Array, *, scale_by_dim: bool = False) -> jax.Array:
    out = jnp.take(p["table"], ids, axis=0)
    if scale_by_dim:
        out = out * jnp.asarray(np.sqrt(p["table"].shape[1]), out.dtype)
    return out


def unembed(p: dict, x: jax.Array) -> jax.Array:
    """Project hidden states to vocab logits (tied-embedding transpose)."""
    return jax.lax.dot_general(
        x,
        p["table"],
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, f32: (head_dim // 2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
