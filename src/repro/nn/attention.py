"""GQA attention block: global / sliding-window, softcap, QK-norm, QKV-bias.

Supports three execution modes:
* ``full``   — training / prefill over a whole sequence (flash/local path).
* ``decode`` — one new token against a KV cache (full or SWA ring buffer).

Cache contract (uniform for full and ring caches): ``pos_ids[b, s]`` is the
absolute position held in cache slot ``s`` (−1 ⇒ empty).  Ring buffers write
slot ``pos % size``; masking is entirely position-based so the attention op
never needs to know which cache kind it got.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.nn import core as nn

Cache = dict[str, jax.Array]


def attention_init(pf: nn.ParamFactory, cfg: ModelConfig) -> dict:
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "q": nn.linear_init(
            pf, "q", (D,), (Hq, hd), ("embed",), ("heads", "head_dim"), bias=cfg.qkv_bias
        ),
        "k": nn.linear_init(
            pf, "k", (D,), (Hkv, hd), ("embed",), ("kv_heads", "head_dim"), bias=cfg.qkv_bias
        ),
        "v": nn.linear_init(
            pf, "v", (D,), (Hkv, hd), ("embed",), ("kv_heads", "head_dim"), bias=cfg.qkv_bias
        ),
        "o": nn.linear_init(
            pf,
            "o",
            (Hq, hd),
            (D,),
            ("heads", "head_dim"),
            ("embed",),
            scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5,
        ),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(pf, "q_norm", hd, "head_dim")
        p["k_norm"] = nn.rmsnorm_init(pf, "k_norm", hd, "head_dim")
    return p


def _window(cfg: ModelConfig, mixer: str) -> Optional[int]:
    return cfg.sliding_window if mixer == "swa" else None


def init_cache(
    cfg: ModelConfig, mixer: str, batch: int, max_seq: int, dtype: Any
) -> Cache:
    """Full cache for global layers; ring buffer of `sliding_window` for SWA."""
    size = min(cfg.sliding_window, max_seq) if mixer == "swa" else max_seq
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos_ids": jnp.full((batch, size), -1, jnp.int32),
    }


def attention_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mixer: str,
    positions: jax.Array,
    *,
    mode: str = "full",
    cache: Optional[Cache] = None,
) -> tuple[jax.Array, Optional[Cache]]:
    """x: (B, S, D) for full; (B, 1, D) for decode.  positions: (B, S) / (B, 1)."""
    B, S, _ = x.shape
    window = _window(cfg, mixer)
    q = nn.linear(p["q"], x)  # (B, S, Hq, hd)
    k = nn.linear(p["k"], x)  # (B, S, Hkv, hd)
    v = nn.linear(p["v"], x)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = nn.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)

    if mode == "full":
        n_heads = q.shape[2]
        k_orig, v_orig = k, v  # cache gets the true KV heads, not padded ones
        pad_h = 0
        if cfg.pad_heads_to and cfg.pad_heads_to > n_heads:
            # §Perf: pad Q-head *activations* (params untouched) so the S²
            # attention compute shards over 'model' even when n_heads doesn't
            # divide it (smollm 15H, qwen2 14H vs a 16-way axis).  KV heads
            # are expanded to per-Q first (GQA grouping survives padding);
            # padded heads have zero q ⇒ garbage output, dropped before the
            # output projection.
            G = q.shape[2] // k.shape[2]
            if G > 1:
                k = jnp.repeat(k, G, axis=2)
                v = jnp.repeat(v, G, axis=2)
            pad_h = cfg.pad_heads_to - n_heads
            zpad = lambda a: jnp.concatenate(
                [a, jnp.zeros(a.shape[:2] + (pad_h, a.shape[3]), a.dtype)], axis=2
            )
            q, k, v = zpad(q), zpad(k), zpad(v)
        if cfg.activation_constraints:
            from repro.distributed.constrain import constrain

            kv_ax = "heads" if pad_h else "kv_heads"
            q = constrain(q, "batch", "seq", "heads", "head_dim")
            k = constrain(k, "batch", "seq", kv_ax, "head_dim")
            v = constrain(v, "batch", "seq", kv_ax, "head_dim")
        if cfg.fused_attention_vjp:
            from repro.kernels.flash_vjp import flash_attention_fused

            out = flash_attention_fused(
                q, k, v, True, window, cfg.attn_logit_softcap, None, 0, 512
            )
        else:
            out = ops.attention(
                q, k, v, causal=True, window=window, softcap=cfg.attn_logit_softcap
            )
        if pad_h:
            out = out[:, :, :n_heads]
        new_cache = None
        if cache is not None:
            new_cache = _fill_cache_from_prefill(cache, k_orig, v_orig, positions)
        out = nn.linear(p["o"], out, n_in=2)
        return out, new_cache

    assert mode == "decode" and cache is not None and S == 1
    cur = positions[:, 0]  # (B,)
    size = cache["k"].shape[1]
    slot = (cur % size).astype(jnp.int32)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    pos_ids = cache["pos_ids"].at[bidx, slot].set(cur.astype(jnp.int32))
    out = None
    if cfg.decode_split_kv:
        out = ops.decode_attention_seq_sharded(
            q[:, 0], k_cache, v_cache, pos_ids, cur,
            window=window, softcap=cfg.attn_logit_softcap,
            seq_axes=tuple(cfg.decode_seq_axes),
            batch_axes=tuple(cfg.decode_batch_axes),
        )
        if out is not None:
            out = out.reshape(B, 1, q.shape[2], q.shape[3])
    if out is None:
        out = ops.decode_attention(
            q[:, 0],
            k_cache,
            v_cache,
            pos_ids,
            cur,
            window=window,
            softcap=cfg.attn_logit_softcap,
        )[:, None]
    out = nn.linear(p["o"], out, n_in=2)
    return out, {"k": k_cache, "v": v_cache, "pos_ids": pos_ids}


def _fill_cache_from_prefill(
    cache: Cache, k: jax.Array, v: jax.Array, positions: jax.Array
) -> Cache:
    """Scatter prefill K/V into a (possibly smaller ring) cache by slot = pos % size."""
    B, S = positions.shape
    size = cache["k"].shape[1]
    if size >= S:
        # contiguous write at slots [pos]: for aligned prefill pos = arange(S)
        slots = positions % size
    else:
        # ring: only the last `size` positions survive; earlier writes are
        # overwritten by later ones in slot order. Scatter handles it since
        # later entries win with .at[].set on increasing positions? Scatter
        # order is unspecified -> mask to last `size` positions explicitly.
        keep_from = positions[:, -1:] - (size - 1)
        keep = positions >= keep_from
        slots = jnp.where(keep, positions % size, size)  # size = out-of-range drop
    bidx = jnp.arange(B)[:, None]
    k_cache = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype), mode="drop")
    v_cache = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype), mode="drop")
    pos_ids = cache["pos_ids"].at[bidx, slots].set(
        positions.astype(jnp.int32), mode="drop"
    )
    return {"k": k_cache, "v": v_cache, "pos_ids": pos_ids}
