"""Mamba-1 block (Jamba variant: RMSNorm on Δ/B/C for stability).

Full-sequence mode uses the chunked selective scan (kernels.ops.mamba_scan);
decode mode keeps O(1) state: a (d_conv−1)-deep conv window plus the
(d_inner, d_state) SSM state per sequence.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.nn import core as nn

Cache = dict[str, jax.Array]


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, mc.d_state, mc.d_conv, dt_rank


def mamba_init(pf: nn.ParamFactory, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    DI, N, DC, R = _dims(cfg)

    def a_init(key, shape):
        # S4D-real: A_log = log(1..N), broadcast over channels.
        return jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :], shape
        )

    def dt_bias_init(key, shape):
        # softplus^-1(dt) for dt ~ LogUniform[1e-3, 1e-1] (Mamba init).
        dt = jnp.exp(
            jax.random.uniform(key, shape, jnp.float32)
            * (math.log(0.1) - math.log(1e-3))
            + math.log(1e-3)
        )
        return dt + jnp.log(-jnp.expm1(-dt))

    out_scale = 0.02 / max(1, 2 * cfg.n_layers) ** 0.5
    return {
        "in_proj": nn.linear_init(pf, "in_proj", (D,), (2 * DI,), ("embed",), ("mlp",)),
        "conv_w": pf.param("conv_w", (DC, DI), (None, "mlp"), scale=1.0 / math.sqrt(DC)),
        "conv_b": pf.param("conv_b", (DI,), ("mlp",), init="zeros"),
        "x_proj": nn.linear_init(
            pf, "x_proj", (DI,), (R + 2 * N,), ("mlp",), (None,)
        ),
        "dt_proj": nn.linear_init(
            pf, "dt_proj", (R,), (DI,), (None,), ("mlp",), scale=R**-0.5
        ),
        "dt_bias": pf.param("dt_bias", (DI,), ("mlp",), init=dt_bias_init, dtype=jnp.float32),
        "A_log": pf.param("A_log", (DI, N), ("mlp", None), init=a_init, dtype=jnp.float32),
        "D": pf.param("D", (DI,), ("mlp",), init="ones", dtype=jnp.float32),
        "dt_norm": nn.rmsnorm_init(pf, "dt_norm", R, None),
        "b_norm": nn.rmsnorm_init(pf, "b_norm", N, None),
        "c_norm": nn.rmsnorm_init(pf, "c_norm", N, None),
        "out_proj": nn.linear_init(
            pf, "out_proj", (DI,), (D,), ("mlp",), ("embed",), scale=out_scale
        ),
    }


def init_cache(cfg: ModelConfig, batch: int, dtype: Any) -> Cache:
    DI, N, DC, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, DC - 1, DI), dtype),
        "ssm": jnp.zeros((batch, DI, N), jnp.float32),
    }


def _ssm_inputs(p: dict, xs: jax.Array, cfg: ModelConfig):
    """xs: (..., DI) -> dt (..., DI), B, C (..., N)."""
    _, N, _, R = _dims(cfg)
    dbc = nn.linear(p["x_proj"], xs)
    dt_r, b, c = jnp.split(dbc, [R, R + N], axis=-1)
    dt_r = nn.rmsnorm(p["dt_norm"], dt_r, cfg.norm_eps)
    b = nn.rmsnorm(p["b_norm"], b, cfg.norm_eps)
    c = nn.rmsnorm(p["c_norm"], c, cfg.norm_eps)
    dt = jax.nn.softplus(
        nn.linear(p["dt_proj"], dt_r).astype(jnp.float32) + p["dt_bias"]
    )
    return dt, b, c


def mamba_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str = "full",
    cache: Optional[Cache] = None,
) -> tuple[jax.Array, Optional[Cache]]:
    """x: (B, S, D) full / (B, 1, D) decode."""
    B, S, _ = x.shape
    DI, N, DC, _ = _dims(cfg)
    xz = nn.linear(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)  # (B, S, DI) each
    A = -jnp.exp(p["A_log"])

    if mode == "full":
        # causal depthwise conv as DC shifted adds (XLA-fusible, no im2col)
        conv = sum(
            p["conv_w"][i][None, None, :]
            * jnp.pad(xs, ((0, 0), (DC - 1 - i, 0), (0, 0)))[:, :S]
            for i in range(DC)
        ) + p["conv_b"]
        xs_c = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
        dt, b, c = _ssm_inputs(p, xs_c, cfg)
        state0 = jnp.zeros((B, DI, N), jnp.float32)
        y, state = ops.mamba_scan(
            xs_c, dt.astype(x.dtype), A, b, c, p["D"], state0,
            chunk=cfg.mamba.chunk, remat_chunks=cfg.chunk_scan_remat,
        )
        new_cache = None
        if cache is not None:
            new_cache = {
                "conv": xs[:, -(DC - 1):].astype(cache["conv"].dtype)
                if S >= DC - 1
                else jnp.concatenate([cache["conv"], xs], axis=1)[:, -(DC - 1):],
                "ssm": state,
            }
    else:
        assert cache is not None and S == 1
        window = jnp.concatenate([cache["conv"], xs], axis=1)  # (B, DC, DI)
        conv = (
            jnp.einsum("bci,ci->bi", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
            + p["conv_b"]
        )
        xs_c = jax.nn.silu(conv).astype(x.dtype)  # (B, DI)
        dt, b, c = _ssm_inputs(p, xs_c, cfg)
        y, state = ops.mamba_step(xs_c, dt.astype(x.dtype), A, b, c, p["D"], cache["ssm"])
        y = y[:, None]
        new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype), "ssm": state}

    y = y.reshape(B, S, DI) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return nn.linear(p["out_proj"], y), new_cache
