"""Adaptive sampling controller: tracing overhead as a closed feedback loop.

The paper answers "is instrumentation cheap enough to leave on?" once, in
Table I, with an offline hyperfine run.  This controller answers it
continuously: it calibrates a no-op baseline with the same
:class:`~repro.core.overhead.TimingStats` protocol, then periodically reads
the collector's record-path self-timing (``timing_snapshot()``: every Nth
``record()`` call is wall-clocked end-to-end, sinks included), converts it
into *percent of wall time spent tracing* and duty-cycles span capture
(``set_sample_rate``) to hold that number under ``budget_pct``.

Control law: proportional back-off when over budget
(``rate *= budget/overhead``, floored at ``min_rate``), multiplicative
recovery toward 1.0 once overhead falls below half the budget.  Every rate
change is itself recorded as a ``controller`` event — the decision trail
rides in the trace, on an essential track the controller never sheds.

``budget_pct <= 0`` means **always-on**: the controller keeps measuring and
exporting the overhead gauge but never reduces the rate — the configuration
the benchmarks use to show the bound is real.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Optional

from typing import TYPE_CHECKING

from repro.metrics.registry import MetricsRegistry

if TYPE_CHECKING:
    from repro.core.overhead import TimingStats

DEFAULT_BUDGET_PCT = 5.0  # the paper's Table I ballpark (+5.1% / +4.8%)


def calibrate_noop(runs: int = 256, warmup: int = 64) -> "TimingStats":
    """Cost of a timed call that records nothing — the overhead zero point.

    ``repro.core.overhead`` imports jax; deferring it here keeps the metrics
    plane importable from jax-free processes (router front door, synthetic
    replicas, the fleet daemon) — only a run that *starts* the adaptive
    controller pays for jax."""
    from repro.core.overhead import hyperfine

    return hyperfine(lambda: None, label="noop", warmup=warmup, runs=runs)


class AdaptiveController:
    """Bounds measured tracing overhead by duty-cycling span capture."""

    def __init__(
        self,
        collector: Any,
        registry: Optional[MetricsRegistry] = None,
        *,
        budget_pct: float = DEFAULT_BUDGET_PCT,
        interval_s: float = 0.25,
        min_rate: float = 0.05,
        grow: float = 1.5,
        smooth: float = 0.5,
        calibration_runs: int = 256,
        noop: Optional[TimingStats] = None,
    ) -> None:
        self.collector = collector
        self.budget_pct = float(budget_pct)
        self.interval_s = interval_s
        self.min_rate = min_rate
        self.grow = grow
        self.smooth = smooth
        self.noop = noop if noop is not None else calibrate_noop(calibration_runs)
        self._noop_s = self.noop.mean_ms * 1e-3
        self.rate = 1.0
        self.overhead_pct = 0.0
        self.adjustments = 0
        self._last_t = time.monotonic()
        self._pending = {"timed": 0, "timed_s": 0.0, "records": 0}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._g_overhead = self._g_rate = self._g_adjust = None
        if registry is not None:
            self._g_overhead = registry.gauge(
                "repro_trace_overhead_pct",
                "self-measured record-path overhead, % of wall time (EWMA)")
            self._g_rate = registry.gauge(
                "repro_trace_sample_rate_target",
                "controller-chosen capture duty cycle")
            self._g_budget = registry.gauge(
                "repro_trace_overhead_budget_pct", "configured overhead budget")
            self._g_budget.set(self.budget_pct)
            self._g_adjust = registry.gauge(
                "repro_trace_controller_adjustments", "rate changes so far")
            self._g_rate.set(self.rate)
        if hasattr(collector, "set_sample_rate"):
            collector.set_sample_rate(self.rate)

    # -- control loop --------------------------------------------------------

    def step(self) -> float:
        """One control tick; returns the current overhead estimate (pct).

        Public and deterministic (no sleeping) so tests and benchmarks can
        drive the loop themselves.  Windows shorter than half the control
        interval bank their timing snapshot and keep the previous estimate:
        a near-empty window that catches one expensive record (the final
        rotation fsync at shutdown, say) would otherwise spike the EWMA
        right before drivers report the end-state gauge.
        """
        now = time.monotonic()
        elapsed = now - self._last_t
        snap = self.collector.timing_snapshot()
        self._pending["timed"] += snap["timed"]
        self._pending["timed_s"] += snap["timed_s"]
        self._pending["records"] += snap["records"]
        if elapsed < 0.5 * self.interval_s:
            return self.overhead_pct
        self._last_t = now
        pend, self._pending = self._pending, {
            "timed": 0, "timed_s": 0.0, "records": 0}
        if elapsed > 0 and pend["timed"] > 0 and pend["records"] > 0:
            per_record_s = pend["timed_s"] / pend["timed"]
            inst = 100.0 * max(0.0, per_record_s - self._noop_s) \
                * pend["records"] / elapsed
            self.overhead_pct = (self.smooth * inst
                                 + (1.0 - self.smooth) * self.overhead_pct)
            if self.budget_pct > 0:
                self._adjust()
        if self._g_overhead is not None:
            self._g_overhead.set(round(self.overhead_pct, 4))
            self._g_rate.set(self.rate)
            self._g_adjust.set(self.adjustments)
        return self.overhead_pct

    def _adjust(self) -> None:
        rate = self.rate
        if self.overhead_pct > self.budget_pct:
            rate = max(self.min_rate,
                       rate * self.budget_pct / self.overhead_pct)
        elif self.overhead_pct < 0.5 * self.budget_pct and rate < 1.0:
            rate = min(1.0, rate * self.grow)
        if abs(rate - self.rate) < 1e-3:
            return
        prev, self.rate = self.rate, rate
        self.adjustments += 1
        if hasattr(self.collector, "set_sample_rate"):
            self.collector.set_sample_rate(rate)
        self.collector.record("mark", "controller", {
            "rate": round(rate, 4),
            "prev": round(prev, 4),
            "overhead_pct": round(self.overhead_pct, 4),
            "budget_pct": self.budget_pct,
        })

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "AdaptiveController":
        if self._thread is not None:
            return self
        self.collector.record("mark", "controller", {
            "rate": self.rate,
            "budget_pct": self.budget_pct,
            "noop_ms": round(self.noop.mean_ms, 6),
            "interval_s": self.interval_s,
        })
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="repro-trace-controller", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.step()
            except Exception:  # a torn snapshot must not kill the loop
                pass

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        self.step()  # final reading so drivers report the end-state gauge

    def snapshot(self) -> dict[str, Any]:
        return {
            "budget_pct": self.budget_pct,
            "overhead_pct": round(self.overhead_pct, 4),
            "sample_rate": round(self.rate, 4),
            "adjustments": self.adjustments,
            "noop_ms": round(self.noop.mean_ms, 6),
        }


class DeviceCaptureBudget:
    """Second budget loop, device-specific: schedules duty-cycled profiler
    capture windows for :class:`repro.trace.liveprof.LiveDeviceProfiler`.

    Host-span shedding (:class:`AdaptiveController`) bounds a *per-event*
    cost by admitting fewer events.  Device capture has a different cost
    shape: each window pays a largely **fixed** price (profiler start/stop
    plus parsing and aligning the dump) regardless of how short the window
    is, so shrinking the window-on fraction alone cannot bound overhead —
    the off time between windows must stretch until the fixed cost
    amortises under budget.  The law here does both:

    * overhead EWMA from each cycle's measured cost over its wall time;
    * over budget → shrink ``on_fraction`` proportionally (less device data
      per cycle, cheaper parse) **and** lengthen the next off time to
      ``cost * 100/budget`` so even the fixed floor fits the budget;
    * under half budget → multiplicative recovery of ``on_fraction``.

    ``budget_pct <= 0`` means **measure-only**: one calibration window runs
    (so the cost gauges mean something), then capture disables and the loop
    keeps exporting the measured numbers.
    """

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        *,
        budget_pct: float = DEFAULT_BUDGET_PCT,
        period_s: float = 2.0,
        min_on_s: float = 0.05,
        min_fraction: float = 0.05,
        grow: float = 1.5,
        smooth: float = 0.5,
    ) -> None:
        self.budget_pct = float(budget_pct)
        self.period_s = float(period_s)
        self.min_on_s = min_on_s
        self.min_fraction = min_fraction
        self.grow = grow
        self.smooth = smooth
        self.on_fraction = 0.5 if self.budget_pct > 0 else min_fraction
        self.overhead_pct = 0.0
        self.cost_ewma_s = 0.0
        self.windows = 0
        self.adjustments = 0
        self.capture_enabled = True
        self._g_overhead = self._g_fraction = self._g_budget = None
        self._g_adjust = self._g_windows = None
        if registry is not None:
            self._g_overhead = registry.gauge(
                "repro_device_capture_overhead_pct",
                "measured device-capture overhead (start/stop+parse+align),"
                " % of wall time (EWMA)")
            self._g_fraction = registry.gauge(
                "repro_device_capture_on_fraction",
                "fraction of each capture period the profiler window is on")
            self._g_budget = registry.gauge(
                "repro_device_capture_budget_pct",
                "configured device-capture overhead budget")
            self._g_budget.set(self.budget_pct)
            self._g_adjust = registry.gauge(
                "repro_device_capture_adjustments",
                "device window-fraction changes so far")
            self._g_windows = registry.gauge(
                "repro_device_capture_windows",
                "device capture windows completed so far")
            self._g_fraction.set(self.on_fraction)

    def plan(self) -> tuple[float, float]:
        """(on_s, off_s) for the next capture cycle.

        ``on_s = 0`` means capture is disabled (measure-only after the
        calibration window, or the budget loop shut it off)."""
        if not self.capture_enabled:
            return 0.0, self.period_s
        on_s = max(self.min_on_s, self.period_s * self.on_fraction)
        off_s = self.period_s - on_s
        if self.budget_pct > 0 and self.cost_ewma_s > 0:
            # the fixed per-window cost must amortise under budget even if
            # narrowing the window saves nothing: stretch the off time
            need = self.cost_ewma_s * 100.0 / self.budget_pct - on_s
            off_s = max(off_s, need)
        return on_s, max(0.0, off_s)

    def observe(self, cost_s: float, elapsed_s: float) -> float:
        """Fold one completed window's measured cost into the loop.

        ``cost_s`` is the wall time the capture machinery itself consumed
        (start+stop+parse+align); ``elapsed_s`` the full cycle it is spread
        over.  Returns the overhead estimate (pct)."""
        self.windows += 1
        self.cost_ewma_s = (cost_s if self.windows == 1 else
                            self.smooth * cost_s
                            + (1.0 - self.smooth) * self.cost_ewma_s)
        if elapsed_s > 0:
            inst = 100.0 * cost_s / elapsed_s
            self.overhead_pct = (inst if self.windows == 1 else
                                 self.smooth * inst
                                 + (1.0 - self.smooth) * self.overhead_pct)
        if self.budget_pct <= 0:
            # calibration complete: measure-only from here on
            self.capture_enabled = False
        else:
            f = self.on_fraction
            if self.overhead_pct > self.budget_pct:
                f = max(self.min_fraction,
                        f * self.budget_pct / self.overhead_pct)
            elif self.overhead_pct < 0.5 * self.budget_pct and f < 1.0:
                f = min(1.0, f * self.grow)
            if abs(f - self.on_fraction) >= 1e-3:
                self.on_fraction = f
                self.adjustments += 1
        self.export()
        return self.overhead_pct

    def export(self) -> None:
        if self._g_overhead is None:
            return
        self._g_overhead.set(round(self.overhead_pct, 4))
        self._g_fraction.set(round(self.on_fraction if self.capture_enabled
                                   else 0.0, 4))
        self._g_adjust.set(self.adjustments)
        self._g_windows.set(self.windows)

    def snapshot(self) -> dict[str, Any]:
        return {
            "budget_pct": self.budget_pct,
            "overhead_pct": round(self.overhead_pct, 4),
            "on_fraction": round(self.on_fraction, 4),
            "cost_ewma_s": round(self.cost_ewma_s, 6),
            "windows": self.windows,
            "adjustments": self.adjustments,
            "capture_enabled": self.capture_enabled,
        }
