"""Metric primitives: counters, gauges and fixed-bucket histograms.

The live complement to ``repro.trace``'s event stream: where a trace answers
"what happened, in order", a metric answers "how much, right now" — cheap
enough to update on every recorded event and small enough to scrape, merge
and snapshot without ever storing samples.

* :class:`Counter` / :class:`Gauge` — a locked float; counters only go up.
* :class:`Histogram` — fixed exponential bucket bounds (milliseconds by
  default).  Observations land in buckets by binary search; quantiles are
  answered by walking the cumulative counts and linearly interpolating
  inside the target bucket, clamped to the observed min/max.  Two
  histograms with identical bounds **merge** by adding bucket counts, which
  is associative and commutative — per-rotation snapshots, per-host shards
  and fleet-level rollups all compose from the same operation.
* :class:`MetricsRegistry` — get-or-create keyed by ``(name, labels)``,
  JSON-able :meth:`~MetricsRegistry.snapshot` and Prometheus text-format
  :meth:`~MetricsRegistry.render` (the ``/metrics`` wire format).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import threading
import time
from typing import Any, Iterable, Mapping, Optional, Sequence

# Exponential-ish bounds in milliseconds: microsecond record-path costs up to
# multi-second checkpoint restores land with < one-bucket quantile error.
DEFAULT_BUCKETS_MS: tuple[float, ...] = (
    0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0, 30000.0,
)


def _label_key(labels: Mapping[str, Any]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labels: Mapping[str, str], extra: str = "") -> str:
    parts = [f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotonic counter. ``inc`` only accepts non-negative deltas."""

    kind = "counter"

    def __init__(self, name: str, labels: Mapping[str, str], help: str = "") -> None:
        self.name = name
        self.labels = dict(labels)
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict[str, Any]:
        return {"name": self.name, "kind": self.kind, "labels": self.labels,
                "value": self.value}

    def render(self) -> list[str]:
        return [f"{self.name}{_fmt_labels(self.labels)} {_fmt_value(self.value)}"]


class Gauge(Counter):
    """A value that can go either way (depth, rate, last-seen overhead %)."""

    kind = "gauge"

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)


class Histogram:
    """Fixed-bound histogram with interpolated quantiles and exact merge.

    ``bounds`` are the upper edges of the finite buckets (strictly
    increasing); one implicit overflow bucket catches everything above the
    last bound.  ``quantile(q)`` is exact to within the width of the bucket
    the true quantile falls in — no samples are retained.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        labels: Mapping[str, str],
        help: str = "",
        bounds: Sequence[float] = DEFAULT_BUCKETS_MS,
    ) -> None:
        bounds = tuple(float(b) for b in bounds)
        if not bounds or any(b <= a for a, b in zip(bounds, bounds[1:])):
            raise ValueError("histogram bounds must be non-empty and strictly increasing")
        self.name = name
        self.labels = dict(labels)
        self.help = help
        self.bounds = bounds
        self._lock = threading.Lock()
        self._counts = [0] * (len(bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> Optional[float]:
        """Interpolated q-quantile (0 <= q <= 1); None when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            count, counts = self._count, list(self._counts)
            lo_obs, hi_obs = self._min, self._max
        if count == 0:
            return None
        target = q * count
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else min(0.0, lo_obs)
                hi = self.bounds[i] if i < len(self.bounds) else hi_obs
                frac = (target - cum) / c
                est = lo + frac * (hi - lo)
                return min(max(est, lo_obs), hi_obs)
            cum += c
        return hi_obs

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into self (identical bounds required). Returns self."""
        if other.bounds != self.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds "
                f"({self.name}: {len(self.bounds)} vs {other.name}: {len(other.bounds)})"
            )
        with other._lock:
            counts = list(other._counts)
            count, total = other._count, other._sum
            lo, hi = other._min, other._max
        with self._lock:
            for i, c in enumerate(counts):
                self._counts[i] += c
            self._count += count
            self._sum += total
            self._min = min(self._min, lo)
            self._max = max(self._max, hi)
        return self

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            snap = {
                "name": self.name,
                "kind": self.kind,
                "labels": self.labels,
                "bounds": list(self.bounds),
                "counts": list(self._counts),
                "count": self._count,
                "sum": self._sum,
                "min": None if self._count == 0 else self._min,
                "max": None if self._count == 0 else self._max,
            }
        for q in (0.5, 0.95, 0.99):
            snap[f"p{int(q * 100)}"] = self.quantile(q)
        return snap

    @classmethod
    def from_snapshot(cls, snap: Mapping[str, Any]) -> "Histogram":
        h = cls(snap["name"], snap.get("labels") or {}, bounds=snap["bounds"])
        h._counts = [int(c) for c in snap["counts"]]
        h._count = int(snap["count"])
        h._sum = float(snap["sum"])
        h._min = math.inf if snap.get("min") is None else float(snap["min"])
        h._max = -math.inf if snap.get("max") is None else float(snap["max"])
        return h

    def render(self) -> list[str]:
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
        lines = []
        cum = 0
        for bound, c in zip(self.bounds + (math.inf,), counts):
            cum += c
            le = _fmt_labels(self.labels, f'le="{_fmt_value(bound)}"')
            lines.append(f"{self.name}_bucket{le} {cum}")
        labels = _fmt_labels(self.labels)
        lines.append(f"{self.name}_sum{labels} {_fmt_value(total)}")
        lines.append(f"{self.name}_count{labels} {count}")
        return lines


class MetricsRegistry:
    """Get-or-create registry of metric series keyed by (name, labels)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[tuple[str, tuple], Any] = {}

    def _get(self, cls, name: str, help: str, labels: Mapping[str, Any], **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, {k: str(v) for k, v in labels.items()}, help, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls) or m.kind != cls.kind:
                raise TypeError(f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "", **labels: Any) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: Any) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        bounds: Optional[Sequence[float]] = None,
        **labels: Any,
    ) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         bounds=tuple(bounds) if bounds else DEFAULT_BUCKETS_MS)

    def metrics(self) -> list[Any]:
        with self._lock:
            return sorted(self._metrics.values(),
                          key=lambda m: (m.name, _label_key(m.labels)))

    def snapshot(self) -> dict[str, Any]:
        """JSON-able view of every series (histograms with p50/p95/p99)."""
        return {"t": time.time(), "metrics": [m.snapshot() for m in self.metrics()]}

    def render(self) -> str:
        """Prometheus text exposition format (one HELP/TYPE block per name)."""
        lines: list[str] = []
        seen: set[str] = set()
        for m in self.metrics():
            if m.name not in seen:
                seen.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m.render())
        return "\n".join(lines) + "\n"
