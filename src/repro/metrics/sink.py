"""Trace→metrics bridge: a collector sink that folds events into a registry.

``MetricsSink`` is a plain ``fn(event)`` callable, installed on a
:class:`~repro.trace.collector.TraceCollector` through the **unsampled** sink
slot (``add_sink(sink, sampled=False)``): it sees every recorded event even
while the adaptive controller is shedding span *capture*, so counters and
latency histograms stay exact under duty-cycling — sampling bounds what is
stored and streamed, never what is counted.

Derived series (all prefixed ``repro_``):

* per-unit counters+histograms from spawn/exit pairs — ``repro_requests_total``
  / ``repro_request_ms`` and the same for step, microbatch, prefill,
  decode_tick, checkpoint, restart, train_step;
* ``repro_dispatch_total{op,backend,source}`` and
  ``repro_dispatch_ms{op,backend}`` from dispatch decisions' measured runs;
* ``repro_device_ms{device,op}`` histograms and
  ``repro_device_slices_total{align}`` from merged device slices, plus
  ``repro_device_capture_windows_total`` from the live profiler's
  window-close marks (see :mod:`repro.trace.liveprof`);
* ``repro_router_requests_total{replica,outcome}`` and
  ``repro_router_route_ms`` from the router front door's terminal ``route``
  outcome events (see :mod:`repro.router.frontdoor`);
* ``repro_tune_points_total{op,pruned}`` from design-space sweep points and
  ``repro_tune_best_speedup{op}`` gauges from per-space winner events (see
  :mod:`repro.tune.explore`);
* ``repro_stragglers_total``, ``repro_trace_controller_events_total``;
* ``repro_trace_events_total{kind}`` for the raw stream.

``MetricsPlane`` bundles a registry + sink + the collector's cheap drop
counters into the one object drivers hand to the HTTP listener and the
streaming session's per-rotation snapshot hook.
"""
from __future__ import annotations

import re
import threading
from typing import Any, Optional

from repro.core.events import Event
from repro.metrics.registry import Counter, Histogram, MetricsRegistry

# Unit-lifecycle names worth a dedicated duration histogram; everything else
# still lands in the kind-labelled event counter.
TIMED_UNITS = frozenset({
    "request", "prefill", "decode_tick", "step", "train_step", "microbatch",
    "checkpoint", "restart", "serve_run", "train_run",
})

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
# "span=<id>" annotation prefixes on device slice names are per-request —
# strip them so the op label stays low-cardinality
_SPAN_TOKEN_RE = re.compile(r"\bspan[=:]\d+\s*")


def _metric_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _device_op(name: str) -> str:
    return _SPAN_TOKEN_RE.sub("", name).strip() or "?"


class MetricsSink:
    """Callable event sink updating a :class:`MetricsRegistry` in-place."""

    def __init__(self, registry: MetricsRegistry, *, max_open_spans: int = 8192) -> None:
        self.registry = registry
        self._max_open = max_open_spans
        self._open: dict[int, float] = {}  # span id -> spawn wall-time
        self._lock = threading.Lock()
        self._kind_counters: dict[str, Counter] = {}
        self._unit_counters: dict[str, Counter] = {}
        self._unit_hists: dict[str, Histogram] = {}
        self._dispatch_counters: dict[tuple, Counter] = {}
        self._dispatch_hists: dict[tuple, Histogram] = {}
        self._device_hists: dict[tuple, Histogram] = {}
        self._device_counters: dict[str, Counter] = {}
        self._router_counters: dict[tuple, Counter] = {}
        self._tune_counters: dict[tuple, Counter] = {}
        self._route_hist: Optional[Histogram] = None
        self._hop_hists: dict[str, Histogram] = {}
        self._hop_mismatch: Optional[Counter] = None
        self._capture_windows = registry.counter(
            "repro_device_capture_windows_total",
            "live device-capture windows merged")
        self._stragglers = registry.counter(
            "repro_stragglers_total", "straggler detections")
        self._controller_events = registry.counter(
            "repro_trace_controller_events_total",
            "adaptive controller decisions recorded into the trace")

    def _kind_counter(self, kind: str) -> Counter:
        c = self._kind_counters.get(kind)
        if c is None:
            c = self.registry.counter("repro_trace_events_total",
                                      "events seen by the metrics sink", kind=kind)
            self._kind_counters[kind] = c
        return c

    def _unit(self, name: str) -> tuple[Counter, Optional[Histogram]]:
        c = self._unit_counters.get(name)
        if c is None:
            m = _metric_name(name)
            c = self.registry.counter(f"repro_{m}s_total", f"completed {name} units")
            self._unit_counters[name] = c
            if name in TIMED_UNITS:
                self._unit_hists[name] = self.registry.histogram(
                    f"repro_{m}_ms", f"{name} wall time (ms)")
        return c, self._unit_hists.get(name)

    def __call__(self, e: Event) -> None:
        self._kind_counter(e.kind).inc()
        if e.kind == "spawn":
            if e.span:
                with self._lock:
                    if len(self._open) >= self._max_open:
                        self._open.pop(next(iter(self._open)))
                    self._open[e.span] = e.t
        elif e.kind == "exit":
            counter, hist = self._unit(e.name)
            counter.inc()
            if e.span and hist is not None:
                with self._lock:
                    t0 = self._open.pop(e.span, None)
                if t0 is not None:
                    hist.observe((e.t - t0) * 1e3)
        elif e.kind == "dispatch":
            p = e.payload if isinstance(e.payload, dict) else {}
            key = (e.name, str(p.get("backend")), str(p.get("source")))
            c = self._dispatch_counters.get(key)
            if c is None:
                c = self.registry.counter(
                    "repro_dispatch_total", "dispatch decisions",
                    op=key[0], backend=key[1], source=key[2])
                self._dispatch_counters[key] = c
            c.inc()
            measured = p.get("measured_s")
            if isinstance(measured, (int, float)):
                hkey = (e.name, key[1])
                h = self._dispatch_hists.get(hkey)
                if h is None:
                    h = self.registry.histogram(
                        "repro_dispatch_ms", "measured dispatch execution (ms)",
                        op=hkey[0], backend=hkey[1])
                    self._dispatch_hists[hkey] = h
                h.observe(float(measured) * 1e3)
        elif e.kind == "device":
            p = e.payload if isinstance(e.payload, dict) else {}
            align = str(p.get("align") or "none")
            c = self._device_counters.get(align)
            if c is None:
                c = self.registry.counter(
                    "repro_device_slices_total",
                    "merged device slices by alignment mode", align=align)
                self._device_counters[align] = c
            c.inc()
            dur = p.get("dur_s")
            if isinstance(dur, (int, float)):
                hkey = (str(p.get("device") or "?"), _device_op(e.name))
                h = self._device_hists.get(hkey)
                if h is None:
                    h = self.registry.histogram(
                        "repro_device_ms", "device slice wall time (ms)",
                        device=hkey[0], op=hkey[1])
                    self._device_hists[hkey] = h
                h.observe(float(dur) * 1e3)
        elif e.kind == "route":
            # only the terminal per-request outcome counts a request; the
            # per-attempt "route" decision events would overcount retries
            if e.name != "outcome":
                return
            p = e.payload if isinstance(e.payload, dict) else {}
            key = (str(p.get("replica")), str(p.get("outcome")))
            c = self._router_counters.get(key)
            if c is None:
                c = self.registry.counter(
                    "repro_router_requests_total",
                    "routed requests by terminal outcome",
                    replica=key[0], outcome=key[1])
                self._router_counters[key] = c
            c.inc()
            route_ms = p.get("route_ms")
            if isinstance(route_ms, (int, float)):
                if self._route_hist is None:
                    self._route_hist = self.registry.histogram(
                        "repro_router_route_ms",
                        "routing-decision overhead per request (ms)")
                self._route_hist.observe(float(route_ms))
            hops = p.get("hops")
            if isinstance(hops, dict):
                # per-hop latency decomposition (frontdoor_queue | network |
                # replica_queue | service); the four telescope to the
                # end-to-end latency, so a sum drifting past 5% of latency_ms
                # means a hop was measured wrong — count it, don't hide it
                total = 0.0
                for hop in ("frontdoor_queue", "network", "replica_queue",
                            "service"):
                    v = hops.get(hop)
                    if not isinstance(v, (int, float)):
                        continue
                    total += float(v)
                    h = self._hop_hists.get(hop)
                    if h is None:
                        h = self.registry.histogram(
                            "repro_router_hop_ms",
                            "per-hop request latency decomposition (ms)",
                            hop=hop)
                        self._hop_hists[hop] = h
                    h.observe(max(0.0, float(v)))
                lat = p.get("latency_ms")
                if (isinstance(lat, (int, float)) and lat > 0
                        and abs(total - float(lat)) > 0.05 * float(lat)):
                    if self._hop_mismatch is None:
                        self._hop_mismatch = self.registry.counter(
                            "repro_router_hop_sum_mismatch_total",
                            "requests whose hop decomposition failed to sum "
                            "to end-to-end latency (within 5%)")
                    self._hop_mismatch.inc()
        elif e.kind == "tune":
            p = e.payload if isinstance(e.payload, dict) else {}
            if p.get("winner"):
                # best-vs-default per op; >= 1.0 by construction (the default
                # point competes in the same argmin)
                speedup = p.get("speedup")
                if isinstance(speedup, (int, float)):
                    self.registry.gauge(
                        "repro_tune_best_speedup",
                        "tuned best-config speedup over the hand-picked default",
                        op=str(p.get("op"))).set(float(speedup))
                return
            key = (str(p.get("op")), "true" if p.get("pruned") else "false")
            c = self._tune_counters.get(key)
            if c is None:
                c = self.registry.counter(
                    "repro_tune_points_total",
                    "design-space points seen by the tuner",
                    op=key[0], pruned=key[1])
                self._tune_counters[key] = c
            c.inc()
        elif e.name == "device_window":
            p = e.payload if isinstance(e.payload, dict) else {}
            if "events" in p:  # window-close marks only (not start/warning)
                self._capture_windows.inc()
        elif e.kind == "straggler":
            self._stragglers.inc()
        elif e.name == "controller":
            self._controller_events.inc()


class MetricsPlane:
    """Registry + sink + collector drop/sampling gauges, as one attachable unit."""

    def __init__(self, collector: Any = None,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry or MetricsRegistry()
        self.sink = MetricsSink(self.registry)
        self.collector: Any = None
        if collector is not None:
            self.attach(collector)

    def attach(self, collector: Any) -> "MetricsPlane":
        """Fan the sink in as an *unsampled* sink: metrics see shed events."""
        add_sink = getattr(collector, "add_sink", None)
        if add_sink is None:
            raise TypeError(
                f"{type(collector).__name__} has no add_sink fan-out; "
                "MetricsPlane requires a TraceCollector")
        add_sink(self.sink, sampled=False)
        self.collector = collector
        return self

    def refresh(self) -> None:
        """Pull the collector's cheap drop/sampling counters into gauges."""
        c = self.collector
        drop_counters = getattr(c, "drop_counters", None)
        if drop_counters is None:
            return
        d = drop_counters()
        g = self.registry.gauge
        g("repro_trace_dropped_total", "events evicted from bounded rings").set(
            d.get("dropped", 0))
        g("repro_trace_sampled_out_total",
          "events shed by the adaptive controller").set(d.get("sampled_out", 0))
        for track, n in (d.get("by_track") or {}).items():
            if n:
                g("repro_trace_dropped_by_track", "ring evictions per track",
                  track=track or "main").set(n)
        g("repro_trace_sample_rate", "current capture duty cycle [0,1]").set(
            getattr(c, "sample_rate", 1.0))

    def snapshot(self) -> dict[str, Any]:
        self.refresh()
        return self.registry.snapshot()

    def render(self) -> str:
        self.refresh()
        return self.registry.render()

    def summary(self) -> dict[str, float]:
        """Flat {series: value} of all counters/gauges (histograms as _count)."""
        self.refresh()
        out: dict[str, float] = {}
        for m in self.registry.metrics():
            labels = "".join(
                f",{k}={v}" for k, v in sorted(m.labels.items()))
            if m.kind == "histogram":
                out[f"{m.name}_count{{{labels.lstrip(',')}}}" if labels
                    else f"{m.name}_count"] = m.count
            else:
                out[f"{m.name}{{{labels.lstrip(',')}}}" if labels
                    else m.name] = m.value
        return out
