"""Stdlib HTTP listener exposing a :class:`MetricsPlane` for scraping.

Prometheus text at ``/metrics``, the JSON snapshot at ``/metrics.json`` and
a trivial ``/healthz`` — the same surface the fleet daemon serves, here as a
sidecar thread inside ``launch.serve`` / ``launch.train`` so a single
training or serving process is scrapeable with nothing but ``--metrics-port``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any
from urllib.parse import urlparse

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    plane: Any = None

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def stop(self) -> None:
        self.shutdown()
        self.server_close()


class _Handler(BaseHTTPRequestHandler):
    def log_message(self, fmt: str, *args: Any) -> None:  # quiet by default
        pass

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
        path = urlparse(self.path).path
        plane = self.server.plane
        try:
            if path == "/metrics":
                self._send(200, plane.render().encode(), PROM_CONTENT_TYPE)
            elif path == "/metrics.json":
                body = json.dumps(plane.snapshot(), default=repr).encode()
                self._send(200, body, "application/json")
            elif path == "/healthz":
                self._send(200, b'{"ok": true}', "application/json")
            else:
                self._send(404, b'{"error": "not found"}', "application/json")
        except Exception as exc:
            self._send(500, json.dumps({"error": repr(exc)}).encode(),
                       "application/json")


def serve_metrics(plane: Any, port: int = 0,
                  host: str = "127.0.0.1") -> MetricsHTTPServer:
    """Start a daemon-thread scrape endpoint; ``port=0`` picks a free port."""
    server = MetricsHTTPServer((host, port), _Handler)
    server.plane = plane
    threading.Thread(target=server.serve_forever,
                     name="repro-metrics-http", daemon=True).start()
    return server
