"""repro.metrics — live metrics plane derived from the trace stream.

Counters/gauges/fixed-bucket histograms (:mod:`.registry`), a trace-event
sink that keeps them current (:mod:`.sink`), an adaptive sampling controller
that bounds self-measured tracing overhead (:mod:`.controller`) and a stdlib
HTTP scrape endpoint (:mod:`.http`).
"""
from repro.metrics.controller import (
    DEFAULT_BUDGET_PCT,
    AdaptiveController,
    DeviceCaptureBudget,
    calibrate_noop,
)
from repro.metrics.http import MetricsHTTPServer, serve_metrics
from repro.metrics.registry import (
    DEFAULT_BUCKETS_MS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.metrics.sink import TIMED_UNITS, MetricsPlane, MetricsSink

__all__ = [
    "DEFAULT_BUCKETS_MS",
    "DEFAULT_BUDGET_PCT",
    "TIMED_UNITS",
    "AdaptiveController",
    "DeviceCaptureBudget",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsHTTPServer",
    "MetricsPlane",
    "MetricsRegistry",
    "MetricsSink",
    "calibrate_noop",
    "serve_metrics",
]
