"""Runtime substrate: training supervisor with fault tolerance."""
