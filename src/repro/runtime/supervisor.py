"""Training supervisor: checkpoint/restart, failure injection, stragglers,
elastic re-mesh.

The control loop a 1000+-node deployment needs, exercised here with simulated
faults (the CPU container has one real device; the *mechanisms* are identical):

* **Failure detection + restart** — any step raising :class:`NodeFailure`
  (injected by :class:`FailureInjector`, or real XLA errors) rolls back to the
  last checkpoint and replays.  Because the data pipeline is stateless-indexed
  by the step counter, replay is bit-deterministic.
* **Straggler mitigation** — per-step deadline = ``k×`` the rolling median
  step time; breaches are recorded as ``straggler`` lifecycle events and
  counted (on a real cluster the action is re-scheduling the slow host; the
  detection side is what lives in software).
* **Elastic re-mesh** — ``resize(new_mesh)`` re-shards the live train state
  onto a different mesh (checkpoints are unsharded, so this is a device_put,
  not a format migration).
* **Lifecycle tracing** — step / checkpoint / restart spawn-exit events flow
  into the paper's EventLog (Adaptyst's thread/process-tracing analogue).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Iterator, Mapping, Optional

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.core.events import GLOBAL_LOG, EventLog
from repro.dispatch.dispatcher import Dispatcher
from repro.dispatch.profiles import signature

PyTree = Any


class NodeFailure(RuntimeError):
    """Simulated (or surfaced) loss of a worker during a step."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: fail just before the listed steps."""

    fail_at_steps: tuple[int, ...] = ()
    _already: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._already:
            self._already.add(step)
            raise NodeFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    straggler_factor: float = 3.0  # deadline = factor × rolling median
    straggler_window: int = 20
    max_restarts: int = 10


class Supervisor:
    """Runs ``train_step`` under fault tolerance.

    ``train_step(state, batch) -> (state, metrics)`` must be pure (jitted);
    ``batch_fn(step) -> batch`` must be stateless-indexed (resumable).
    """

    def __init__(
        self,
        cfg: SupervisorConfig,
        train_step: Callable,
        batch_fn: Callable[[int], Any],
        init_state: PyTree,
        *,
        state_shardings: Optional[PyTree] = None,
        log: Optional[EventLog] = None,
        failures: Optional[FailureInjector] = None,
        dispatcher: Optional[Dispatcher] = None,
        step_variants: Optional[Mapping[str, Callable]] = None,
        stream: Optional[Any] = None,
    ) -> None:
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        # profile-guided placement: when both are given, each step routes to
        # the argmin-cost compiled variant (see repro.dispatch)
        self.dispatcher = dispatcher
        self.step_variants = dict(step_variants) if step_variants else None
        # per-backend tuned-config tags, resolved lazily at the first
        # dispatched step (tune winners are installed before run())
        self._configs: Optional[dict] = None
        # durable trace sink (repro.trace.StreamingSession): rotated at every
        # checkpoint so the on-disk trace is never staler than the on-disk
        # model state — a crash recovers both to the same point
        self.stream = stream
        self.state = init_state
        self.state_shardings = state_shardings
        self.log = GLOBAL_LOG if log is None else log
        self.failures = failures or FailureInjector()
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir)
        self.step = 0
        self.restarts = 0
        self.stragglers = 0
        self._durations: list[float] = []

    # -- fault handling ------------------------------------------------------

    def _restore_latest(self) -> None:
        last = latest_step(self.cfg.ckpt_dir)
        with self.log.lifecycle("restart", {"from_step": last}):
            if last is None:
                self.step = 0  # restart from scratch
                return
            self.state = restore(
                self.cfg.ckpt_dir, last, self.state, self.state_shardings
            )
            self.step = last

    def resize(self, new_mesh, reshard_fn: Callable[[PyTree, Any], PyTree]) -> None:
        """Elastic re-mesh: move the live state onto ``new_mesh``."""
        with self.log.lifecycle("elastic_resize", {"mesh": str(new_mesh.shape)}):
            self.state, self.state_shardings = reshard_fn(self.state, new_mesh)

    # -- main loop -----------------------------------------------------------

    def _deadline(self) -> Optional[float]:
        if len(self._durations) < 5:
            return None
        window = self._durations[-self.cfg.straggler_window:]
        return self.cfg.straggler_factor * statistics.median(window)

    def run(self) -> dict[str, Any]:
        metrics_hist = []
        if latest_step(self.cfg.ckpt_dir) is None:
            # step-0 checkpoint BEFORE the first (donating) step: restart-from-
            # scratch must never reference donated buffers.
            with self.log.lifecycle("checkpoint", 0):
                self.ckpt.save(0, self.state)
        while self.step < self.cfg.max_steps:
            try:
                with self.log.lifecycle("step", self.step) as step_span:
                    self.failures.maybe_fail(self.step)
                    t0 = time.monotonic()
                    batch = self.batch_fn(self.step)
                    if self.dispatcher is not None and self.step_variants:
                        # inside the step's span scope: the dispatch event
                        # lands in the span tree as the step's child
                        if self._configs is None:
                            self._configs = self.dispatcher.active_configs()
                        self.state, metrics = self.dispatcher.dispatch(
                            "train_step", self.step_variants, self.state, batch,
                            sig=signature(batch),  # state pytree is fixed-shape
                            configs=self._configs,
                        )
                    else:
                        self.state, metrics = self.train_step(self.state, batch)
                    jax.block_until_ready(metrics)
                    dt = time.monotonic() - t0
                deadline = self._deadline()
                if deadline is not None and dt > deadline:
                    self.stragglers += 1
                    # recorded after the step closed, but caused by it: the
                    # explicit parent keeps the tree causal, not lexical
                    self.log.record("straggler", "step", {"step": self.step, "s": dt},
                                    parent=step_span)
                self._durations.append(dt)
                metrics_hist.append(jax.device_get(metrics))
                self.step += 1
                if self.step % self.cfg.ckpt_every == 0:
                    with self.log.lifecycle("checkpoint", self.step, parent=step_span):
                        self.ckpt.save(self.step, self.state)
                    if self.stream is not None:
                        self.stream.rotate()
            except NodeFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self._restore_latest()
        self.ckpt.wait()
        with self.log.lifecycle("checkpoint", self.step):
            self.ckpt.save(self.step, self.state)
            self.ckpt.wait()
        if self.stream is not None:
            self.stream.rotate()
        return {
            "steps": self.step,
            "restarts": self.restarts,
            "stragglers": self.stragglers,
            "metrics": metrics_hist,
            # collector health: a bounded log on a long run drops oldest
            # events; surfacing the counter here keeps the loss visible in
            # every driver's JSON output (perf "lost samples" discipline).
            "trace": {
                "events": len(self.log),
                "dropped": self.log.dropped,
                "capacity": self.log.maxlen,
                # cheap per-track/shed counters when the log is a collector
                # (no span resolution: run() may be mid-restart churn)
                **(self.log.drop_counters()
                   if hasattr(self.log, "drop_counters") else {}),
            },
        }
