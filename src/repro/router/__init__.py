"""repro.router — a replica fleet behind a profile-guided front door.

The system-level tier over :mod:`repro.serving`: the engine becomes a
replica (:mod:`.replica` — an HTTP front over the continuous-batching
engine, or the deterministic synthetic engine for accelerator-free CI), a
supervisor keeps N of them alive (:mod:`.manager` — ready-file handshake,
healthz liveness, restart with exponential backoff), and a cost model picks
where each request class runs best (:mod:`.cost` — fleet (git SHA, chip)
profile seeds, then live per-replica EWMA latency, argmin with least-loaded
tie-breaking and bounded-queue admission control).  :mod:`.frontdoor` is the
single listener tying them together with drain-then-retry exactly-once
forwarding; :mod:`.loadgen` drives and verifies it.
"""
from repro.router.cost import (
    DEFAULT_COST_S,
    CostRouter,
    NoReplicaAvailable,
    RouteDecision,
    RouterBusy,
    SeedCosts,
    class_of,
    seed_costs_from_store,
)
from repro.router.frontdoor import FrontDoorServer, forward_generate, make_frontdoor
from repro.router.manager import ReplicaHandle, ReplicaManager
from repro.router.replica import (
    ReplicaServer,
    SyntheticEngine,
    expected_synthetic_tokens,
)

__all__ = [
    "DEFAULT_COST_S",
    "CostRouter",
    "FrontDoorServer",
    "NoReplicaAvailable",
    "ReplicaHandle",
    "ReplicaManager",
    "ReplicaServer",
    "RouteDecision",
    "RouterBusy",
    "SeedCosts",
    "SyntheticEngine",
    "class_of",
    "expected_synthetic_tokens",
    "forward_generate",
    "make_frontdoor",
    "seed_costs_from_store",
]
