"""ReplicaManager: spawn, watch and resurrect the replica fleet.

Each replica is a ``python -m repro.router.replica`` subprocess launched with
``--port 0`` and a per-replica ready file (the shared handshake from
:mod:`repro.utils.ready`), its stdout/stderr captured to per-replica log
files under the workdir.  A supervisor thread then runs a small state
machine per replica:

``up`` → (process exit or repeated ``/healthz`` failures) → ``backoff`` →
(exponential delay, capped) → ``starting`` → (ready file reappears, on a
**new** port) → ``up``.

Every transition is recorded as a ``mark``/``replica`` trace event on the
router track and mirrored into ``repro_router_replica_up`` /
``repro_router_replica_restarts_total``; the ``on_up``/``on_down`` callbacks
are how the :class:`~repro.router.cost.CostRouter` learns a replica's
current URL and routability.  Liveness needs both probes: ``proc.poll()``
catches a SIGKILLed child instantly, the ``/healthz`` GET catches a process
that is alive but wedged (the supervisor kills it and restarts).
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
from typing import Any, Callable, Optional

from repro.core.events import EventLog
from repro.utils.ready import read_ready_info, wait_for_ready_file

HEALTH_FAILS_TO_RESTART = 3  # consecutive /healthz failures ⇒ wedged


@dataclasses.dataclass
class ReplicaHandle:
    name: str
    ready_file: str
    log_path: str
    proc: Optional[subprocess.Popen] = None
    url: str = ""
    info: dict[str, Any] = dataclasses.field(default_factory=dict)
    state: str = "starting"  # starting | up | backoff
    restarts: int = 0
    backoff_s: float = 0.0
    resume_at: float = 0.0
    start_deadline: float = 0.0
    health_fails: int = 0

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else None


class ReplicaManager:
    """Spawn N replicas, keep them alive, tell the router who is routable."""

    def __init__(
        self,
        count: int,
        replica_argv: list[str],
        workdir: str,
        *,
        log: Optional[EventLog] = None,
        registry: Optional[Any] = None,
        on_up: Optional[Callable[[str, str, dict[str, Any]], None]] = None,
        on_down: Optional[Callable[[str, str], None]] = None,
        poll_s: float = 0.5,
        backoff_s: float = 0.5,
        max_backoff_s: float = 8.0,
        startup_timeout_s: float = 120.0,
        python: str = sys.executable,
    ) -> None:
        if count < 1:
            raise ValueError(f"count must be >= 1 (got {count})")
        self.count = count
        self.replica_argv = list(replica_argv)
        self.workdir = workdir
        self.log = log
        self.registry = registry
        self.on_up = on_up
        self.on_down = on_down
        self.poll_s = poll_s
        self.backoff0_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.startup_timeout_s = startup_timeout_s
        self.python = python
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.replicas: dict[str, ReplicaHandle] = {}

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "ReplicaManager":
        """Spawn all replicas, block until every one is ready, then supervise."""
        os.makedirs(self.workdir, exist_ok=True)
        for i in range(self.count):
            name = f"r{i}"
            h = ReplicaHandle(
                name=name,
                ready_file=os.path.join(self.workdir, f"{name}.ready"),
                log_path=os.path.join(self.workdir, f"{name}.log"),
            )
            self.replicas[name] = h
            self._spawn(h)
        for h in self.replicas.values():
            wait_for_ready_file(h.ready_file, self.startup_timeout_s,
                                proc=h.proc)
            self._became_ready(h)
        self._thread = threading.Thread(target=self._supervise,
                                        name="replica-supervisor", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        for h in self.replicas.values():
            if h.proc is not None and h.proc.poll() is None:
                h.proc.terminate()
        deadline = time.monotonic() + 5.0
        for h in self.replicas.values():
            if h.proc is None:
                continue
            try:
                h.proc.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                h.proc.kill()
                h.proc.wait(timeout=5.0)

    # -- internals ------------------------------------------------------------

    def _spawn(self, h: ReplicaHandle) -> None:
        if os.path.exists(h.ready_file):
            os.unlink(h.ready_file)  # stale URL must not look like readiness
        cmd = [self.python, "-m", "repro.router.replica",
               "--name", h.name, "--port", "0",
               "--ready-file", h.ready_file] + self.replica_argv
        logf = open(h.log_path, "ab")
        try:
            # cwd is inherited: a relative PYTHONPATH=src (the repo's own
            # convention) must keep resolving inside the child
            h.proc = subprocess.Popen(cmd, stdout=logf, stderr=logf)
        finally:
            logf.close()  # the child holds its own fd
        h.state = "starting"
        h.start_deadline = time.monotonic() + self.startup_timeout_s
        h.health_fails = 0
        self._event(h, "starting", pid=h.pid)

    def _became_ready(self, h: ReplicaHandle) -> None:
        h.info = read_ready_info(h.ready_file)
        h.url = h.info["url"]
        h.state = "up"
        h.backoff_s = 0.0
        h.health_fails = 0
        self._event(h, "up", pid=h.pid, url=h.url)
        self._gauge(h, 1.0)
        if self.on_up is not None:
            self.on_up(h.name, h.url, h.info)

    def _went_down(self, h: ReplicaHandle, reason: str) -> None:
        h.restarts += 1
        h.backoff_s = (self.backoff0_s if h.backoff_s == 0.0
                       else min(h.backoff_s * 2, self.max_backoff_s))
        h.state = "backoff"
        h.resume_at = time.monotonic() + h.backoff_s
        self._event(h, "down", reason=reason, restarts=h.restarts,
                    backoff_s=h.backoff_s)
        self._gauge(h, 0.0)
        if self.registry is not None:
            self.registry.counter(
                "repro_router_replica_restarts_total",
                "replica restarts by the supervisor",
                replica=h.name).inc()
        if self.on_down is not None:
            self.on_down(h.name, reason)

    def _healthz_ok(self, h: ReplicaHandle) -> bool:
        try:
            with urllib.request.urlopen(f"{h.url}/healthz", timeout=2.0) as r:
                return bool(json.loads(r.read()).get("ok"))
        except (urllib.error.URLError, TimeoutError, ConnectionError,
                OSError, ValueError):
            return False

    def _supervise(self) -> None:
        while not self._stop.wait(self.poll_s):
            for h in self.replicas.values():
                try:
                    self._tick(h)
                except Exception as exc:  # supervisor must never die
                    self._event(h, "supervisor-error", error=repr(exc))

    def _tick(self, h: ReplicaHandle) -> None:
        now = time.monotonic()
        if h.state == "up":
            rc = h.proc.poll() if h.proc is not None else -1
            if rc is not None:
                self._went_down(h, f"exited rc={rc}")
                return
            if self._healthz_ok(h):
                h.health_fails = 0
            else:
                h.health_fails += 1
                if h.health_fails >= HEALTH_FAILS_TO_RESTART:
                    # alive but unresponsive: put it out of its misery
                    h.proc.kill()
                    h.proc.wait(timeout=10.0)
                    self._went_down(
                        h, f"unresponsive ({h.health_fails} healthz failures)")
        elif h.state == "backoff":
            if now >= h.resume_at:
                self._spawn(h)
        elif h.state == "starting":
            if h.proc is not None and h.proc.poll() is not None:
                self._went_down(h, f"died during startup rc={h.proc.returncode}")
                return
            if os.path.exists(h.ready_file):
                try:
                    self._became_ready(h)
                except (ValueError, OSError):
                    pass  # torn/half-written: next tick re-reads
            elif now >= h.start_deadline:
                if h.proc is not None:
                    h.proc.kill()
                    h.proc.wait(timeout=10.0)
                self._went_down(h, "startup timeout")

    # -- observability --------------------------------------------------------

    def _event(self, h: ReplicaHandle, state: str, **extra: Any) -> None:
        if self.log is not None:
            self.log.record("mark", "replica",
                            {"replica": h.name, "state": state, **extra})

    def _gauge(self, h: ReplicaHandle, v: float) -> None:
        if self.registry is not None:
            self.registry.gauge("repro_router_replica_up",
                                "replica routable (1) or down (0)",
                                replica=h.name).set(v)

    def status(self) -> dict[str, Any]:
        with self._lock:
            return {
                h.name: {
                    "state": h.state,
                    "pid": h.pid,
                    "url": h.url,
                    "restarts": h.restarts,
                    "chip": h.info.get("chip"),
                    "git_sha": h.info.get("git_sha"),
                }
                for h in self.replicas.values()
            }
