"""One serve replica: an HTTP front over a continuous-batching engine.

``python -m repro.router.replica`` turns the batch-driven
:class:`repro.serving.engine.Engine` into a long-lived process the router can
spawn, poll and route to:

* ``POST /v1/generate`` ``{"prompt": [...], "max_new": N}`` — submit one
  request and block until its tokens are ready (the engine keeps batching
  underneath: concurrent requests share decode ticks);
* ``GET /healthz`` — liveness + identity (pid, chip, git SHA) + occupancy;
* ``GET /metrics`` / ``/metrics.json`` — the replica's own metrics plane.

Startup follows the shared ready-file handshake (:mod:`repro.utils.ready`):
bind ``--port 0``, then atomically write a JSON ready file carrying the URL
plus the identity the router needs for fleet profile seeding.

``--synthetic`` swaps in :class:`SyntheticEngine` — same scheduling shape
(bounded slots, per-tick token production) with **deterministic** outputs
(:func:`expected_synthetic_tokens`) and a configurable per-tick sleep, and no
jax import anywhere.  That is what CI's router-smoke runs: a client can
recompute every expected token, so a request re-executed after a replica
SIGKILL is provably identical — exactly-once is verifiable, not assumed.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Optional
from urllib.parse import urlparse

from repro.core.events import (EventLog, SpanContext, TRACEPARENT_HEADER,
                               current_span, next_span_id, span_scope)
from repro.metrics import MetricsPlane
from repro.trace import TraceCollector
from repro.utils.ready import write_ready_file

SYNTHETIC_VOCAB = 50257


def expected_synthetic_tokens(prompt: list[int], max_new: int) -> list[int]:
    """The tokens a synthetic replica will emit for ``prompt`` — any replica,
    any restart.  Clients recompute this to verify exactly-once retries."""
    seed = sum(prompt) % 65521
    return [(seed * 31 + i * 7 + 11) % SYNTHETIC_VOCAB for i in range(max_new)]


@dataclasses.dataclass
class _SynRequest:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    span: int = 0
    parent: int = 0
    t_active: float = 0.0  # monotonic instant the request won a decode slot


class SyntheticEngine:
    """Engine-shaped synthetic server core: slots, ticks, deterministic tokens.

    Mirrors the real engine's client surface (``submit`` / ``step`` /
    ``pending``) and its request lifecycle events, but each decode tick
    sleeps ``ms_per_token`` instead of running a model — so scheduling,
    batching pressure and tail behaviour are exercised with zero accelerator
    (and zero jax import).
    """

    def __init__(self, *, max_batch: int = 4, ms_per_token: float = 2.0,
                 log: Optional[EventLog] = None,
                 metrics: Optional[Any] = None) -> None:
        self.max_batch = max_batch
        self.ms_per_token = ms_per_token
        self.log = log if log is not None else EventLog()
        self._lock = threading.Lock()
        self.queue: list[_SynRequest] = []
        self.active: list[Optional[_SynRequest]] = [None] * max_batch
        self._rid = 0
        self._g_queue = self._g_slots = None
        if metrics is not None:
            self._g_queue = metrics.gauge(
                "repro_serve_queue_depth", "requests waiting for a decode slot")
            self._g_slots = metrics.gauge(
                "repro_serve_active_slots", "occupied decode slots")

    def submit(self, prompt: list[int], max_new: int = 32) -> int:
        with self._lock:
            rid = self._rid
            self._rid += 1
            req = _SynRequest(rid, list(prompt), max_new,
                              span=next_span_id(), parent=current_span())
            self.queue.append(req)
            depth = len(self.queue)
        self.log.record("spawn", "request", req.rid, span=req.span,
                        parent=req.parent)
        if self._g_queue is not None:
            self._g_queue.set(depth)
        return rid

    def pending(self) -> int:
        with self._lock:
            return len(self.queue) + sum(r is not None for r in self.active)

    def step(self) -> list[_SynRequest]:
        with self._lock:
            for slot in range(self.max_batch):
                if self.active[slot] is None and self.queue:
                    req = self.queue.pop(0)
                    req.t_active = time.monotonic()
                    self.active[slot] = req
            live = [r for r in self.active if r is not None]
            if self._g_queue is not None:
                self._g_queue.set(len(self.queue))
                self._g_slots.set(len(live))
        if not live:
            return []
        if self.ms_per_token > 0:
            time.sleep(self.ms_per_token / 1e3)  # one shared "decode tick"
        finished: list[_SynRequest] = []
        with self._lock:
            for slot, r in enumerate(self.active):
                if r is None:
                    continue
                expected = expected_synthetic_tokens(r.prompt, r.max_new)
                r.out.append(expected[len(r.out)])
                if len(r.out) >= r.max_new:
                    self.active[slot] = None
                    finished.append(r)
            if finished and self._g_slots is not None:
                self._g_slots.set(sum(r is not None for r in self.active))
        for r in finished:
            self.log.record("exit", "request", r.rid, span=r.span,
                            parent=r.parent)
        return finished


class ReplicaServer:
    """HTTP serving wrapper around an engine (real or synthetic).

    One daemon engine-loop thread owns ``step()``; HTTP handler threads
    ``submit()`` (both engines are submit-thread-safe) and block on a shared
    condition until the loop publishes their rid's tokens.  Each handler
    opens an ``rpc`` span under the run root; the engine's request spawn/exit
    bracket nests inside it, so the replica's trace reads rpc → request →
    prefill → dispatch.  When the front door sent an ``X-Repro-Traceparent``
    header, the rpc span carries that :class:`SpanContext` as its *remote*
    parent — ``repro.trace stitch`` re-links it under the frontdoor's route
    span once both sessions are merged.
    """

    def __init__(self, engine: Any, *, name: str, log: EventLog,
                 plane: Optional[MetricsPlane] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 info: Optional[dict[str, Any]] = None) -> None:
        self.engine = engine
        self.name = name
        self.origin = f"{name}:{os.getpid()}"
        self.log = log
        self.plane = plane
        self.info = dict(info or {})
        self.completed = 0
        self._results: dict[int, Any] = {}  # rid -> finished request object
        self._cond = threading.Condition()
        self._stop = threading.Event()
        self.run_span = 0
        self._httpd = _ReplicaHTTPServer((host, port), _ReplicaHandler)
        self._httpd.replica = self
        self._loop_thread: Optional[threading.Thread] = None
        self._http_thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "ReplicaServer":
        # long-lived run root: every request span nests under it, mirroring
        # the driver's `with lifecycle("serve_run")` envelope
        self.run_span = next_span_id()
        self.log.record("spawn", "serve_run",
                        {"replica": self.name, **self.info}, span=self.run_span)
        self._loop_thread = threading.Thread(
            target=self._engine_loop, name=f"{self.name}-engine", daemon=True)
        self._loop_thread.start()
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, name=f"{self.name}-http",
            daemon=True)
        self._http_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._loop_thread is not None:
            self._loop_thread.join(timeout=5.0)
        self.log.record("exit", "serve_run",
                        {"replica": self.name, "completed": self.completed},
                        span=self.run_span)

    def _engine_loop(self) -> None:
        while not self._stop.is_set():
            if self.engine.pending() == 0:
                with self._cond:
                    self._cond.wait(timeout=0.02)
                continue
            finished = self.engine.step()
            if finished:
                now = time.monotonic()
                with self._cond:
                    for r in finished:
                        r.t_done = now  # plain dataclasses: setattr is fine
                        self._results[r.rid] = r
                        self.completed += 1
                    self._cond.notify_all()

    def submit_and_wait(self, prompt: list[int], max_new: int,
                        timeout_s: float = 120.0,
                        ctx: Optional[SpanContext] = None,
                        ) -> tuple[int, list[int], dict[str, Any]]:
        """Submit one request, block for its tokens; returns ``(rid, tokens,
        meta)`` where ``meta`` carries the rpc span id plus the queue/service
        split (``queue_ms`` = submit → decode-slot admission, ``service_ms``
        = admission → final token) the front door folds into its per-hop
        latency decomposition.
        """
        t_sub = time.monotonic()
        payload: dict[str, Any] = {"replica": self.name}
        if ctx is not None:
            payload["trace"] = ctx.trace
            payload["remote"] = ctx.to_payload()
        # the rpc span is this process's anchor for the cross-process chain:
        # locally it nests under the run root (single-session trees are
        # unchanged); its payload's "remote" ref names the frontdoor's route
        # span, and the engine's request bracket nests inside it
        with span_scope(self.run_span), \
                self.log.lifecycle("rpc", payload) as rpc_span:
            rid = self.engine.submit(prompt, max_new=max_new)
            with self._cond:
                self._cond.notify_all()  # wake the engine loop
                deadline = time.monotonic() + timeout_s
                while rid not in self._results:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or self._stop.is_set():
                        raise TimeoutError(
                            f"request {rid} not completed within {timeout_s}s")
                    self._cond.wait(timeout=min(remaining, 0.25))
                r = self._results.pop(rid)
            t_done = getattr(r, "t_done", time.monotonic())
            t_active = getattr(r, "t_active", 0.0) or t_done
            meta = {
                "span": rpc_span,
                "queue_ms": round(max(0.0, t_active - t_sub) * 1e3, 3),
                "service_ms": round(max(0.0, t_done - t_active) * 1e3, 3),
            }
            return rid, r.out, meta

    def health(self) -> dict[str, Any]:
        return {
            "ok": True,
            "replica": self.name,
            "pid": os.getpid(),
            "completed": self.completed,
            "pending": self.engine.pending(),
            **self.info,
        }


class _ReplicaHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    replica: Any = None


class _ReplicaHandler(BaseHTTPRequestHandler):
    def log_message(self, fmt: str, *args: Any) -> None:
        pass

    def _send(self, code: int, doc: Any) -> None:
        body = json.dumps(doc, default=repr).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
        path = urlparse(self.path).path
        rep = self.server.replica
        try:
            if path == "/healthz":
                self._send(200, rep.health())
            elif path == "/metrics" and rep.plane is not None:
                body = rep.plane.render().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            elif path == "/metrics.json" and rep.plane is not None:
                self._send(200, rep.plane.snapshot())
            else:
                self._send(404, {"error": "not found"})
        except Exception as exc:
            self._send(500, {"error": repr(exc)})

    def do_POST(self) -> None:  # noqa: N802 (stdlib naming)
        path = urlparse(self.path).path
        rep = self.server.replica
        if path != "/v1/generate":
            self._send(404, {"error": "not found"})
            return
        recv_unix = time.time()  # replica-side handshake stamp (wall clock)
        try:
            n = int(self.headers.get("Content-Length") or 0)
            body = json.loads(self.rfile.read(n) or b"{}")
            prompt = body.get("prompt")
            max_new = int(body.get("max_new", 16))
            if (not isinstance(prompt, list) or not prompt
                    or not all(isinstance(t, int) for t in prompt)):
                self._send(400, {"error": "prompt must be a non-empty list of ints"})
                return
            if max_new < 1:
                self._send(400, {"error": "max_new must be >= 1"})
                return
            ctx = SpanContext.extract(self.headers.get(TRACEPARENT_HEADER))
            t0 = time.perf_counter()
            rid, tokens, meta = rep.submit_and_wait(prompt, max_new, ctx=ctx)
            handler_ms = round((time.perf_counter() - t0) * 1e3, 3)
            self._send(200, {
                "rid": rid,
                "tokens": tokens,
                "replica": rep.name,
                "latency_ms": handler_ms,
                # everything the front door needs to decompose this hop and
                # to skew-correct this replica's clock at stitch time
                "ctx": {
                    "origin": rep.origin,
                    "span": meta["span"],
                    "trace": ctx.trace if ctx else None,
                    "recv_unix": recv_unix,
                    "sent_unix": time.time(),
                    "handler_ms": handler_ms,
                    "queue_ms": meta["queue_ms"],
                    "service_ms": meta["service_ms"],
                },
            })
        except TimeoutError as exc:
            self._send(504, {"error": str(exc)})
        except Exception as exc:
            self._send(500, {"error": repr(exc)})


def _build_real_engine(args: argparse.Namespace, log: EventLog,
                       plane: MetricsPlane) -> tuple[Any, dict[str, Any]]:
    """Construct a jax-backed Engine (imports deferred: synthetic replicas
    and the router process itself must never pay jax startup)."""
    import jax

    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.serving.engine import Engine, ServeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    dispatcher = None
    info: dict[str, Any] = {"arch": cfg.name}
    if args.dispatch != "off":
        from repro.dispatch import DispatchConfig, Dispatcher

        dispatcher = Dispatcher(
            DispatchConfig(policy=args.dispatch,
                           static_backend=args.dispatch_backend),
            log=log)
        info["chip"] = dispatcher.chip.name
        if args.fleet:
            from repro.fleet import warm_start_from_fleet

            fleet_rec, _pusher = warm_start_from_fleet(
                args.fleet, dispatcher, token=args.fleet_token)
            info["fleet"] = fleet_rec
    engine = Engine(
        cfg, params,
        ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                    seed=args.seed),
        log=log, dispatcher=dispatcher, metrics=plane.registry)
    return engine, info


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.router.replica", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--name", default=f"replica-{os.getpid()}")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (announced via --ready-file)")
    ap.add_argument("--ready-file", default=None, metavar="PATH",
                    help="announce the bound URL + identity here once serving")
    ap.add_argument("--synthetic", action="store_true",
                    help="deterministic no-accelerator engine (CI/tests)")
    ap.add_argument("--synthetic-ms-per-token", type=float, default=2.0,
                    metavar="MS", help="synthetic decode-tick sleep")
    ap.add_argument("--arch", default=None,
                    help="model config for a real engine (required unless "
                         "--synthetic)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--dispatch",
                    choices=("off", "static", "roofline", "profiled"),
                    default="off")
    ap.add_argument("--dispatch-backend", default="chunked")
    ap.add_argument("--fleet", default=None, metavar="URL|DIR",
                    help="warm-start dispatch profiles from a fleet target")
    ap.add_argument("--fleet-token", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-dir-root", default=None, metavar="DIR",
                    help="stream this replica's trace into DIR/<name>-<pid>/ "
                         "(a fresh dir per incarnation so supervisor restarts "
                         "never collide); the dir is announced in the ready "
                         "file for `repro.trace stitch` auto-discovery")
    ap.add_argument("--trace-rotate", type=int, default=2048, metavar="N",
                    help="events per streamed segment")
    args = ap.parse_args(argv)
    if not args.synthetic and not args.arch:
        ap.error("--arch is required unless --synthetic")

    from repro.hw.specs import default_chip
    from repro.trace.session import git_sha

    log = TraceCollector()
    plane = MetricsPlane(log)
    if args.synthetic:
        engine: Any = SyntheticEngine(
            max_batch=args.max_batch,
            ms_per_token=args.synthetic_ms_per_token,
            log=log, metrics=plane.registry)
        info: dict[str, Any] = {"chip": default_chip().name}
    else:
        engine, info = _build_real_engine(args, log, plane)
        info.setdefault("chip", default_chip().name)
    info.update({"git_sha": git_sha(), "synthetic": bool(args.synthetic)})

    stream = None
    if args.trace_dir_root:
        from repro.trace.stream import StreamingSession

        trace_dir = os.path.join(args.trace_dir_root,
                                 f"{args.name}-{os.getpid()}")
        stream = StreamingSession(
            trace_dir, rotate_events=args.trace_rotate,
            meta={"driver": "replica", "replica": args.name,
                  "origin": f"{args.name}:{os.getpid()}"},
            metrics_provider=plane.snapshot,
        ).attach(log)
        info["trace_dir"] = trace_dir

    server = ReplicaServer(engine, name=args.name, log=log, plane=plane,
                           host=args.host, port=args.port, info=info).start()
    announce = {"url": server.url, "pid": os.getpid(), "name": args.name,
                **info}
    print(json.dumps({"replica": args.name, **announce}), flush=True)
    if args.ready_file:
        write_ready_file(args.ready_file, announce)

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())
    while not stop.is_set():
        stop.wait(0.2)
    server.stop()
    if stream is not None:
        stream.close(stats=log.stats())
    print(json.dumps({"replica": args.name, "completed": server.completed,
                      "shutdown": True}), file=sys.stderr, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
