"""Cost-aware replica selection: the system-level analogue of ``repro.dispatch``.

The in-process dispatcher answers "which kernel tier runs this op best"; the
:class:`CostRouter` answers the same question one level up — "which *replica*
serves this request class best" — from the same two signal sources:

* **fleet profiles** (a priori): at startup each replica's (git SHA, chip)
  bucket is pulled from the fleet store and priced into a per-class seed cost
  (``serve_prefill`` at the nearest prompt length + ``max_new`` decode steps,
  best backend's min wall time).  Replicas on different chips therefore start
  with *different* costs — the heterogeneous-allocation argmin the paper
  sweeps offline, answered from measured history;
* **live EWMA latency** (a posteriori): every completion folds the observed
  end-to-end service time back into a per-(replica, class) EWMA, so the
  ranking tracks what the fleet could not know — current load, thermal
  state, a replica warming its caches after a restart.

Routing is argmin-cost with least-loaded tie-breaking (costs within
``tie_rel`` of the best are a tie), plus admission control: each replica
accepts at most ``queue_depth`` in-flight requests, and when every healthy
replica is full the request is shed (:class:`RouterBusy`) instead of queued
without bound.  No jax import anywhere on this path — the router process
stays a few-ms-startup front door.
"""
from __future__ import annotations

import dataclasses
import re
import threading
from typing import Any, Optional

# Fallback cost when a replica has neither a fleet seed nor live samples for
# a class: high enough that any measured replica wins, identical across cold
# replicas so the tie-break (least-loaded) spreads the exploration.
DEFAULT_COST_S = 0.25


class RouterBusy(RuntimeError):
    """Every healthy replica is at its queue-depth bound — shed the request."""


class NoReplicaAvailable(RuntimeError):
    """No replica is currently healthy (e.g. all mid-restart)."""


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < max(1, n):
        b <<= 1
    return b


def class_of(prompt_len: int, max_new: int) -> str:
    """Request class: power-of-two (prompt length, decode length) bucket.

    Mirrors the engine's own signature bucketing — prefill compiles per
    distinct prompt length, so callers already bucket lengths; the class is
    the routing-table key for seed costs and EWMA state.
    """
    return f"p{_pow2_bucket(prompt_len)}/n{_pow2_bucket(max_new)}"


_CLASS_RE = re.compile(r"^p(\d+)/n(\d+)$")
# ProfileStore keys are "op|backend|sig" with sig like "int32[1,16]" for a
# prefill's (1, prompt_len) token array.
_PREFILL_SIG_RE = re.compile(r"\[1,(\d+)\]$")


@dataclasses.dataclass
class SeedCosts:
    """Per-class a-priori costs priced from one fleet profile bucket."""

    prefill_s: dict[int, float]  # prompt_len -> best-backend min seconds
    decode_s: Optional[float]  # per decode tick, best backend
    match: str = "miss"  # fleet pull match quality (exact/chip/miss)

    def cost(self, cls: str) -> Optional[float]:
        m = _CLASS_RE.match(cls)
        if not m or self.decode_s is None or not self.prefill_s:
            return None
        plen, max_new = int(m.group(1)), int(m.group(2))
        nearest = min(self.prefill_s, key=lambda p: abs(p - plen))
        return self.prefill_s[nearest] + max_new * self.decode_s


def seed_costs_from_store(store: Any, match: str = "miss") -> Optional[SeedCosts]:
    """Price a pulled ProfileStore into :class:`SeedCosts`.

    Scans ``serve_prefill`` / ``serve_decode`` entries (the serving engine's
    dispatch ops) and keeps, per prompt length, the best backend's minimum
    observed wall time.  Returns None when the bucket carries nothing the
    router can price — the replica then starts on the default cost and live
    EWMA takes over from the first completion.
    """
    if store is None:
        return None
    prefill: dict[int, float] = {}
    decode: Optional[float] = None
    for key, entry in getattr(store, "_entries", {}).items():
        if entry.count == 0 or entry.min_s == float("inf"):
            continue
        parts = key.split("|")
        if len(parts) != 3:
            continue
        op, _backend, sig = parts
        if op == "serve_prefill":
            m = _PREFILL_SIG_RE.search(sig)
            if m:
                plen = int(m.group(1))
                prefill[plen] = min(prefill.get(plen, float("inf")), entry.min_s)
        elif op == "serve_decode":
            decode = entry.min_s if decode is None else min(decode, entry.min_s)
    if not prefill or decode is None:
        return None
    return SeedCosts(prefill_s=prefill, decode_s=decode, match=match)


@dataclasses.dataclass
class ReplicaSignal:
    """Everything the router knows about one replica."""

    name: str
    url: str = ""
    healthy: bool = False
    inflight: int = 0
    completed: int = 0
    failed: int = 0
    ewma_s: dict[str, float] = dataclasses.field(default_factory=dict)
    ewma_all_s: Optional[float] = None
    seed: Optional[SeedCosts] = None


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One routing choice: where, at what predicted cost, from which signal."""

    replica: str
    url: str
    cls: str
    cost_s: float
    source: str  # ewma | ewma-any | seed | cold
    inflight: int  # replica in-flight count at decision time (pre-begin)

    def payload(self) -> dict[str, Any]:
        """Trace-event payload, shaped like a dispatch decision's."""
        return {"replica": self.replica, "class": self.cls,
                "cost_ms": round(self.cost_s * 1e3, 4), "source": self.source,
                "inflight": self.inflight}


class CostRouter:
    """Argmin-cost replica selection with admission control.

    Thread-safe: HTTP handler threads route/complete concurrently while the
    replica manager's supervisor thread flips health state.  ``registry`` (a
    :class:`repro.metrics.registry.MetricsRegistry`) gets per-replica
    queue-depth gauges and up/down state gauges maintained in place.
    """

    def __init__(
        self,
        *,
        queue_depth: int = 16,
        ewma_alpha: float = 0.25,
        tie_rel: float = 0.10,
        default_cost_s: float = DEFAULT_COST_S,
        registry: Optional[Any] = None,
    ) -> None:
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1 (got {queue_depth})")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1] (got {ewma_alpha})")
        self.queue_depth = queue_depth
        self.ewma_alpha = ewma_alpha
        self.tie_rel = tie_rel
        self.default_cost_s = default_cost_s
        self.registry = registry
        self._lock = threading.Lock()
        self._replicas: dict[str, ReplicaSignal] = {}
        self._rr = 0  # final round-robin tie-break cursor
        self.rejected = 0

    # -- membership / health (ReplicaManager callbacks) -----------------------

    def add_replica(self, name: str) -> None:
        with self._lock:
            self._replicas.setdefault(name, ReplicaSignal(name))
        self._gauges(name)

    def seed_replica(self, name: str, store: Any, match: str = "miss") -> bool:
        """Install fleet-pulled seed costs for one replica; True if priceable."""
        seed = seed_costs_from_store(store, match=match)
        with self._lock:
            r = self._replicas.setdefault(name, ReplicaSignal(name))
            r.seed = seed
        return seed is not None

    def mark_up(self, name: str, url: str) -> None:
        with self._lock:
            r = self._replicas.setdefault(name, ReplicaSignal(name))
            r.healthy = True
            r.url = url
        self._gauges(name)

    def mark_down(self, name: str) -> None:
        with self._lock:
            r = self._replicas.get(name)
            if r is not None:
                r.healthy = False
        self._gauges(name)

    # -- cost model -----------------------------------------------------------

    def _cost(self, r: ReplicaSignal, cls: str) -> tuple[float, str]:
        """Predicted service seconds for ``cls`` on ``r`` + signal source."""
        ewma = r.ewma_s.get(cls)
        if ewma is not None:
            return ewma, "ewma"
        if r.ewma_all_s is not None:
            return r.ewma_all_s, "ewma-any"
        if r.seed is not None:
            seeded = r.seed.cost(cls)
            if seeded is not None:
                return seeded, "seed"
        return self.default_cost_s, "cold"

    def route(self, cls: str) -> RouteDecision:
        """Pick the argmin-cost healthy replica with a free queue slot.

        Ties (costs within ``tie_rel`` of the minimum) break to the
        least-loaded replica, then round-robin — so a cold fleet of
        identical replicas load-balances instead of convoying onto one.
        Raises :class:`NoReplicaAvailable` (nothing healthy — callers may
        wait and retry) or :class:`RouterBusy` (healthy but all queues full —
        callers shed).
        """
        with self._lock:
            healthy = [r for r in self._replicas.values() if r.healthy]
            if not healthy:
                raise NoReplicaAvailable(
                    f"0/{len(self._replicas)} replicas healthy")
            open_ = [r for r in healthy if r.inflight < self.queue_depth]
            if not open_:
                self.rejected += 1
                raise RouterBusy(
                    f"all {len(healthy)} healthy replicas at queue depth "
                    f"{self.queue_depth}")
            scored = [(self._cost(r, cls), r) for r in open_]
            best_cost = min(c for (c, _src), _r in scored)
            tied = [(c, src, r) for (c, src), r in scored
                    if c <= best_cost * (1.0 + self.tie_rel)]
            least = min(r.inflight for _c, _s, r in tied)
            tied = [t for t in tied if t[2].inflight == least]
            self._rr += 1
            cost, source, r = tied[self._rr % len(tied)]
            return RouteDecision(replica=r.name, url=r.url, cls=cls,
                                 cost_s=cost, source=source,
                                 inflight=r.inflight)

    # -- in-flight + feedback -------------------------------------------------

    def begin(self, name: str) -> None:
        with self._lock:
            r = self._replicas[name]
            r.inflight += 1
        self._gauges(name)

    def end(self, name: str) -> None:
        with self._lock:
            r = self._replicas.get(name)
            if r is not None and r.inflight > 0:
                r.inflight -= 1
        self._gauges(name)

    def complete(self, name: str, cls: str, seconds: float) -> None:
        """Fold one observed end-to-end service time into the EWMA signals."""
        a = self.ewma_alpha
        with self._lock:
            r = self._replicas[name]
            r.completed += 1
            prev = r.ewma_s.get(cls)
            r.ewma_s[cls] = seconds if prev is None else (1 - a) * prev + a * seconds
            r.ewma_all_s = (seconds if r.ewma_all_s is None
                            else (1 - a) * r.ewma_all_s + a * seconds)

    def fail(self, name: str, *, dead: bool = False) -> None:
        """Record a forward failure; ``dead`` marks the replica down outright
        (connection refused/reset — the process is gone) so no further
        requests route to it until the manager confirms a restart."""
        with self._lock:
            r = self._replicas.get(name)
            if r is None:
                return
            r.failed += 1
            if dead:
                r.healthy = False
        self._gauges(name)

    # -- introspection --------------------------------------------------------

    def _gauges(self, name: str) -> None:
        if self.registry is None:
            return
        with self._lock:
            r = self._replicas.get(name)
            if r is None:
                return
            inflight, healthy = r.inflight, r.healthy
        self.registry.gauge("repro_router_replica_queue_depth",
                            "in-flight requests per replica",
                            replica=name).set(inflight)
        self.registry.gauge("repro_router_replica_up",
                            "replica routable (1) or down (0)",
                            replica=name).set(1.0 if healthy else 0.0)

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return {
                "queue_depth": self.queue_depth,
                "rejected": self.rejected,
                "replicas": {
                    r.name: {
                        "healthy": r.healthy,
                        "inflight": r.inflight,
                        "completed": r.completed,
                        "failed": r.failed,
                        "ewma_ms": {c: round(v * 1e3, 3)
                                    for c, v in sorted(r.ewma_s.items())},
                        "seeded": r.seed is not None,
                        "seed_match": r.seed.match if r.seed else None,
                    }
                    for r in self._replicas.values()
                },
            }
