"""The router's front door: one HTTP listener, exactly-once forwarding.

``POST /v1/generate`` runs the full request pipeline inside a ``request``
span parented under the router's run root:

1. classify (:func:`repro.router.cost.class_of`) and **route** — each
   routing decision is recorded as a ``route`` event parented under the
   request span, mirroring how dispatch decisions nest under the op that
   triggered them;
2. **forward** to the chosen replica.  A connection-level failure
   (refused / reset / replica hung up mid-response) means the replica died
   with the request in flight: mark it down, pick another replica, retry —
   the drain-then-retry path that makes a SIGKILLed replica invisible to
   clients.  Admission control stays honest across retries (``begin``/``end``
   bracket every attempt);
3. account the terminal ``outcome`` event (``ok`` / ``retried`` /
   ``rejected`` / ``error``) that the metrics sink folds into
   ``repro_router_requests_total{replica,outcome}`` and
   ``repro_router_route_ms`` — every request gets exactly one.

``GET /healthz`` reports router totals plus per-replica manager state (CI
reads pids out of it to aim its SIGKILL); ``/metrics`` + ``/metrics.json``
expose the router's metrics plane on the same port.
"""
from __future__ import annotations

import http.client
import json
import os
import socket
import time
import urllib.error
import urllib.request
import uuid
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Optional
from urllib.parse import urlparse

from repro.core.events import SpanContext, TRACEPARENT_HEADER, next_span_id
from repro.router.cost import NoReplicaAvailable, RouterBusy, class_of

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class ReplicaDead(RuntimeError):
    """Connection-level forward failure: the replica process is gone."""


class ForwardFailed(RuntimeError):
    """The replica answered, but with an error/timeout — do not mark it dead."""


def forward_generate(url: str, body: bytes, timeout_s: float,
                     headers: Optional[dict[str, str]] = None) -> dict[str, Any]:
    """POST one generate request to a replica, classifying failures.

    :class:`ReplicaDead` is raised only for failures that prove the process
    is unreachable (refused/reset/hung-up) — those are safe to drain-retry
    on another replica.  Anything else (HTTP error, timeout with the
    connection still up) raises :class:`ForwardFailed`: the replica may
    still be computing, so retrying elsewhere risks double work, and the
    supervisor's healthz probing owns the wedged-replica call.

    ``headers`` adds extra request headers — the front door passes the
    ``X-Repro-Traceparent`` span context here.
    """
    req = urllib.request.Request(
        f"{url}/v1/generate", data=body, method="POST",
        headers={"Content-Type": "application/json", **(headers or {})})
    try:
        with urllib.request.urlopen(req, timeout=timeout_s) as resp:
            return json.loads(resp.read())
    except urllib.error.HTTPError as exc:
        raise ForwardFailed(f"replica HTTP {exc.code}") from exc
    except (ConnectionRefusedError, ConnectionResetError, BrokenPipeError,
            http.client.RemoteDisconnected) as exc:
        raise ReplicaDead(f"{type(exc).__name__}: {exc}") from exc
    except urllib.error.URLError as exc:
        reason = getattr(exc, "reason", None)
        if isinstance(reason, (ConnectionRefusedError, ConnectionResetError,
                               BrokenPipeError, http.client.RemoteDisconnected)):
            raise ReplicaDead(f"{type(reason).__name__}: {reason}") from exc
        raise ForwardFailed(f"URLError: {reason}") from exc
    except (http.client.HTTPException, socket.timeout, TimeoutError,
            OSError) as exc:
        raise ForwardFailed(f"{type(exc).__name__}: {exc}") from exc


class FrontDoorServer(ThreadingHTTPServer):
    """Router-owned listener; handler threads read shared state off it."""

    daemon_threads = True
    allow_reuse_address = True
    # injected by repro.router.cli before serve_forever
    log: Any = None
    router: Any = None
    manager: Any = None
    plane: Any = None
    run_span: int = 0
    forward_timeout_s: float = 120.0
    request_timeout_s: float = 30.0  # budget for finding a live replica
    requests_seen: int = 0
    origin: str = ""  # process identity stamped into injected SpanContexts

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def stop(self) -> None:
        self.shutdown()
        self.server_close()


class FrontDoorHandler(BaseHTTPRequestHandler):
    def log_message(self, fmt: str, *args: Any) -> None:
        pass

    def _send(self, code: int, doc: Any,
              headers: Optional[dict[str, str]] = None) -> None:
        body = json.dumps(doc, default=repr).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    # -- GET: health + metrics -------------------------------------------------

    def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
        path = urlparse(self.path).path
        srv = self.server
        try:
            if path == "/healthz":
                self._send(200, {
                    "ok": True,
                    "requests": srv.requests_seen,
                    "router": srv.router.snapshot(),
                    "replicas": srv.manager.status(),
                })
            elif path == "/metrics":
                body = srv.plane.render().encode()
                self.send_response(200)
                self.send_header("Content-Type", PROM_CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            elif path == "/metrics.json":
                self._send(200, srv.plane.snapshot())
            else:
                self._send(404, {"error": "not found"})
        except Exception as exc:
            self._send(500, {"error": repr(exc)})

    # -- POST: the routed request pipeline ------------------------------------

    def do_POST(self) -> None:  # noqa: N802 (stdlib naming)
        if urlparse(self.path).path != "/v1/generate":
            self._send(404, {"error": "not found"})
            return
        srv = self.server
        try:
            n = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(n) or b"{}"
            body = json.loads(raw)
            prompt = body.get("prompt")
            max_new = int(body.get("max_new", 16))
            if (not isinstance(prompt, list) or not prompt
                    or not all(isinstance(t, int) for t in prompt)):
                self._send(400, {"error": "prompt must be a non-empty list of ints"})
                return
        except (ValueError, TypeError) as exc:
            self._send(400, {"error": f"bad request body: {exc}"})
            return
        srv.requests_seen += 1
        self._route_and_forward(srv, raw, prompt, max_new)

    def _route_and_forward(self, srv: FrontDoorServer, raw: bytes,
                           prompt: list[int], max_new: int) -> None:
        log, router = srv.log, srv.router
        cls = class_of(len(prompt), max_new)
        origin = srv.origin or f"frontdoor:{os.getpid()}"
        trace_id = uuid.uuid4().hex[:16]
        t_req0 = time.perf_counter()
        route_ms = 0.0
        attempts = 0
        deadline = time.monotonic() + srv.request_timeout_s

        def outcome(name: str, replica: str, rspan: int,
                    **extra: Any) -> dict[str, Any]:
            payload = {
                "replica": replica, "outcome": name, "class": cls,
                "route_ms": round(route_ms, 4),
                "latency_ms": round((time.perf_counter() - t_req0) * 1e3, 3),
                "attempts": attempts, **extra,
            }
            log.record("route", "outcome", payload, parent=rspan)
            return payload

        with log.lifecycle("request", {"class": cls, "trace": trace_id},
                           parent=srv.run_span) as rspan:
            while True:
                t0 = time.perf_counter()
                try:
                    decision = router.route(cls)
                except RouterBusy as exc:
                    route_ms += (time.perf_counter() - t0) * 1e3
                    p = outcome("rejected", "-", rspan, error=str(exc))
                    self._send(429, {"error": str(exc), **p})
                    return
                except NoReplicaAvailable as exc:
                    route_ms += (time.perf_counter() - t0) * 1e3
                    if time.monotonic() >= deadline:
                        p = outcome("error", "-", rspan, error=str(exc))
                        self._send(503, {"error": str(exc), **p})
                        return
                    time.sleep(0.05)  # replicas mid-restart: wait, re-route
                    continue
                route_ms += (time.perf_counter() - t0) * 1e3
                # the per-attempt route decision gets its own span id so the
                # replica's rpc span can name it as a remote parent; the
                # injected SpanContext's sent_unix + the reply's wall stamps
                # form the handshake pair stitch uses to estimate clock skew
                route_span = next_span_id()
                log.record("route", "route",
                           {**decision.payload(), "trace": trace_id},
                           span=route_span, parent=rspan)
                ctx = SpanContext(trace=trace_id, span=route_span,
                                  origin=origin, sent_unix=time.time())
                router.begin(decision.replica)
                t_fwd = time.perf_counter()
                try:
                    reply = forward_generate(decision.url, raw,
                                             srv.forward_timeout_s,
                                             headers={TRACEPARENT_HEADER:
                                                      ctx.inject()})
                except ReplicaDead as exc:
                    router.end(decision.replica)
                    router.fail(decision.replica, dead=True)
                    attempts += 1
                    log.record("mark", "replica",
                               {"replica": decision.replica, "state": "dead-on-forward",
                                "error": str(exc)}, parent=rspan)
                    if time.monotonic() >= deadline:
                        p = outcome("error", decision.replica, rspan,
                                    error=str(exc))
                        self._send(503, {"error": str(exc), **p})
                        return
                    continue  # drain-then-retry on another replica
                except ForwardFailed as exc:
                    router.end(decision.replica)
                    router.fail(decision.replica)
                    attempts += 1
                    if time.monotonic() >= deadline:
                        p = outcome("error", decision.replica, rspan,
                                    error=str(exc))
                        self._send(502, {"error": str(exc), **p})
                        return
                    continue
                recv_unix = time.time()
                service_s = time.perf_counter() - t_fwd
                router.end(decision.replica)
                router.complete(decision.replica, cls, service_s)
                extra = self._hop_extra(reply, ctx, recv_unix,
                                        fwd_ms=service_s * 1e3,
                                        lat_ms=(time.perf_counter() - t_req0) * 1e3)
                p = outcome("retried" if attempts else "ok",
                            decision.replica, rspan, **extra)
                self._send(200, {**reply, "routed_to": decision.replica,
                                 "outcome": p["outcome"],
                                 "route_ms": p["route_ms"],
                                 "attempts": attempts,
                                 "trace": trace_id,
                                 "hops": p.get("hops")},
                           headers={"X-Repro-Replica": decision.replica,
                                    "X-Repro-Route-Ms": str(p["route_ms"])})
                return

    @staticmethod
    def _hop_extra(reply: dict[str, Any], ctx: SpanContext, recv_unix: float,
                   *, fwd_ms: float, lat_ms: float) -> dict[str, Any]:
        """Per-hop latency decomposition + the clock-skew handshake record.

        The four hops telescope — ``frontdoor_queue = latency - forward``,
        ``network = forward - handler``, ``replica_queue = handler -
        service`` — so their sum equals the end-to-end latency *by
        construction*, using only single-clock durations (each term is
        measured within one process; no cross-host clock appears).  ``hs``
        carries the four wall timestamps of the forward round trip
        (frontdoor send/recv, replica recv/send) for stitch's NTP-style
        offset estimate.
        """
        extra: dict[str, Any] = {"latency_ms": round(lat_ms, 3)}
        rctx = reply.get("ctx")
        if not isinstance(rctx, dict):
            return extra  # pre-tracing replica: no decomposition possible
        try:
            handler_ms = float(rctx["handler_ms"])
            service_ms = float(rctx["service_ms"])
        except (KeyError, TypeError, ValueError):
            return extra
        extra["hops"] = {
            "frontdoor_queue": round(lat_ms - fwd_ms, 3),
            "network": round(fwd_ms - handler_ms, 3),
            "replica_queue": round(handler_ms - service_ms, 3),
            "service": round(service_ms, 3),
        }
        extra["hs"] = {
            "origin": rctx.get("origin"), "span": rctx.get("span"),
            "trace": ctx.trace,
            "sent_unix": ctx.sent_unix, "recv_unix": recv_unix,
            "replica_recv_unix": rctx.get("recv_unix"),
            "replica_sent_unix": rctx.get("sent_unix"),
        }
        return extra


def make_frontdoor(host: str = "127.0.0.1", port: int = 0) -> FrontDoorServer:
    return FrontDoorServer((host, port), FrontDoorHandler)
