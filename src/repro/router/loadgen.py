"""Router load generator: drive a mixed workload, verify exactly-once.

  PYTHONPATH=src python -m repro.router.loadgen --router http://127.0.0.1:PORT \\
      --requests 200 --concurrency 8 --verify-synthetic --json out.json

Builds a deterministic request mix (seeded prompt lengths × decode lengths),
fires it through worker threads, and accounts every submitted request into
exactly one bucket: ``ok`` / ``retried`` (completed), ``rejected`` (shed by
admission control), or ``error``.  ``--verify-synthetic`` recomputes
:func:`repro.router.replica.expected_synthetic_tokens` for every completed
response — the proof that a request retried after a replica SIGKILL produced
the *same* answer it would have on the dead replica, i.e. that drain-retry
is invisible to clients.  ``run()`` is importable for tests and benchmarks.
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import threading
import time
import urllib.error
import urllib.request
from typing import Any, Optional

from repro.router.replica import expected_synthetic_tokens


def _percentile(xs: list[float], q: float) -> Optional[float]:
    if not xs:
        return None
    xs = sorted(xs)
    i = min(len(xs) - 1, int(q * len(xs)))
    return xs[i]


def build_specs(n: int, prompt_lens: list[int], max_new: int,
                seed: int = 0) -> list[dict[str, Any]]:
    """Deterministic mixed workload: n requests cycling the prompt lengths."""
    rng = random.Random(seed)
    specs = []
    for i in range(n):
        plen = prompt_lens[i % len(prompt_lens)]
        specs.append({
            "prompt": [rng.randrange(0, 50257) for _ in range(plen)],
            "max_new": max_new,
        })
    return specs


def run(router_url: str, specs: list[dict[str, Any]], *, concurrency: int = 4,
        timeout_s: float = 120.0, verify_synthetic: bool = False) -> dict[str, Any]:
    """Fire ``specs`` at the router; return the full accounting report."""
    lock = threading.Lock()
    idx = [0]
    outcomes = {"ok": 0, "retried": 0, "rejected": 0, "error": 0}
    by_replica: dict[str, int] = {}
    latencies: list[float] = []
    route_ms: list[float] = []
    hop_ms: dict[str, list[float]] = {}  # frontdoor's per-hop decomposition
    responses: dict[int, int] = {}  # spec index -> completion count
    verify_failures = 0
    verified = 0

    def one(i: int, spec: dict[str, Any]) -> None:
        nonlocal verify_failures, verified
        body = json.dumps(spec).encode()
        req = urllib.request.Request(
            f"{router_url}/v1/generate", data=body, method="POST",
            headers={"Content-Type": "application/json"})
        t0 = time.perf_counter()
        try:
            with urllib.request.urlopen(req, timeout=timeout_s) as resp:
                doc = json.loads(resp.read())
            outcome = doc.get("outcome", "ok")
            ok = True
        except urllib.error.HTTPError as exc:
            try:
                doc = json.loads(exc.read())
            except Exception:
                doc = {}
            outcome = doc.get("outcome",
                              "rejected" if exc.code == 429 else "error")
            ok = False
        except Exception:
            doc, outcome, ok = {}, "error", False
        wall_ms = (time.perf_counter() - t0) * 1e3
        good_tokens = None
        if ok and verify_synthetic:
            expected = expected_synthetic_tokens(spec["prompt"], spec["max_new"])
            good_tokens = doc.get("tokens") == expected
        with lock:
            outcomes[outcome] = outcomes.get(outcome, 0) + 1
            if ok:
                responses[i] = responses.get(i, 0) + 1
                latencies.append(wall_ms)
                rep = doc.get("routed_to") or doc.get("replica") or "?"
                by_replica[rep] = by_replica.get(rep, 0) + 1
                if isinstance(doc.get("route_ms"), (int, float)):
                    route_ms.append(float(doc["route_ms"]))
                if isinstance(doc.get("hops"), dict):
                    for hop, v in doc["hops"].items():
                        if isinstance(v, (int, float)):
                            hop_ms.setdefault(hop, []).append(float(v))
                if good_tokens is not None:
                    verified += 1
                    if not good_tokens:
                        verify_failures += 1

    def worker() -> None:
        while True:
            with lock:
                if idx[0] >= len(specs):
                    return
                i = idx[0]
                idx[0] += 1
            one(i, specs[i])

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(max(1, concurrency))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0
    completed = outcomes["ok"] + outcomes["retried"]
    report = {
        "submitted": len(specs),
        "completed": completed,
        "outcomes": outcomes,
        # any spec index answered twice would be a duplicate delivery —
        # impossible over one HTTP round-trip each, asserted anyway
        "duplicates": sum(1 for c in responses.values() if c > 1),
        "lost": len(specs) - sum(outcomes.values()),
        "by_replica": dict(sorted(by_replica.items())),
        "latency_ms": {
            "p50": _percentile(latencies, 0.50),
            "p95": _percentile(latencies, 0.95),
            "max": max(latencies) if latencies else None,
        },
        "route_ms": {
            "mean": (round(sum(route_ms) / len(route_ms), 4)
                     if route_ms else None),
            "p95": _percentile(route_ms, 0.95),
        },
        "hop_ms": {
            hop: {"mean": round(sum(vs) / len(vs), 4),
                  "p95": _percentile(vs, 0.95)}
            for hop, vs in sorted(hop_ms.items())
        },
        "wall_s": round(wall_s, 3),
    }
    if verify_synthetic:
        report["verified"] = verified
        report["verify_failures"] = verify_failures
    return report


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.router.loadgen", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--router", required=True, metavar="URL")
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--prompt-lens", default="8,16,32",
                    help="comma-separated prompt lengths to cycle through")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--timeout-s", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify-synthetic", action="store_true",
                    help="recompute expected synthetic tokens per response")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the report JSON here")
    args = ap.parse_args(argv)

    specs = build_specs(args.requests,
                        [int(x) for x in args.prompt_lens.split(",") if x],
                        args.max_new, seed=args.seed)
    report = run(args.router.rstrip("/"), specs,
                 concurrency=args.concurrency, timeout_s=args.timeout_s,
                 verify_synthetic=args.verify_synthetic)
    print(json.dumps(report), flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
    ok = (report["completed"] + report["outcomes"]["rejected"]
          + report["outcomes"]["error"] == report["submitted"]
          and report["duplicates"] == 0
          and report.get("verify_failures", 0) == 0)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
