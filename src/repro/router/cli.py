"""Router driver: spawn a replica fleet behind one cost-routed front door.

  PYTHONPATH=src python -m repro.router --replicas 2 --synthetic \\
      --port 0 --ready-file router.ready --trace-dir router_trace

Everything after the router's own flags configures the replicas (they all
get the same engine flags): ``--synthetic`` for the deterministic CI engine,
or ``--arch``/``--reduced``/``--dispatch`` for real jax-backed replicas.

Observability mirrors the single-process drivers: ``--trace-dir`` streams
the router's events (request spans, route decisions, replica lifecycle)
durably; ``--metrics-port`` serves the router metrics plane on a dedicated
listener (the front door also exposes ``/metrics`` on its own port).

``--fleet`` seeds the cost model: for each replica's announced
(git SHA, chip) the router pulls that bucket's ProfileStore and prices
per-class a-priori costs from its ``serve_prefill``/``serve_decode``
entries — so a heterogeneous fleet starts routing each request class toward
the chip where it measured fastest, before a single live sample exists.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import threading
from typing import Optional

from repro.metrics import MetricsPlane, serve_metrics
from repro.router.cost import CostRouter
from repro.router.frontdoor import make_frontdoor
from repro.router.manager import ReplicaManager
from repro.trace import StreamingSession, TraceCollector
from repro.utils.ready import write_ready_file


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.router", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--replicas", type=int, default=2, metavar="N")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="front-door port (0 picks a free one)")
    ap.add_argument("--ready-file", default=None, metavar="PATH",
                    help="announce the front-door URL here once routable")
    ap.add_argument("--workdir", default="router_work", metavar="DIR",
                    help="replica ready files + per-replica logs land here")
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="admission control: max in-flight per replica")
    ap.add_argument("--ewma-alpha", type=float, default=0.25,
                    help="live latency EWMA weight for new samples")
    ap.add_argument("--request-timeout-s", type=float, default=30.0,
                    help="budget for finding a live replica before 503")
    ap.add_argument("--forward-timeout-s", type=float, default=120.0,
                    help="per-attempt replica response timeout")
    ap.add_argument("--fleet", default=None, metavar="URL|DIR",
                    help="seed per-replica routing costs from this fleet's "
                         "(git SHA, chip) profile buckets")
    ap.add_argument("--fleet-token", default=None)
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="dedicated Prometheus listener for the router plane")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="stream router events as durable JSONL segments")
    ap.add_argument("--trace-rotate", type=int, default=2048, metavar="N")
    ap.add_argument("--trace-rotate-keep", type=int, default=None, metavar="N")
    ap.add_argument("--startup-timeout-s", type=float, default=120.0)
    # replica engine flags (forwarded verbatim to every replica)
    ap.add_argument("--synthetic", action="store_true")
    ap.add_argument("--synthetic-ms-per-token", type=float, default=2.0)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--dispatch",
                    choices=("off", "static", "roofline", "profiled"),
                    default="off")
    ap.add_argument("--dispatch-backend", default="chunked")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not args.synthetic and not args.arch:
        ap.error("--arch is required unless --synthetic")

    replica_argv = ["--max-batch", str(args.max_batch),
                    "--max-seq", str(args.max_seq),
                    "--seed", str(args.seed)]
    if args.synthetic:
        replica_argv += ["--synthetic", "--synthetic-ms-per-token",
                         str(args.synthetic_ms_per_token)]
    else:
        replica_argv += ["--arch", args.arch,
                         "--dispatch", args.dispatch,
                         "--dispatch-backend", args.dispatch_backend]
        if args.reduced:
            replica_argv.append("--reduced")
        if args.fleet:
            replica_argv += ["--fleet", args.fleet]
            if args.fleet_token:
                replica_argv += ["--fleet-token", args.fleet_token]
    if args.trace_dir:
        # every replica streams its own session under <trace-dir>/replicas/
        # (each picks a fresh <name>-<pid> subdir per incarnation, so
        # supervisor restarts never collide); the frontdoor manifest lists
        # the announced dirs so `repro.trace stitch <trace-dir>` finds the
        # whole fleet from one path
        replica_argv += ["--trace-dir-root",
                         os.path.join(args.trace_dir, "replicas"),
                         "--trace-rotate", str(args.trace_rotate)]

    log = TraceCollector()
    plane = MetricsPlane(log)
    router = CostRouter(queue_depth=args.queue_depth,
                        ewma_alpha=args.ewma_alpha,
                        registry=plane.registry)
    stream = None
    if args.trace_dir:
        stream = StreamingSession(
            args.trace_dir,
            rotate_events=args.trace_rotate,
            max_segments=args.trace_rotate_keep,
            meta={"driver": "router", "replicas": args.replicas,
                  "origin": f"frontdoor:{os.getpid()}"},
            metrics_provider=plane.snapshot,
        ).attach(log)

    fleet_client = None
    seed_cache: dict[tuple[str, str], tuple] = {}
    if args.fleet:
        from repro.fleet.client import FleetClient, FleetError

        fleet_client = FleetClient(args.fleet, token=args.fleet_token)

    def seed_from_fleet(name: str, info: dict) -> None:
        """Pull the replica's (git SHA, chip) bucket and price routing costs.

        One pull per distinct identity — homogeneous fleets hit the fleet
        service once, not N times."""
        if fleet_client is None:
            return
        key = (str(info.get("git_sha") or ""), str(info.get("chip") or ""))
        if key not in seed_cache:
            try:
                pulled = fleet_client.pull(*key)
                seed_cache[key] = (pulled["store"], pulled["match"])
            except FleetError as exc:
                print(f"router: fleet seed pull failed for {key}: {exc}",
                      file=sys.stderr)
                seed_cache[key] = (None, "error")
        store, match = seed_cache[key]
        priced = router.seed_replica(name, store, match=match)
        print(f"router: {name} fleet seed ({key[0]}, {key[1]}) -> {match}"
              f"{' (priced)' if priced else ''}", file=sys.stderr)

    replica_sessions: list[dict] = []

    def on_up(name: str, url: str, info: dict) -> None:
        router.add_replica(name)
        seed_from_fleet(name, info)
        router.mark_up(name, url)
        td = info.get("trace_dir")
        if stream is not None and td and not any(
                r["trace_dir"] == td for r in replica_sessions):
            replica_sessions.append({"replica": name, "trace_dir": td})
            stream.set_meta("replica_sessions", list(replica_sessions))

    def on_down(name: str, reason: str) -> None:
        router.mark_down(name)

    manager = ReplicaManager(
        args.replicas, replica_argv, args.workdir,
        log=log, registry=plane.registry,
        on_up=on_up, on_down=on_down,
        startup_timeout_s=args.startup_timeout_s)

    # root span of the router's whole life: request spans and replica
    # lifecycle marks nest under it in report --tree and the exporters
    from repro.core.events import next_span_id

    run_span = next_span_id()
    log.record("spawn", "router_run",
               {"replicas": args.replicas, "synthetic": args.synthetic},
               span=run_span)
    try:
        manager.start()
    except Exception as exc:
        print(f"router: replica startup failed: {exc}", file=sys.stderr)
        manager.stop()
        return 1

    front = make_frontdoor(args.host, args.port)
    front.log = log
    front.router = router
    front.manager = manager
    front.plane = plane
    front.run_span = run_span
    front.origin = f"frontdoor:{os.getpid()}"
    front.request_timeout_s = args.request_timeout_s
    front.forward_timeout_s = args.forward_timeout_s
    threading.Thread(target=front.serve_forever, name="frontdoor",
                     daemon=True).start()

    mserver = None
    if args.metrics_port is not None:
        mserver = serve_metrics(plane, port=args.metrics_port)
        print(f"router metrics: {mserver.url}/metrics", file=sys.stderr)

    print(json.dumps({"router": front.url, "replicas": manager.status()}),
          flush=True)
    if args.ready_file:
        write_ready_file(args.ready_file,
                         {"url": front.url, "replicas": args.replicas})

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())
    while not stop.is_set():
        stop.wait(0.2)

    front.stop()
    manager.stop()
    log.record("exit", "router_run",
               {"requests": front.requests_seen}, span=run_span)
    rec = {
        "router": front.url,
        "requests": front.requests_seen,
        "routing": router.snapshot(),
        "replicas": manager.status(),
    }
    trace_stats = log.stats()
    rec["trace"] = trace_stats
    if stream is not None:
        rec["trace_dir"] = stream.close(stats=trace_stats)
    if mserver is not None:
        mserver.stop()
    print(json.dumps(rec), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
