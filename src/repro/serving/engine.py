"""Continuous-batching serving engine (fixed decode slots).

vLLM-style scheduling reduced to its TPU-friendly core: a static
(max_batch)-slot decode batch whose caches live donated on device, per-slot
prefill that scatters a new request's cache into its slot, and one fused
decode step for all active slots per tick.  Static shapes everywhere — no
recompilation as requests come and go (slot masks handle liveness).

Request lifecycle events (spawn/exit) flow into the EventLog — the paper's
thread/process tracing, where the unit of concurrency is the request.

Prefill compiles per distinct prompt length (callers should bucket lengths);
a production deployment would add a masked fixed-length prefill on top of the
same cache contract.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.events import GLOBAL_LOG, EventLog, current_span, next_span_id, span_scope
from repro.dispatch.cost import estimate_callable
from repro.dispatch.dispatcher import Dispatcher, with_impl
from repro.dispatch.profiles import signature
from repro.models import lm
from repro.trace.liveprof import device_annotation


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = -1  # -1 = never; synthetic workloads run to max_new
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    span: int = 0  # trace span id shared by the request's spawn/exit events
    parent: int = 0  # enclosing span at submit time (e.g. the driver's run span)
    t_active: float = 0.0  # monotonic instant the request won a decode slot


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        scfg: ServeConfig,
        *,
        log: Optional[EventLog] = None,
        dispatcher: Optional[Dispatcher] = None,
        metrics: Optional[Any] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.log = GLOBAL_LOG if log is None else log
        self.dispatcher = dispatcher
        # live occupancy gauges (a repro.metrics MetricsRegistry): queue depth
        # and decode-slot usage are states, not events — the trace can't
        # answer "how full is the batch right now" without replaying it
        self._g_queue = self._g_slots = None
        if metrics is not None:
            self._g_queue = metrics.gauge(
                "repro_serve_queue_depth", "requests waiting for a decode slot")
            self._g_slots = metrics.gauge(
                "repro_serve_active_slots", "occupied decode slots")
        B, S = scfg.max_batch, scfg.max_seq
        self.caches = lm.init_caches(cfg, B, S)
        self.cur_pos = np.zeros(B, np.int32)  # next position per slot
        self.active: list[Optional[Request]] = [None] * B
        self.queue: list[Request] = []
        # submit() is called from HTTP handler threads when the engine runs
        # behind a repro.router replica; the queue hand-off is the only state
        # shared with the engine-loop thread (active/caches stay loop-owned)
        self._queue_lock = threading.Lock()
        self._rid = itertools.count()
        self._key = jax.random.PRNGKey(scfg.seed)

        # compiled surfaces (static shapes).  With a dispatcher, one compiled
        # variant per backend target (kernel impl baked in at trace time) and
        # the dispatcher routes each call to the argmin-cost variant.
        prefill_fn = lambda p, t: lm.prefill(p, cfg, t, max_seq=S)  # noqa: E731
        decode_fn = lambda p, t, c, ch: lm.decode_step(p, cfg, t, c, ch)  # noqa: E731
        if dispatcher is None:
            self._prefill = jax.jit(prefill_fn, static_argnums=())
            self._decode = jax.jit(decode_fn, donate_argnums=(3,))
        else:
            self._prefill_variants = {
                t.name: jax.jit(with_impl(t.impl, prefill_fn))
                for t in dispatcher.registry.targets()
            }
            self._decode_variants = {
                t.name: jax.jit(with_impl(t.impl, decode_fn), donate_argnums=(3,))
                for t in dispatcher.registry.targets()
            }
            self._canonical = {"serve_prefill": prefill_fn, "serve_decode": decode_fn}
            self._est_cache: dict = {}
            # per-backend tuned-config tags, resolved at first dispatch (the
            # drivers install repro.tune winners before the engine runs);
            # keys every recorded sample to the config actually executing
            self._configs: Optional[dict] = None
            self._prefill = lambda p, t: self._dispatched("serve_prefill", self._prefill_variants, p, t)
            self._decode = lambda p, t, c, ch: self._dispatched(
                "serve_decode", self._decode_variants, p, t, c, ch
            )

    def _dispatched(self, op: str, variants: dict, *args: Any) -> Any:
        """Route one compiled-surface call through the dispatcher.

        A-priori costs come from pricing the op's canonical (chunked)
        formulation per backend via the SDFG/roofline machinery, cached per
        argument signature; the dispatcher folds measured wall-times on top.
        The profile key is the token array's signature — params/caches are
        fixed per engine, and walking their pytree every tick would cost more
        than a decode step.
        """
        sig = signature(args[1])  # tokens: distinguishes prefill buckets
        if self._configs is None:
            self._configs = self.dispatcher.active_configs()
        if self.dispatcher.cfg.policy == "static":
            # pinned backend: the SDFG pricing would be computed only to be
            # logged — skip the extra trace per prompt-length bucket
            return self.dispatcher.dispatch(op, variants, *args, sig=sig,
                                            configs=self._configs)
        key = (op, sig)
        if key not in self._est_cache:
            canonical = with_impl("chunked", self._canonical[op])
            self._est_cache[key] = {
                t.name: estimate_callable(
                    canonical, *args, target=t, chip=self.dispatcher.chip
                ).seconds
                for t in self.dispatcher.registry.targets()
            }
        return self.dispatcher.dispatch(
            op, variants, *args, estimates=self._est_cache[key], sig=sig,
            configs=self._configs,
        )

    # -- client API ----------------------------------------------------------

    def submit(self, prompt: list[int], max_new: int = 32) -> int:
        req = Request(next(self._rid), list(prompt), max_new,
                      span=next_span_id(), parent=current_span())
        with self._queue_lock:
            self.queue.append(req)
            depth = len(self.queue)
        # span id pairs this spawn with the exit in _decode_tick even when
        # requests interleave (exporters and durations() pair by span first);
        # the parent captured at submit keeps the request under the driver's
        # run span even though its exit lands ticks later on another path
        self.log.record("spawn", "request", req.rid, span=req.span, parent=req.parent)
        if self._g_queue is not None:
            self._g_queue.set(depth)
        return req.rid

    def pending(self) -> int:
        """Requests not yet delivered (queued + occupying a decode slot)."""
        with self._queue_lock:
            return len(self.queue) + sum(r is not None for r in self.active)

    def run_to_completion(self) -> dict[int, list[int]]:
        results: dict[int, list[int]] = {}
        while self.queue or any(self.active):
            for r in self.step():
                results[r.rid] = r.out
        return results

    # -- engine tick ----------------------------------------------------------

    def step(self) -> list[Request]:
        """One tick: admit to free slots (prefill), then batched decode."""
        self._admit()
        finished = self._decode_tick()
        return finished

    def _admit(self) -> None:
        for slot in range(self.scfg.max_batch):
            if self.active[slot] is not None:
                continue
            with self._queue_lock:
                if not self.queue:
                    break
                req = self.queue.pop(0)
            req.slot = slot
            req.t_active = time.monotonic()
            # the prefill (and the dispatch decision it triggers) must nest
            # under the request span, whose bracket events live elsewhere;
            # the device annotation stamps the prefill span id onto every
            # profiler slice launched inside it
            with span_scope(req.span), \
                    self.log.lifecycle("prefill", req.rid) as psid, \
                    device_annotation(psid):
                tokens = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, new_caches = self._prefill(self.params, tokens)
                self.caches = jax.tree.map(
                    lambda c, n: c.at[slot].set(n[0].astype(c.dtype)),
                    self.caches,
                    new_caches,
                )
                first = self._sample(logits)[0]
                req.out.append(int(first))
                self.cur_pos[slot] = len(req.prompt)
            self.active[slot] = req
        if self._g_queue is not None:
            self._g_queue.set(len(self.queue))
            self._g_slots.set(sum(r is not None for r in self.active))

    def _decode_tick(self) -> list[Request]:
        live = [r for r in self.active if r is not None]
        if not live:
            return []
        B = self.scfg.max_batch
        tokens = np.zeros(B, np.int32)
        for r in live:
            tokens[r.slot] = r.out[-1]
        with self.log.lifecycle("decode_tick", len(live)) as dsid, \
                device_annotation(dsid):
            logits, self.caches = self._decode(
                self.params,
                jnp.asarray(tokens),
                jnp.asarray(self.cur_pos),
                self.caches,
            )
            nxt = np.asarray(self._sample(logits))
        finished: list[Request] = []
        for r in live:
            self.cur_pos[r.slot] += 1
            tok = int(nxt[r.slot])
            r.out.append(tok)
            hit_eos = tok == self.scfg.eos_id
            out_of_room = self.cur_pos[r.slot] + 1 >= self.scfg.max_seq
            if len(r.out) >= r.max_new or hit_eos or out_of_room:
                r.done = True
                self.active[r.slot] = None
                self.log.record("exit", "request", r.rid, span=r.span, parent=r.parent)
                finished.append(r)
        if finished and self._g_slots is not None:
            self._g_slots.set(sum(r is not None for r in self.active))
        return finished

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.scfg.temperature, axis=-1).astype(
            jnp.int32
        )
