"""Serving substrate: fixed-slot continuous-batching engine."""
