"""Static tracepoints — the USDT analogue.

USDT (User-Static-Defined-Tracing) probes are markers compiled into the
binary: a nop + ELF note when disabled, an eBPF-visible event when a consumer
enables the semaphore.  The TPU translation:

* ``tp.point(name, value)`` is written into the model/step source (static —
  requires a source marker, exactly like USDT's ``DTRACE_PROBE``).
* When tracing is **disabled** the marker is a Python no-op at trace time, so
  the jitted program is *byte-identical* to the uninstrumented one (tested in
  tests/test_tracepoints.py) — this is even stronger than USDT's nop-sled.
* When **enabled in "tape" mode** the values flow through a functional tape
  that becomes an extra output of the jitted step: the cost is a handful of
  device-side scalar ops ("user time", like USDT's inline fire).
* When **enabled in "callback" mode** the marker emits a host callback — that
  is the kernel-trap-style mechanism shared with uprobes, and shows up as
  host/"system" time in the overhead study (benchmarks/overhead_table1.py).

The tape is trace-time thread-local state, so ``collect`` must wrap the
function *inside* jit (or be jitted itself).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from functools import wraps
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core.events import GLOBAL_LOG, EventLog

_STATE = threading.local()


def _state():
    if not hasattr(_STATE, "mode"):
        _STATE.mode = None  # None | "tape" | "callback"
        _STATE.tape = None
        _STATE.log = GLOBAL_LOG
    return _STATE


def tracing_enabled() -> bool:
    return _state().mode is not None


@contextmanager
def enable(mode: str = "tape", log: EventLog | None = None) -> Iterator[None]:
    """Enable tracepoints for functions *traced* within this context.

    Like flipping the USDT semaphore: jit-compilation performed inside sees
    the markers; compilation outside does not.
    """
    if mode not in ("tape", "callback"):
        raise ValueError(f"mode must be 'tape' or 'callback', got {mode!r}")
    st = _state()
    prev = (st.mode, st.tape, st.log)
    st.mode = mode
    st.log = GLOBAL_LOG if log is None else log  # (EventLog is falsy when empty)
    try:
        yield
    finally:
        st.mode, st.tape, st.log = prev


def point(name: str, value: jax.typing.ArrayLike | None = None, agg: str = "last") -> None:
    """A static tracepoint.  No-op (compiled away) unless tracing is enabled.

    agg: how repeated fires of the same point combine on the tape —
    "last" | "sum" | "max" | "count".
    """
    st = _state()
    if st.mode is None:
        return
    if value is None:
        value = jnp.int32(1)
        agg = "count" if agg == "last" else agg
    value = jnp.asarray(value)
    if st.mode == "callback":
        log = st.log

        def _sink(v, _name=name, _log=log):
            _log.record("probe", _name, v)

        jax.debug.callback(_sink, value)
        return
    # tape mode
    if st.tape is None:
        # point() fired outside collect(): aggregate into a throwaway tape so
        # instrumented libraries still work when the caller forgot collect().
        st.tape = {}
    tape = st.tape
    scalar = value if value.ndim == 0 else _summarize(value)
    if name not in tape:
        tape[name] = (scalar, jnp.int32(1)) if agg != "count" else (jnp.int32(1), jnp.int32(1))
        return
    old, n = tape[name]
    if agg == "last":
        new = scalar
    elif agg == "sum":
        new = old + scalar
    elif agg == "max":
        new = jnp.maximum(old, scalar)
    elif agg == "count":
        new = old + jnp.int32(1)
    else:
        raise ValueError(f"unknown agg {agg!r}")
    tape[name] = (new, n + jnp.int32(1))


def _summarize(value: jax.Array) -> jax.Array:
    # Tracepoints carry scalars (USDT argument registers); reduce arrays.
    return jnp.mean(value.astype(jnp.float32))


def collect(fn: Callable) -> Callable:
    """Wrap ``fn`` so it returns ``(out, tape)`` when tape-tracing is enabled.

    The tape is a dict {point_name: (value, fire_count)} of device scalars —
    it is part of the jitted computation (functional, donate-safe).
    When tracing is disabled, returns ``(out, {})``.
    """

    @wraps(fn)
    def wrapped(*args: Any, **kwargs: Any):
        st = _state()
        if st.mode != "tape":
            return fn(*args, **kwargs), {}
        prev = st.tape
        st.tape = {}
        try:
            out = fn(*args, **kwargs)
            tape = st.tape
        finally:
            st.tape = prev
        return out, tape

    return wrapped
