"""Dynamic probes — the Uprobes analogue.

A uprobe attaches to an *unmodified* binary at a symbol/offset: the kernel
patches a trap into the text page, and the handler runs on every hit.  A TPU
program cannot be patched after compilation, so the TPU-idiomatic equivalent
attaches at the two places that still exist at runtime:

1. **Python symbol interception** (``attach`` / ``detach_all``): wrap a
   function *in its defining module* with an instrumented version — no source
   change, exactly like attaching to an ELF symbol.  Entry/exit host events
   are recorded, and (optionally) a host callback is inserted into the traced
   computation at the function's dataflow position (the "trap").
2. **jaxpr equation interception** (``inject_probes``): re-interpret the
   program's jaxpr, firing a probe at every equation matched by name-stack or
   primitive — the jaxpr plays the role of the symbol table.

Both mechanisms route events through host callbacks, which is why uprobe-mode
instrumentation shifts cost into *system/host* time in the overhead study —
mirroring the paper's Fig. 2 finding that "Uprobes incurs more system time".
"""
from __future__ import annotations

import dataclasses
import time
from functools import wraps
from typing import Any, Callable

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 moved core types under jax.extend
    from jax.extend import core as jcore
except ImportError:  # pragma: no cover
    from jax import core as jcore  # type: ignore

from repro.core.events import GLOBAL_LOG, EventLog

# --------------------------------------------------------------------------
# 1. Python-symbol interception
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Attachment:
    module: Any
    name: str
    original: Callable


class ProbeRegistry:
    """Attach/detach dynamic probes on module-level functions."""

    def __init__(self, log: EventLog | None = None) -> None:
        self.log = GLOBAL_LOG if log is None else log  # (EventLog is falsy when empty)
        self._attached: list[_Attachment] = []

    def attach(self, module: Any, name: str, *, tap_output: bool = True) -> None:
        """Instrument ``module.name`` in place.  No source change required."""
        original = getattr(module, name)
        if getattr(original, "__repro_probe__", False):
            return  # already attached
        log = self.log
        target = f"{getattr(module, '__name__', module)}.{name}"

        @wraps(original)
        def probed(*args: Any, **kwargs: Any):
            log.record("probe", target + ":enter", time.monotonic())
            out = original(*args, **kwargs)
            if tap_output:
                leaf = next(
                    (l for l in jax.tree.leaves(out) if hasattr(l, "dtype")), None
                )
                if leaf is not None and jnp.issubdtype(leaf.dtype, jnp.floating):
                    # register-sized probe argument (uprobes tap a register, not
                    # a reduction over the tensor): first element only.
                    summary = leaf.ravel()[0].astype(jnp.float32)

                    def _sink(v, _t=target, _log=log):
                        _log.record("probe", _t + ":ret", v)

                    jax.debug.callback(_sink, summary)
            log.record("probe", target + ":exit", time.monotonic())
            return out

        probed.__repro_probe__ = True  # type: ignore[attr-defined]
        setattr(module, name, probed)
        self._attached.append(_Attachment(module, name, original))

    def detach_all(self) -> None:
        while self._attached:
            a = self._attached.pop()
            setattr(a.module, a.name, a.original)

    def __enter__(self) -> "ProbeRegistry":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.detach_all()


# --------------------------------------------------------------------------
# 2. jaxpr equation interception
# --------------------------------------------------------------------------


def by_primitive(*names: str) -> Callable:
    names_set = set(names)

    def matcher(eqn) -> bool:
        return eqn.primitive.name in names_set

    return matcher


def by_scope(substring: str) -> Callable:
    """Match equations whose named_scope stack contains ``substring``."""

    def matcher(eqn) -> bool:
        try:
            return substring in str(eqn.source_info.name_stack)
        except AttributeError:
            return False

    return matcher


def _is_dropvar(v) -> bool:
    return type(v).__name__ == "DropVar"


def eval_jaxpr_with_probes(jaxpr, consts, *args, matcher: Callable, probe: Callable):
    """Interpret ``jaxpr``, firing ``probe(eqn, outvals)`` at matched equations.

    ``probe`` runs at trace time and may insert host callbacks / tape points.
    Higher-order equations (scan, pjit, cond) are bound opaquely — probes
    attach at the granularity the symbol table (name stack) exposes, like
    uprobes on inlined functions.
    """
    env: dict = {}

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    def write(v, val):
        if not _is_dropvar(v):
            env[v] = val

    for v, c in zip(jaxpr.constvars, consts):
        write(v, c)
    for v, a in zip(jaxpr.invars, args):
        write(v, a)
    for eqn in jaxpr.eqns:
        invals = [read(v) for v in eqn.invars]
        outvals = eqn.primitive.bind(*invals, **eqn.params)
        if not eqn.primitive.multiple_results:
            outvals = [outvals]
        if matcher(eqn):
            outvals = probe(eqn, outvals)
        for v, val in zip(eqn.outvars, outvals):
            write(v, val)
    return [read(v) for v in jaxpr.outvars]


def inject_probes(
    fn: Callable,
    matcher: Callable,
    *,
    mode: str = "callback",
    log: EventLog | None = None,
) -> Callable:
    """Return ``fn`` with probes attached at matched jaxpr equations.

    ``mode="callback"`` emits host events (uprobe trap semantics);
    ``mode="tap"`` returns collected {probe_name: scalar} as a second output
    (useful for deterministic tests).
    """
    log = GLOBAL_LOG if log is None else log

    def probed(*args: Any, **kwargs: Any):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        taps: dict[str, Any] = {}
        counter = [0]

        def probe(eqn, outvals):
            name = f"{eqn.primitive.name}#{counter[0]}"
            counter[0] += 1
            leaf = next(
                (
                    o
                    for o in outvals
                    if hasattr(o, "dtype") and jnp.issubdtype(o.dtype, jnp.floating)
                ),
                None,
            )
            if leaf is None:
                return outvals
            # register-sized argument, not a tensor reduction (uprobe semantics)
            summary = leaf.ravel()[0].astype(jnp.float32)
            if mode == "callback":

                def _sink(v, _name=name, _log=log):
                    _log.record("probe", _name, v)

                jax.debug.callback(_sink, summary)
            else:
                taps[name] = summary
            return outvals

        flat_args = jax.tree.leaves((args, kwargs))
        out = eval_jaxpr_with_probes(
            closed.jaxpr, closed.consts, *flat_args, matcher=matcher, probe=probe
        )
        out = jax.tree.unflatten(jax.tree.structure(jax.eval_shape(fn, *args, **kwargs)), out)
        if mode == "tap":
            return out, taps
        return out

    return probed
