"""Trip-count-aware analyzer for optimized (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` prices a while-loop body ONCE —
for a layer-scanned model that undercounts FLOPs, bytes and collective
traffic by the trip count (23× for gemma2, 1024× for a token-chunked loss).
This module re-derives per-device costs exactly the way the paper's
`linuxperf` derives block costs: walk the IR, price each op, and multiply
through the call graph:

  * **dot FLOPs** — parsed from operand/result shapes + contracting dims
    (exact, including SPMD redundancy and remat recompute);
  * **collective bytes** — ring-algorithm pricing per op (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
    group size from replica_groups;
  * **memory traffic** — Σ (operand + result bytes) over materialising ops:
    an un-fused upper bound on HBM traffic (fusion-internal ops are priced
    at their fusion boundary when XLA did fuse them);
  * **call-graph multipliers** — while bodies × ``known_trip_count`` (from
    backend_config), fusions/calls × 1, conditionals × max branch.

Used by repro.core.roofline for the §Roofline terms.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 0.125, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# type group is lazy `.*?`: tuple types embed /*index=N*/ comments, so a
# charclass can't cover them; the opcode is the first bare word followed by '('.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branches=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

# ops that don't move bytes at runtime (metadata / aliasing / control)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "reshape", "custom-call",
    "get-dimension-size", "domain", "opt-barrier",
}
# HBM-traffic anchors: ops that materialise buffers on TPU.  Elementwise ops
# NOT in this set are assumed fused into a neighbouring anchor by XLA-TPU
# (this CPU-backend HLO is barely fused, so pricing every op would model a
# no-fusion machine and overstate HBM traffic ~10×).  Exact for flops/
# collectives; the memory term is a fused-machine estimate.
_MEM_ANCHORS = {
    "dot", "convolution", "fusion", "copy", "transpose", "gather", "scatter",
    "scatter-add", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "pad", "reduce", "reduce-window", "sort", "select-and-scatter", "rev",
    "rng-bit-generator", "cholesky", "triangular-solve", "fft",
}
# ops whose real traffic is the slice they produce, not the array they index
_SLICING_OPS = {"dynamic-slice", "slice", "gather"}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _type_elems_bytes(type_str: str) -> tuple[float, float]:
    """Total (elements, bytes) over all array shapes in a type string."""
    elems = 0.0
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs (raw tail of the line)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=lambda: defaultdict(float))


class HloModuleAnalysis:
    def __init__(self, hlo_text: str, n_devices: int) -> None:
        self.n_devices = n_devices
        self.comps: dict[str, list[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._cost_cache: dict[str, CompCost] = {}

    # -- parsing -------------------------------------------------------------

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_START_RE.match(line.strip()) if "{" in line else None
                if m and "->" in line:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, type_str, opcode, rest = m.groups()
                self.comps[cur].append(Instr(name, type_str, opcode, rest))
        if self.entry is None and self.comps:
            self.entry = next(reversed(self.comps))

    # -- pricing -------------------------------------------------------------

    def _dot_flops(self, instr: Instr, shapes: dict[str, str]) -> float:
        out_elems, _ = _type_elems_bytes(instr.type_str)
        lhs_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        ops = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
        if not ops:
            return 0.0
        lhs_shape = _shape_dims(shapes.get(ops[0], ""))
        k = 1.0
        if lhs_m and lhs_shape:
            for d in (lhs_m.group(1).split(",") if lhs_m.group(1) else []):
                di = int(d)
                if di < len(lhs_shape):
                    k *= lhs_shape[di]
        return 2.0 * out_elems * k

    def _conv_flops(self, instr: Instr, shapes: dict[str, str]) -> float:
        out_elems, _ = _type_elems_bytes(instr.type_str)
        ops = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
        if len(ops) < 2:
            return 0.0
        rhs = _shape_dims(shapes.get(ops[1], ""))
        if not rhs:
            return 0.0
        return 2.0 * out_elems * float(np.prod(rhs[1:], dtype=np.float64))

    def _coll_bytes(self, instr: Instr, opcode: str) -> float:
        _, result_bytes = _type_elems_bytes(instr.type_str)
        n = self.n_devices
        m = _GROUPS_IOTA_RE.search(instr.rest)
        if m:
            n = max(2, int(m.group(2)))
        else:
            m = _GROUPS_LIST_RE.search(instr.rest)
            if m:
                n = max(2, len(m.group(1).split(",")))
        if opcode == "all-gather":
            return result_bytes * (n - 1) / n
        if opcode == "reduce-scatter":
            return result_bytes * (n - 1)
        if opcode == "all-reduce":
            return 2 * result_bytes * (n - 1) / n
        if opcode == "all-to-all":
            return result_bytes * (n - 1) / n
        return result_bytes  # collective-permute

    def comp_cost(self, comp: str) -> CompCost:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        cost = CompCost()
        self._cost_cache[comp] = cost  # break cycles defensively
        instrs = self.comps.get(comp, [])
        shapes = {i.name: i.type_str for i in instrs}
        for instr in instrs:
            op = instr.opcode
            base = op.replace("-start", "")
            # nested computations
            if op == "while":
                body = _CALLED_RE.search(instr.rest)
                trips = 1
                tm = _TRIP_RE.search(instr.rest)
                if tm:
                    trips = int(tm.group(1))
                if body:
                    sub = self.comp_cost(body.group(1))
                    cost.flops += sub.flops * trips
                    cost.mem_bytes += sub.mem_bytes * trips
                    cost.coll_bytes += sub.coll_bytes * trips
                    for k, v in sub.coll_by_op.items():
                        cost.coll_by_op[k] += v * trips
                continue
            if op == "conditional":
                bm = _BRANCHES_RE.search(instr.rest)
                if bm:
                    subs = [
                        self.comp_cost(b.strip().lstrip("%"))
                        for b in bm.group(1).split(",") if b.strip()
                    ]
                    if subs:
                        best = max(subs, key=lambda s: s.flops + s.mem_bytes)
                        cost.flops += best.flops
                        cost.mem_bytes += best.mem_bytes
                        cost.coll_bytes += best.coll_bytes
                        for k, v in best.coll_by_op.items():
                            cost.coll_by_op[k] += v
                continue
            if op in ("fusion", "call", "async-start"):
                cm = _CALLED_RE.search(instr.rest)
                _, out_b = _type_elems_bytes(instr.type_str)
                if cm:
                    sub = self.comp_cost(cm.group(1))
                    cost.flops += sub.flops
                    cost.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_op.items():
                        cost.coll_by_op[k] += v
                    # memory = slice-aware reads + alias-aware writes: a
                    # fusion parameter consumed only by slice/gather ops reads
                    # just the slices; a dynamic-update-slice root writes the
                    # update, not the (aliased, in-place) full buffer.
                    cost.mem_bytes += self._fusion_mem_bytes(
                        cm.group(1), instr, shapes
                    )
                else:
                    cost.mem_bytes += out_b + self._operand_bytes(instr, shapes)
                continue
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                b = self._coll_bytes(instr, base)
                cost.coll_bytes += b
                cost.coll_by_op[base] += b
                # collectives also touch HBM on both ends
                _, out_b = _type_elems_bytes(instr.type_str)
                cost.mem_bytes += out_b + self._operand_bytes(instr, shapes)
                continue
            if op == "dot":
                cost.flops += self._dot_flops(instr, shapes)
            elif op == "convolution":
                cost.flops += self._conv_flops(instr, shapes)
            elif op in ("reduce", "reduce-window", "map", "sort", "scatter", "select-and-scatter"):
                in_e, _ = (0.0, 0.0)
                for o in _OPERAND_RE.findall(instr.rest.split(")", 1)[0]):
                    e, _b = _type_elems_bytes(shapes.get(o, ""))
                    in_e += e
                cost.flops += in_e
            elif op not in _FREE_OPS:
                out_e, _ = _type_elems_bytes(instr.type_str)
                cost.flops += out_e  # ~1 flop/elem elementwise
            if op not in _MEM_ANCHORS:
                continue
            _, out_b = _type_elems_bytes(instr.type_str)
            if op in _SLICING_OPS:
                cost.mem_bytes += 2 * out_b  # read slice + write result
            elif op in ("dynamic-update-slice", "scatter", "scatter-add"):
                ops_names = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
                upd = ops_names[1] if len(ops_names) > 1 else None
                _, upd_b = _type_elems_bytes(shapes.get(upd, "")) if upd else (0, out_b)
                cost.mem_bytes += 2 * upd_b  # read update + in-place write
            else:
                cost.mem_bytes += out_b + self._operand_bytes(instr, shapes)
        return cost

    def _fusion_mem_bytes(self, comp: str, call_instr: Instr, caller_shapes) -> float:
        """HBM traffic of one fusion call: slice-aware reads + alias-aware
        writes.

        * a parameter consumed only by slice/gather ops reads the slices;
        * a parameter consumed only as the target (operand 0) of
          dynamic-update-slice ops is an in-place accumulator: read ≈ 0
          (the update is priced as the write);
        * a dynamic-update-slice (possibly behind bitcast/reshape) at the
          root writes its update operand, not the full aliased buffer.
        """
        instrs = self.comps.get(comp)
        if not instrs:
            return (
                _type_elems_bytes(call_instr.type_str)[1]
                + self._operand_bytes(call_instr, caller_shapes)
            )
        operand_names = _OPERAND_RE.findall(call_instr.rest.split(")", 1)[0])
        shapes = {i.name: i.type_str for i in instrs}
        by_name = {i.name: i for i in instrs}
        params: dict[int, str] = {}
        for i in instrs:
            if i.opcode == "parameter":
                m = re.match(r"(\d+)", i.rest)
                if m:
                    params[int(m.group(1))] = i.name

        def first_operand(i: Instr) -> Optional[str]:
            ops = _OPERAND_RE.findall(i.rest.split(")", 1)[0])
            return ops[0] if ops else None

        _TRANSPARENT = ("convert", "bitcast", "reshape", "copy")

        def real_consumers(name: str) -> list[tuple[Instr, str]]:
            """Consumers of `name`, looking through dtype/layout-only ops
            (an XLA-CPU quirk wraps DUS accumulators in bf16↔f32 converts)."""
            out: list[tuple[Instr, str]] = []
            stack, visited = [name], set()
            while stack:
                nm = stack.pop()
                if nm in visited:
                    continue
                visited.add(nm)
                for i in instrs:
                    if nm in _OPERAND_RE.findall(i.rest.split(")", 1)[0]):
                        if i.opcode in _TRANSPARENT:
                            stack.append(i.name)
                        else:
                            out.append((i, nm))
            return out

        # reads
        read = 0.0
        seen: set[str] = set()
        for idx, op_name in enumerate(operand_names):
            if op_name in seen:
                continue
            seen.add(op_name)
            full = _type_elems_bytes(caller_shapes.get(op_name, ""))[1]
            pname = params.get(idx)
            if pname is None:
                read += full
                continue
            consumers = real_consumers(pname)
            if consumers and all(
                c.opcode in _SLICING_OPS and first_operand(c) == via
                for c, via in consumers
            ):
                read += sum(_type_elems_bytes(c.type_str)[1] for c, _ in consumers)
            elif consumers and all(
                c.opcode == "dynamic-update-slice" and first_operand(c) == via
                for c, via in consumers
            ):
                pass  # in-place accumulator target: aliased, no read
            else:
                read += full

        # writes: resolve the root chain; DUS roots write the update only
        def resolve(name: str, depth: int = 0) -> Optional[Instr]:
            i = by_name.get(name)
            while i is not None and depth < 8 and i.opcode in (
                "bitcast", "reshape", "copy", "convert"
            ):
                nxt = first_operand(i)
                i = by_name.get(nxt) if nxt else None
                depth += 1
            return i

        root = instrs[-1]
        roots = [root]
        if root.opcode == "tuple":
            roots = [
                r for r in (
                    resolve(n) for n in _OPERAND_RE.findall(root.rest.split(")", 1)[0])
                ) if r is not None
            ]
        else:
            r = resolve(root.name)
            roots = [r] if r is not None else [root]
        write = 0.0
        for r in roots:
            if r.opcode == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(r.rest.split(")", 1)[0])
                upd = ops[1] if len(ops) > 1 else None
                write += _type_elems_bytes(shapes.get(upd, ""))[1] if upd else 0.0
            elif r.opcode == "parameter":
                pass  # pass-through, aliased
            else:
                write += _type_elems_bytes(r.type_str)[1]
        return read + write

    def _operand_bytes(self, instr: Instr, shapes: dict[str, str]) -> float:
        total = 0.0
        seen = set()
        for o in _OPERAND_RE.findall(instr.rest.split(")", 1)[0]):
            if o in seen or o not in shapes:
                continue
            seen.add(o)
            _, b = _type_elems_bytes(shapes[o])
            total += b
        return total

    def entry_cost(self) -> CompCost:
        # Count only computations reachable from ENTRY (fused/called comps are
        # priced through their call sites, never independently).
        return self.comp_cost(self.entry)


def analyze_hlo_text(hlo_text: str, n_devices: int) -> dict:
    """Per-device costs with loop multipliers.  Returns a flat record."""
    mod = HloModuleAnalysis(hlo_text, n_devices)
    cost = mod.entry_cost()
    return {
        "flops": cost.flops,
        "mem_bytes": cost.mem_bytes,
        "coll_bytes": cost.coll_bytes,
        "coll_by_op": dict(cost.coll_by_op),
    }
