"""Stateful-dataflow-multigraph IR extraction + backend assignment (Fig. 1).

Adaptyst represents a program as an SDFG whose nodes are each assigned to a
*backend module* modelling one system component.  Here the program IR is the
**jaxpr** (JAX's dataflow multigraph) and the components are the TPU
sub-units:

    MXU   systolic matmul units        (dot_general, conv)
    VPU   vector units                 (elementwise, reductions, RNG)
    HBM   memory movers                (gather/scatter/slice/transpose/copy…)
    ICI   interconnect                 (explicit collectives: psum, all_gather…)
    HOST  host link                    (callbacks, infeed — the "system" side)

Every equation becomes a node with FLOP and byte estimates; nodes group into
*regions* by named_scope (the paper's "arbitrarily-sized code blocks"), and
each region gets a roofline *match*: the component class that bounds it
(compute- vs memory-bound via arithmetic intensity against the chip's machine
balance — the cache-aware-roofline decision, one level up the hierarchy).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.hw.specs import ChipSpec, default_chip

MXU, VPU, HBM, ICI, HOST = "MXU", "VPU", "HBM", "ICI", "HOST"

_MXU_PRIMS = {"dot_general", "conv_general_dilated", "ragged_dot"}
_ICI_PRIMS = {
    "psum", "all_gather", "all_to_all", "ppermute", "psum_scatter", "pmax", "pmin",
    "reduce_scatter", "collective_permute",
}
_HOST_PRIMS = {"debug_callback", "io_callback", "pure_callback", "infeed", "outfeed"}
_HBM_PRIMS = {
    "gather", "scatter", "scatter_add", "dynamic_slice", "dynamic_update_slice",
    "slice", "concatenate", "transpose", "reshape", "broadcast_in_dim", "copy",
    "pad", "rev", "squeeze", "iota", "convert_element_type", "bitcast_convert_type",
    "select_n", "take",
}


def _aval_bytes(v) -> int:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(aval.dtype).itemsize


def _aval_size(v) -> int:
    aval = v.aval
    return int(np.prod(aval.shape, dtype=np.int64)) if hasattr(aval, "shape") else 0


@dataclasses.dataclass
class Node:
    id: int
    primitive: str
    backend: str
    flops: float
    bytes: float
    region: str  # innermost named_scope path
    params: dict = dataclasses.field(default_factory=dict, repr=False)


@dataclasses.dataclass
class Edge:
    src: int
    dst: int
    bytes: float


@dataclasses.dataclass
class Region:
    """A named_scope code block with aggregate roofline terms."""

    name: str
    flops: float = 0.0
    bytes: float = 0.0
    nodes: int = 0
    backends: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)

    def match(self, chip: Optional[ChipSpec] = None) -> str:
        """The Adaptyst 'match': which component bounds this region."""
        chip = chip or default_chip()
        if self.backends.get(HOST):
            return HOST
        if self.backends.get(ICI, 0.0) > 0.5 * self.bytes:
            return ICI
        balance = chip.peak_flops_bf16 / chip.hbm_bw  # FLOP/byte machine balance
        if self.intensity() >= balance and self.backends.get(MXU):
            return MXU
        if self.backends.get(MXU, 0.0) > 0.5 * self.flops:
            # matmul-heavy but HBM-bound at this size
            return HBM
        return VPU if self.flops > self.bytes else HBM


def classify(prim_name: str) -> str:
    if prim_name in _MXU_PRIMS:
        return MXU
    if prim_name in _ICI_PRIMS:
        return ICI
    if prim_name in _HOST_PRIMS:
        return HOST
    if prim_name in _HBM_PRIMS:
        return HBM
    return VPU


def _eqn_flops(eqn) -> float:
    name = eqn.primitive.name
    out_size = sum(_aval_size(v) for v in eqn.outvars)
    if name == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, _rc), (lb, _rb) = dims
        lhs = eqn.invars[0].aval
        k = int(np.prod([lhs.shape[i] for i in lc], dtype=np.int64))
        return 2.0 * out_size * k
    if name == "conv_general_dilated":
        rhs = eqn.invars[1].aval
        return 2.0 * out_size * int(np.prod(rhs.shape[1:], dtype=np.int64))
    if classify(name) in (HBM, HOST, ICI):
        return 0.0
    if name.startswith("reduce_") or name in ("argmax", "argmin", "cumsum", "cumprod",
                                              "cummax", "cummin", "sort"):
        # reductions/scans: ~1 flop per input element
        return float(sum(_aval_size(v) for v in eqn.invars if hasattr(v, "aval")))
    # elementwise: ~1 flop per output element
    return float(out_size)


def _eqn_bytes(eqn) -> float:
    ins = sum(_aval_bytes(v) for v in eqn.invars if hasattr(v, "aval"))
    outs = sum(_aval_bytes(v) for v in eqn.outvars)
    return float(ins + outs)


@dataclasses.dataclass
class SDFG:
    nodes: list[Node]
    edges: list[Edge]

    def regions(self) -> dict[str, Region]:
        regs: dict[str, Region] = {}
        for n in self.nodes:
            r = regs.setdefault(n.region, Region(n.region))
            r.flops += n.flops
            r.bytes += n.bytes
            r.nodes += 1
            r.backends[n.backend] += n.flops if n.backend == MXU else n.bytes
        return regs

    def summary(self) -> dict[str, dict[str, float]]:
        """Aggregate flops/bytes/node-count per backend component."""
        out: dict[str, dict[str, float]] = {
            b: {"flops": 0.0, "bytes": 0.0, "nodes": 0} for b in (MXU, VPU, HBM, ICI, HOST)
        }
        for n in self.nodes:
            out[n.backend]["flops"] += n.flops
            out[n.backend]["bytes"] += n.bytes
            out[n.backend]["nodes"] += 1
        return out

    def to_dot(self, max_nodes: int = 200) -> str:
        colors = {MXU: "tomato", VPU: "gold", HBM: "skyblue", ICI: "violet", HOST: "gray"}
        lines = ["digraph sdfg {", "  rankdir=TB;"]
        for n in self.nodes[:max_nodes]:
            lines.append(
                f'  n{n.id} [label="{n.primitive}\\n{n.backend}" '
                f'style=filled fillcolor={colors[n.backend]}];'
            )
        shown = {n.id for n in self.nodes[:max_nodes]}
        for e in self.edges:
            if e.src in shown and e.dst in shown:
                lines.append(f"  n{e.src} -> n{e.dst};")
        lines.append("}")
        return "\n".join(lines)


def extract(fn: Callable, *args, flatten_control_flow: bool = True, **kwargs) -> SDFG:
    """Trace ``fn`` and build its SDFG.

    Control-flow primitives (scan/while/cond/pjit/remat) are descended into
    when ``flatten_control_flow`` — body nodes appear once with a trip-count
    multiplier on their costs (scan length), mirroring how Adaptyst models a
    loop as its block × iterations.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    nodes: list[Node] = []
    edges: list[Edge] = []
    producer: dict[Any, int] = {}
    counter = [0]

    def scope_of(eqn) -> str:
        try:
            s = str(eqn.source_info.name_stack)
            return s if s else "<toplevel>"
        except AttributeError:
            return "<toplevel>"

    def visit(jaxpr, mult: float, region_prefix: str):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            inner = None
            inner_mult = mult
            if flatten_control_flow:
                if name == "scan":
                    inner = eqn.params["jaxpr"].jaxpr
                    inner_mult = mult * eqn.params["length"]
                elif name == "while":
                    inner = eqn.params["body_jaxpr"].jaxpr  # trip count unknown: ×1
                elif name == "cond":
                    inner = eqn.params["branches"][0].jaxpr
                elif name in ("pjit", "jit", "remat2", "checkpoint", "custom_vjp_call",
                              "custom_jvp_call", "custom_vjp_call_jaxpr"):
                    p = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                    if p is not None:
                        inner = p.jaxpr if hasattr(p, "jaxpr") else p
            if inner is not None:
                visit(inner, inner_mult, region_prefix)
                continue
            nid = counter[0]
            counter[0] += 1
            region = region_prefix + scope_of(eqn)
            nodes.append(
                Node(
                    id=nid,
                    primitive=name,
                    backend=classify(name),
                    flops=_eqn_flops(eqn) * mult,
                    bytes=_eqn_bytes(eqn) * mult,
                    region=region,
                )
            )
            for v in eqn.invars:
                if type(v).__name__ == "Literal":
                    continue
                if v in producer:
                    edges.append(Edge(producer[v], nid, _aval_bytes(v)))
            for v in eqn.outvars:
                producer[v] = nid

    visit(closed.jaxpr, 1.0, "")
    return SDFG(nodes, edges)
