"""Instrumentation-overhead harness — the hyperfine methodology (Table I/Fig 2).

Reproduces the paper's measurement protocol exactly: N warm-up runs, M
measured runs, mean/stddev/median/min/max wall-time, plus the system-vs-user
CPU-time breakdown (Fig. 2) from getrusage — on the CPU backend the jitted
computation runs in-process, so *user* time is device-execute work and
*system* time captures the kernel-side cost of host traps (callbacks,
thread synchronisation), mirroring how uprobes' kernel trampolines showed up
as system time in the paper.
"""
from __future__ import annotations

import dataclasses
import math
import resource
import time
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class TimingStats:
    label: str
    runs: int
    mean_ms: float
    stddev_ms: float
    median_ms: float
    min_ms: float
    max_ms: float
    user_s: float  # Σ user CPU time over the measured phase
    system_s: float  # Σ system CPU time over the measured phase

    def row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def overhead_vs(self, base: "TimingStats") -> float:
        """Relative mean-walltime overhead (the paper's +5.1% / +4.8%)."""
        return self.mean_ms / base.mean_ms - 1.0


def stats_from_samples(
    label: str,
    samples_ms: list[float],
    *,
    user_s: float = 0.0,
    system_s: float = 0.0,
) -> TimingStats:
    """Fold raw wall-time samples (ms) into a :class:`TimingStats` row.

    The summary half of the hyperfine protocol, exposed on its own so other
    measurement loops (the adaptive tracing controller's no-op calibration,
    the record-path benchmark) report in the same Table-I vocabulary."""
    s = sorted(samples_ms)
    n = len(s)
    if n == 0:
        raise ValueError("stats_from_samples needs at least one sample")
    mean = sum(s) / n
    var = sum((x - mean) ** 2 for x in s) / max(n - 1, 1)
    median = s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])
    return TimingStats(
        label=label,
        runs=n,
        mean_ms=mean,
        stddev_ms=math.sqrt(var),
        median_ms=median,
        min_ms=s[0],
        max_ms=s[-1],
        user_s=user_s,
        system_s=system_s,
    )


def hyperfine(
    fn: Callable[[], Any],
    *,
    label: str = "",
    warmup: int = 100,
    runs: int = 1000,
) -> TimingStats:
    """Benchmark ``fn`` (hyperfine protocol: 100 warm-up + 1000 measured).

    ``fn`` must be self-contained (compiled function + bound inputs) and is
    blocked to completion each run.
    """

    def once():
        out = fn()
        jax.block_until_ready(out)

    for _ in range(warmup):
        once()
    samples: list[float] = []
    ru0 = resource.getrusage(resource.RUSAGE_SELF)
    for _ in range(runs):
        t0 = time.perf_counter()
        once()
        samples.append((time.perf_counter() - t0) * 1e3)
    ru1 = resource.getrusage(resource.RUSAGE_SELF)
    return stats_from_samples(
        label,
        samples,
        user_s=ru1.ru_utime - ru0.ru_utime,
        system_s=ru1.ru_stime - ru0.ru_stime,
    )


def table(rows: list[TimingStats], baseline: str = "baseline") -> str:
    """Render the Table-I-style report (+ Fig-2 sys/user columns)."""
    base = next((r for r in rows if r.label == baseline), rows[0])
    header = (
        f"{'type':<12} {'mean(ms)':>9} {'stddev':>8} {'median':>8} {'min':>8} "
        f"{'max':>8} {'overhead':>9} {'user(s)':>8} {'sys(s)':>8}"
    )
    lines = [header, "-" * len(header)]
    for r in rows:
        ov = r.overhead_vs(base)
        lines.append(
            f"{r.label:<12} {r.mean_ms:>9.3f} {r.stddev_ms:>8.3f} {r.median_ms:>8.3f} "
            f"{r.min_ms:>8.3f} {r.max_ms:>8.3f} {ov:>8.1%} {r.user_s:>8.2f} {r.system_s:>8.2f}"
        )
    return "\n".join(lines)
