"""Three-term roofline engine (the `linuxperf` cache-aware-roofline analogue).

For a compiled (SPMD-partitioned) step this derives, per chip:

    compute term    = HLO_FLOPs      / peak_FLOP/s          [seconds]
    memory term     = HLO_bytes      / HBM_bandwidth        [seconds]
    collective term = collective_bytes / ICI_link_bandwidth [seconds]

Sources: ``compiled.cost_analysis()`` provides FLOPs and bytes accessed of the
*per-device* program (GSPMD compiles one partitioned module).  Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and price
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute with ring-algorithm byte counts (group size parsed from
replica_groups).

The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures how much
compiled compute is "useful" (catches remat and dispatch-einsum waste).
"""
from __future__ import annotations

import math
import re
from typing import Any, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.hw.specs import ChipSpec, default_chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> dict[str, float]:
    """Per-device bytes moved over the interconnect, ring-algorithm pricing.

    For a full tensor of S bytes over an n-member group:
      all-gather        S·(n−1)/n     (result = S)
      reduce-scatter    S·(n−1)/n     (result = S/n ⇒ result·(n−1))
      all-reduce        2·S·(n−1)/n   (RS + AG)
      all-to-all        S·(n−1)/n
      collective-permute S            (result = S)
    """
    per_op: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_types, single_type, op = m.groups()
        result_bytes = _shape_bytes(tuple_types if tuple_types else single_type)
        n = max(2, _group_size(line, n_devices))
        if op == "all-gather":
            b = result_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            b = result_bytes * (n - 1)
        elif op == "all-reduce":
            b = 2 * result_bytes * (n - 1) / n
        elif op == "all-to-all":
            b = result_bytes * (n - 1) / n
        else:  # collective-permute
            b = result_bytes
        per_op[op] = per_op.get(op, 0.0) + b
    per_op["total"] = sum(per_op.values())
    return per_op


def analyze_compiled(lowered, compiled, mesh, chip: Optional[ChipSpec] = None) -> dict:
    """Roofline record for one compiled step (per-chip terms, seconds).

    Costs come from repro.core.hloanalysis — a trip-count-aware walk of the
    optimized per-device HLO (XLA's own cost_analysis prices while bodies
    once, undercounting layer-scanned models by the trip count).
    """
    from repro.core.hloanalysis import analyze_hlo_text

    chip = chip or default_chip()
    n_dev = mesh.devices.size
    hlo = compiled.as_text()
    costs = analyze_hlo_text(hlo, n_dev)
    flops = costs["flops"]
    bytes_accessed = costs["mem_bytes"]
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0] if xla_cost else {}

    t_compute = flops / chip.peak_flops_bf16
    t_memory = bytes_accessed / chip.hbm_bw
    t_collective = costs["coll_bytes"] / chip.ici_link_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    bottleneck = max(terms, key=terms.get)

    rec = {
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_accessed,
        "collective_bytes_per_dev": costs["coll_bytes"],
        "collective_breakdown": {k: round(v) for k, v in costs["coll_by_op"].items()},
        "xla_cost_flops_per_dev": float(xla_cost.get("flops", 0.0)),  # loop bodies ×1
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": bottleneck,
        "step_time_bound_s": max(terms.values()),
    }
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            }
    except Exception:
        pass
    rec["memory_analysis"] = mem
    return rec


# ---------------------------------------------------------------------------
# VMEM footprint (design-space feasibility for repro.tune)
# ---------------------------------------------------------------------------

# Mosaic double-buffers the HBM→VMEM pipeline: while one input tile is being
# consumed the next is in flight, so a tile's VMEM cost is ~2x its size.
PIPELINE_BUFFERS = 2
# Fraction of VMEM a single kernel may claim for its tiles + scratch; the
# rest is headroom for the compiler's own temporaries and constants.
VMEM_BUDGET_FRACTION = 0.8


def vmem_footprint_bytes(
    tiles: Any, scratch: Any = (), *, buffers: int = PIPELINE_BUFFERS
) -> int:
    """VMEM bytes a kernel config point needs resident at once.

    ``tiles``/``scratch`` are iterables of ``(shape, dtype_bytes)``; input and
    output tiles are multiplied by ``buffers`` (pipeline double-buffering),
    scratch is single-buffered (it persists across grid steps).  This is the
    feasibility half of the roofline model: a config whose tiles don't fit
    never reaches the timing sweep (see :mod:`repro.tune.space`).
    """
    def _bytes(rows: Any) -> int:
        total = 0
        for shape, dtype_bytes in rows:
            n = 1
            for d in shape:
                n *= int(d)
            total += n * int(dtype_bytes)
        return total

    return buffers * _bytes(tiles) + _bytes(scratch)


def fits_vmem(
    footprint_bytes: float,
    chip: Optional[ChipSpec] = None,
    *,
    fraction: float = VMEM_BUDGET_FRACTION,
) -> bool:
    """Whether a config point's working set fits the chip's VMEM budget."""
    chip = chip or default_chip()
    return footprint_bytes <= chip.vmem_bytes * fraction


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful work" yardstick)
# ---------------------------------------------------------------------------


def count_params(abs_params: Any, *, active: bool, cfg: ModelConfig) -> int:
    """Param count; ``active`` scales expert tensors by (top_k / n_experts)."""
    import jax

    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
        keys = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        if active and cfg.moe and "/ffn/w" in keys and leaf.ndim >= 3 and leaf.shape[-3] == cfg.moe.n_experts:
            n = n * cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return int(total)


def model_flops(cfg: ModelConfig, shape: ShapeConfig, abs_params: Any) -> float:
    """Analytic useful FLOPs per step: 6·N_active·T (+backward-free for serve)
    + attention quadratic term + unembed matmul.  Embedding lookup excluded.
    """
    n_active = count_params(abs_params, active=True, cfg=cfg)
    n_embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tied_embeddings else 2)
    n_matmul = max(n_active - n_embed, 0)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        T = B * S
        base = 6.0 * n_matmul * T + 3 * 2.0 * T * cfg.d_model * cfg.vocab_size
        attn_mult = 3  # fwd + bwd
        tokens_sq = _attn_token_pairs(cfg, S, causal=True) * B
    elif shape.kind == "prefill":
        T = B * S
        base = 2.0 * n_matmul * T + 2.0 * T * cfg.d_model * cfg.vocab_size
        attn_mult = 1
        tokens_sq = _attn_token_pairs(cfg, S, causal=True) * B
    else:  # decode: one token vs cache of S
        T = B
        base = 2.0 * n_matmul * T + 2.0 * T * cfg.d_model * cfg.vocab_size
        attn_mult = 1
        tokens_sq = _attn_token_pairs(cfg, S, causal=False, decode=True) * B
    attn = attn_mult * 4.0 * cfg.n_heads * cfg.head_dim * tokens_sq
    return base + attn


def _attn_token_pairs(
    cfg: ModelConfig, S: int, *, causal: bool, decode: bool = False
) -> float:
    """Σ over attention layers of (q, kv) pair count."""
    pairs = 0.0
    for i in range(cfg.n_layers):
        spec = cfg.layer_spec(i)
        if spec.mixer not in ("ga", "swa"):
            continue
        w = cfg.sliding_window if spec.mixer == "swa" else None
        if decode:
            pairs += min(w, S) if w else S
        elif w and w < S:
            pairs += S * w - w * (w - 1) / 2  # causal within window
        else:
            pairs += S * (S + 1) / 2 if causal else S * S
    return pairs
