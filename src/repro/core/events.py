"""Lifecycle event tracing — the thread/process spawn-exit analogue.

Adaptyst's third profiling type is "tracing of spawning and exiting
threads/processes of a given program".  The unit of concurrency in this
framework is not an OS thread: it is the training step, the microbatch, the
checkpoint writer and the serving request.  This module records their
spawn/exit events on the host with monotonic timestamps, and is the sink for
uprobe-style host callbacks (repro.core.uprobes).

Two properties mirror the kernel-side perf machinery:

* **Bounded storage** — an ``EventLog(maxlen=N)`` is a ring: once full, the
  oldest events are overwritten and counted in :attr:`EventLog.dropped`,
  exactly like a perf/eBPF ring buffer under backpressure.  The default is
  unbounded for short-lived tools; long-running servers should bound it
  (see :class:`repro.trace.collector.TraceCollector`).
* **Span identity** — concurrent units interleave (request A's exit can land
  between request B's spawn and exit), so spawn/exit pairing cannot be a
  stack.  ``lifecycle()`` allocates a process-unique span id recorded on both
  bracket events; :meth:`EventLog.durations` pairs by span id, then by
  payload identity, and only falls back to stack order for legacy events.
* **Span hierarchy** — every event carries a ``parent`` span id, defaulted
  from a :mod:`contextvars`-based current-span stack that ``lifecycle()``
  pushes and pops.  contextvars are per-thread and copied into asyncio
  tasks, so concurrent serving requests nest under their own ancestors
  instead of whichever span another thread happens to have open.  The
  resulting parent links are what :func:`repro.trace.collector.span_tree`
  folds into host/device timeline trees.
"""
from __future__ import annotations

import contextvars
import dataclasses
import itertools
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Iterator, Optional

_SPAN_IDS = itertools.count(1)  # process-unique span ids (0 = "no span")

# The current-span stack: a tuple (immutable, so set/reset is race-free) of
# open span ids for this thread/task.  Events default their ``parent`` to the
# top of this stack.
_SPAN_STACK: contextvars.ContextVar[tuple[int, ...]] = contextvars.ContextVar(
    "repro_span_stack", default=()
)


def next_span_id() -> int:
    return next(_SPAN_IDS)


def current_span() -> int:
    """The innermost open span in this thread/task's context (0 = none)."""
    stack = _SPAN_STACK.get()
    return stack[-1] if stack else 0


@contextmanager
def span_scope(span: int) -> Iterator[int]:
    """Make ``span`` the current parent for events recorded in this context.

    Used when a span's bracket events are recorded apart from the work they
    enclose (e.g. a serving request spawns at submit and exits ticks later,
    but its prefill must still nest under it).
    """
    token = _SPAN_STACK.set(_SPAN_STACK.get() + (span,))
    try:
        yield span
    finally:
        _SPAN_STACK.reset(token)


# HTTP header carrying a serialized SpanContext across process boundaries
# (the W3C traceparent analogue for this framework's span-id space).
TRACEPARENT_HEADER = "X-Repro-Traceparent"


@dataclasses.dataclass(frozen=True)
class SpanContext:
    """Serializable cross-process span reference.

    Span ids are process-unique, not globally unique, so a remote reference
    needs three parts: a ``trace`` id naming the end-to-end request, the
    parent ``span`` id in the *origin* process's id space, and the ``origin``
    process identity (``name:pid``) that id space belongs to.  ``sent_unix``
    is the injector's wall clock at send time — one half of the handshake
    pair :mod:`repro.trace.stitch` uses to estimate cross-host clock skew.

    The wire format is a single header value (``repro1;trace=..;span=..;
    origin=..;sent=..``); :meth:`extract` tolerates missing or garbage
    values by returning ``None`` — propagation is best-effort and must
    never fail a request.
    """

    trace: str
    span: int
    origin: str
    sent_unix: float = 0.0

    def inject(self) -> str:
        """The ``X-Repro-Traceparent`` header value for this context."""
        origin = self.origin.replace(";", "_").replace("=", "_")
        return (f"repro1;trace={self.trace};span={self.span};"
                f"origin={origin};sent={self.sent_unix!r}")

    @classmethod
    def extract(cls, value: Optional[str]) -> Optional["SpanContext"]:
        """Parse a header value; ``None`` on anything malformed."""
        if not value or not value.startswith("repro1;"):
            return None
        fields: dict[str, str] = {}
        for part in value.split(";")[1:]:
            k, sep, v = part.partition("=")
            if sep:
                fields[k.strip()] = v.strip()
        try:
            return cls(trace=fields["trace"], span=int(fields["span"]),
                       origin=fields["origin"],
                       sent_unix=float(fields.get("sent", 0.0)))
        except (KeyError, ValueError):
            return None

    def to_payload(self) -> dict[str, Any]:
        """The ``remote`` payload convention: embedding this dict under the
        ``"remote"`` key of a spawn payload marks the span as remotely
        parented; :func:`repro.trace.collector.resolve_spans` lifts it onto
        ``Span.remote`` and :mod:`repro.trace.stitch` re-links it to the
        origin process's span once both sessions are merged."""
        return {"trace": self.trace, "span": self.span, "origin": self.origin}


def remote_ref(payload: Any) -> Optional[dict[str, Any]]:
    """The remote-parent reference embedded in a span payload, if any."""
    if isinstance(payload, dict):
        ref = payload.get("remote")
        if isinstance(ref, dict) and isinstance(ref.get("span"), int) \
                and ref.get("origin"):
            return ref
    return None


@dataclasses.dataclass(frozen=True)
class Event:
    t: float  # monotonic seconds
    kind: str  # spawn | exit | probe | mark | dispatch | route | straggler | device
    name: str  # e.g. "step", "microbatch", "request", probe target
    payload: Any = None
    span: int = 0  # pairs spawn/exit of one unit; 0 = unspanned (legacy)
    parent: int = 0  # enclosing span id (0 = root); defaults from span_scope


def _pair_key(e: Event) -> Optional[Any]:
    """Pairing key for a spawn/exit event: span id, else hashable payload."""
    if e.span:
        return ("span", e.span)
    try:
        hash(e.payload)
    except TypeError:
        return None
    if e.payload is None:
        return None
    return ("payload", e.payload)


class EventLog:
    """Thread-safe append-only event log (the eBPF ring-buffer analogue).

    ``maxlen`` turns it into a bounded ring: the newest ``maxlen`` events are
    kept, evictions are counted in :attr:`dropped` (perf-buffer "lost
    samples" accounting — the collector never blocks the instrumented path).
    """

    def __init__(self, maxlen: int | None = None) -> None:
        self._events: deque[Event] = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._dropped = 0

    @property
    def maxlen(self) -> int | None:
        return self._events.maxlen

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def record(
        self,
        kind: str,
        name: str,
        payload: Any = None,
        *,
        span: int = 0,
        parent: Optional[int] = None,
        t: Optional[float] = None,
    ) -> None:
        """Append one event.  ``t`` overrides the timestamp (monotonic
        seconds) for events measured elsewhere — merged device slices carry
        their own clock; everything else stamps ``time.monotonic()`` here."""
        if parent is None:
            parent = current_span()
        ev = Event(time.monotonic() if t is None else t, kind, name, payload,
                   span, parent)
        with self._lock:
            if self._events.maxlen is not None and len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(ev)

    @contextmanager
    def lifecycle(
        self, name: str, payload: Any = None, *, parent: Optional[int] = None
    ) -> Iterator[int]:
        """spawn/exit bracket for a step / microbatch / request.

        Yields the span id shared by both bracket events, so callers can
        attach child events to the same span.  The span becomes the current
        parent (via the contextvars stack) for anything recorded inside the
        block, and is itself parented to the span that encloses it —
        ``parent=`` overrides that for brackets whose causal parent is not
        the lexically enclosing one (e.g. a checkpoint recorded after its
        step closed).
        """
        span = next_span_id()
        if parent is None:
            parent = current_span()
        self.record("spawn", name, payload, span=span, parent=parent)
        token = _SPAN_STACK.set(_SPAN_STACK.get() + (span,))
        try:
            yield span
        finally:
            _SPAN_STACK.reset(token)
            self.record("exit", name, payload, span=span, parent=parent)

    def events(self, kind: str | None = None, name: str | None = None) -> list[Event]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        if name is not None:
            evs = [e for e in evs if e.name == name]
        return evs

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def to_json(self) -> str:
        """JSON-serialise the log (payloads fall back to repr when needed).

        Top level is ``{"dropped": N, "maxlen": M|null, "events": [...]}`` so
        consumers can see ring-buffer losses alongside the surviving events.
        """
        import json

        def default(obj: Any) -> str:
            return repr(obj)

        with self._lock:
            rows = [dataclasses.asdict(e) for e in self._events]
            dropped, maxlen = self._dropped, self._events.maxlen
        return json.dumps(
            {"dropped": dropped, "maxlen": maxlen, "events": rows}, default=default
        )

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def durations(self, name: str) -> list[float]:
        """Pair spawn/exit events of ``name`` into durations (exit order).

        Pairing is by span id when present, then by (hashable, non-None)
        payload identity — so interleaved units (request A exits between
        request B's spawn and exit) pair correctly.  Events carrying neither
        fall back to the legacy LIFO stack match.
        """
        out: list[float] = []
        open_by_key: dict[Any, list[float]] = {}
        stack: list[float] = []
        for e in self.events(name=name):
            key = _pair_key(e)
            if e.kind == "spawn":
                if key is not None:
                    open_by_key.setdefault(key, []).append(e.t)
                else:
                    stack.append(e.t)
            elif e.kind == "exit":
                opened = open_by_key.get(key) if key is not None else None
                if opened:
                    out.append(e.t - opened.pop())
                elif key is None and stack:
                    out.append(e.t - stack.pop())
        return out


# Global default log (like the kernel's shared perf buffer); components may
# construct private logs for isolation.  Bounded: a long-lived server must
# not grow host memory without limit — see GLOBAL_LOG_MAXLEN.
GLOBAL_LOG_MAXLEN = 1 << 18  # 262144 events ≈ tens of MB worst case
GLOBAL_LOG = EventLog(maxlen=GLOBAL_LOG_MAXLEN)
