"""Lifecycle event tracing — the thread/process spawn-exit analogue.

Adaptyst's third profiling type is "tracing of spawning and exiting
threads/processes of a given program".  The unit of concurrency in this
framework is not an OS thread: it is the training step, the microbatch, the
checkpoint writer and the serving request.  This module records their
spawn/exit events on the host with monotonic timestamps, and is the sink for
uprobe-style host callbacks (repro.core.uprobes).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from contextlib import contextmanager
from typing import Any, Iterator


@dataclasses.dataclass(frozen=True)
class Event:
    t: float  # monotonic seconds
    kind: str  # spawn | exit | probe | mark
    name: str  # e.g. "step", "microbatch", "request", probe target
    payload: Any = None


class EventLog:
    """Thread-safe append-only event log (the eBPF ring-buffer analogue)."""

    def __init__(self) -> None:
        self._events: list[Event] = []
        self._lock = threading.Lock()

    def record(self, kind: str, name: str, payload: Any = None) -> None:
        ev = Event(time.monotonic(), kind, name, payload)
        with self._lock:
            self._events.append(ev)

    @contextmanager
    def lifecycle(self, name: str, payload: Any = None) -> Iterator[None]:
        """spawn/exit bracket for a step / microbatch / request."""
        self.record("spawn", name, payload)
        try:
            yield
        finally:
            self.record("exit", name, payload)

    def events(self, kind: str | None = None, name: str | None = None) -> list[Event]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        if name is not None:
            evs = [e for e in evs if e.name == name]
        return evs

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def to_json(self) -> str:
        """JSON-serialise the log (payloads fall back to repr when needed)."""
        import json

        def default(obj: Any) -> str:
            return repr(obj)

        with self._lock:
            rows = [dataclasses.asdict(e) for e in self._events]
        return json.dumps(rows, default=default)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def durations(self, name: str) -> list[float]:
        """Pair spawn/exit events (stack-matched) into durations."""
        out: list[float] = []
        stack: list[float] = []
        for e in self.events(name=name):
            if e.kind == "spawn":
                stack.append(e.t)
            elif e.kind == "exit" and stack:
                out.append(e.t - stack.pop())
        return out


# Global default log (like the kernel's shared perf buffer); components may
# construct private logs for isolation.
GLOBAL_LOG = EventLog()
