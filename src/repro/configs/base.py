"""Config schema for all architectures and input shapes.

One unified decoder-LM schema covers the 10 assigned architectures via a
*layer pattern*: a periodic sequence of (mixer, ffn) block kinds.  The model
stacks parameters per pattern-position and scans over periods, which keeps the
HLO size O(period) instead of O(n_layers) — essential for fast multi-pod
compilation.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Mixer = Literal["ga", "swa", "mamba", "rwkv"]  # global attn / sliding-window attn / SSM / RWKV6
Ffn = Literal["dense", "moe", "rwkv_ffn", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "ga"
    ffn: Ffn = "dense"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0  # always-on shared experts (DeepSeekMoE)
    d_expert: int = 0  # per-expert FFN width (fine-grained experts)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # jitter etc. omitted: deterministic routing for reproducibility


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 256  # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # low-rank dim of the data-dependent decay MLP (RWKV6 "Finch")
    mix_lora: int = 32  # low-rank dim of the token-shift mix MLPs
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    first_k_dense: int = 0  # first k layers forced to (pattern[0].mixer, dense) (DeepSeekMoE)
    sliding_window: int = 4096
    rope_theta: float = 10000.0
    qkv_bias: bool = False  # Qwen2
    qk_norm: bool = False  # Chameleon
    attn_logit_softcap: Optional[float] = None  # Gemma-2
    final_logit_softcap: Optional[float] = None  # Gemma-2
    post_block_norms: bool = False  # Gemma-2/3 post-attn/post-ffn RMSNorms
    scale_embedding: bool = False  # Gemma: multiply embeddings by sqrt(d_model)
    z_loss_weight: float = 1e-4  # final-logit z-loss (stability at scale)
    tied_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    frontend: str = "text"  # text | vlm_stub | audio_stub
    # numerics / memory policy
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    moment_dtype: str = "float32"  # bf16 for the very large archs (398B on 16GiB chips)
    remat_policy: str = "nothing"  # nothing | dots | everything (= no remat)
    # True: lax.scan over periods (fast compiles, small HLO).  False: unrolled
    # Python loop — used by the dry-run so cost_analysis counts every layer
    # (XLA prices a while-loop body ONCE, not × trip count).
    scan_layers: bool = True
    # --- beyond-paper §Perf optimizations (default off = faithful baseline) ---
    # custom-VJP flash attention: backward recomputes block scores instead of
    # stacking O(S²) softmax residuals through the KV-block scan.
    fused_attention_vjp: bool = False
    # pad attention Q-heads (activations only, params untouched) up to this
    # count so the S² compute shards over 'model' when n_heads doesn't divide
    # it (smollm 15H / qwen2 14H on a 16-way axis); 0 = off.
    pad_heads_to: int = 0
    # explicit activation sharding constraints at module boundaries (helps
    # GSPMD propagation pick batch/model shardings instead of replicating).
    activation_constraints: bool = False
    # replicate the unembed table's embed dim across 'data' inside the loss
    # (one hoisted all-gather instead of a partial-sum all-reduce per chunk).
    loss_table_replicated: bool = False
    # split-KV decode combine (shard_map flash-decoding) when the KV cache is
    # sequence-sharded — otherwise XLA all-gathers the cache every step.
    decode_split_kv: bool = False
    # checkpoint the chunk bodies of the mamba/rwkv chunked scans: AD saves
    # chunk-boundary states only (the SSM analogue of the flash VJP).
    chunk_scan_remat: bool = False
    decode_seq_axes: tuple = ("model",)  # mesh axes the cache seq dim shards over
    decode_batch_axes: tuple = ("pod", "data")  # mesh axes the batch shards over
    loss_chunk: int = 1024  # token-chunked cross-entropy chunk size
    attn_chunk: int = 1024  # KV block length of the lax chunked-attention path
    # profiling (the paper's technique): static tracepoints compiled into the
    # step when enabled; see repro.core.tracepoints
    tracepoints: bool = False

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    def layer_spec(self, i: int) -> LayerSpec:
        if i < self.first_k_dense:
            return LayerSpec(mixer=self.layer_pattern[i % self.period].mixer, ffn="dense")
        return self.layer_pattern[i % self.period]

    @property
    def n_periods(self) -> int:
        return (self.n_layers - self.first_k_dense) // self.period

    @property
    def n_tail(self) -> int:
        """Layers after first_k_dense not covered by full periods (handled unscanned)."""
        return (self.n_layers - self.first_k_dense) % self.period

    @property
    def uses_attention(self) -> bool:
        return any(s.mixer in ("ga", "swa") for s in self.layer_pattern)

    @property
    def pure_full_attention(self) -> bool:
        """True if every mixer is global attention (no locality / recurrence)."""
        return all(s.mixer == "ga" for s in self.layer_pattern)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The four assigned LM shapes; `decode_*`/`long_*` lower serve_step.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (see DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.pure_full_attention:
        return False, (
            f"{cfg.name} is pure full-attention; a 512k dense KV cache has no "
            "locality/recurrence structure — skipped per assignment"
        )
    return True, ""


def reduced(cfg: ModelConfig, *, layers: int | None = None) -> ModelConfig:
    """Smoke-test variant: same family/pattern, tiny dims, runs on 1 CPU."""
    n_layers = layers if layers is not None else max(cfg.first_k_dense + cfg.period, 2)
    changes: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        loss_chunk=32,
        attn_chunk=16,
        param_dtype="float32",
        activation_dtype="float32",
        moment_dtype="float32",
        remat_policy="everything",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            n_shared=min(cfg.moe.n_shared, 1),
            d_expert=32 if cfg.moe.d_expert else 0,
        )
    if cfg.mamba is not None:
        changes["mamba"] = dataclasses.replace(cfg.mamba, d_state=8, chunk=16)
    if cfg.rwkv is not None:
        changes["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=16, decay_lora=8, mix_lora=8, chunk=16)
    return dataclasses.replace(cfg, **changes)
