"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay.

[arXiv:2404.05892; hf] — 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
64 WKV heads of size 64; token-shift with data-dependent (LoRA) mixing;
per-channel data-dependent decay w_t.  O(1)-state decode — the designated
long_500k architecture.
"""
from repro.configs.base import LayerSpec, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads = d_model / rwkv.head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=(LayerSpec("rwkv", "rwkv_ffn"),),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    tied_embeddings=False,
    act="silu",
)
