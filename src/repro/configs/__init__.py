"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    LayerSpec,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeConfig,
    reduced,
    supports_shape,
)

_ARCH_MODULES = {
    "gemma3-4b": "repro.configs.gemma3_4b",
    "smollm-360m": "repro.configs.smollm_360m",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "jamba-1.5-large": "repro.configs.jamba_1_5_large",
    "musicgen-large": "repro.configs.musicgen_large",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "SHAPES",
    "LayerSpec",
    "MambaConfig",
    "ModelConfig",
    "MoEConfig",
    "RWKVConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "list_archs",
    "reduced",
    "supports_shape",
]
