"""gemma3-4b [dense]: 5:1 local:global interleave, 128k context.

[hf:google/gemma-3-1b-pt; unverified] — 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.  head_dim=256 (published Gemma-3 head size; note
n_heads*head_dim != d_model by design).  Sliding window 1024 on local layers;
every 6th layer is global.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    layer_pattern=(
        LayerSpec("swa"),
        LayerSpec("swa"),
        LayerSpec("swa"),
        LayerSpec("swa"),
        LayerSpec("swa"),
        LayerSpec("ga"),
    ),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    post_block_norms=True,  # Gemma-3 sandwich norms
    scale_embedding=True,
    tied_embeddings=True,
    act="gelu",
)
