"""The paper's own workload, ported faithfully.

Section III: "a lightweight yet sufficiently complex C program that computes
approximate square roots of integers from 1 to 100" — used as the serial
benchmark target for the instrumentation-overhead study (100 warm-up runs +
1000 measurement runs, hyperfine).

Here it is a jitted JAX program: Newton-iteration approximate sqrt of
1..100, with optional static tracepoints (the USDT analogue) at the same
program points the paper instruments (function entry / loop / exit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tracepoints as tp

N_VALUES = 100
# The paper's C workload runs ~1.03 ms (Table I baseline).  The JAX analogue
# replicates the 1..100 range and uses more Newton steps so the jitted program
# also lands at ~1 ms wall on this container's CPU — keeping the overhead
# percentages directly comparable.
N_REPEAT = 2048
NEWTON_ITERS = 24


def approx_sqrt_workload(x: jax.Array) -> jax.Array:
    """Newton-iteration approximate sqrt, instrumented with static tracepoints.

    The tracepoints compile to nothing when tracing is disabled (asserted by
    tests/test_tracepoints.py) — USDT semantics.
    """
    tp.point("workload.enter", jnp.float32(x.shape[0]))

    def newton_step(guess, _):
        guess = 0.5 * (guess + x / guess)
        return guess, None

    guess = jnp.maximum(x * 0.5, 1.0)
    guess, _ = jax.lax.scan(newton_step, guess, None, length=NEWTON_ITERS)
    tp.point("workload.exit", guess[0])
    return guess


def make_inputs() -> jax.Array:
    return jnp.tile(jnp.arange(1, N_VALUES + 1, dtype=jnp.float32), (N_REPEAT,))
