"""chameleon-34b [vlm]: early-fusion over VQ image tokens.

[arXiv:2405.09818; unverified] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536.  QK-norm (the paper's divergence fix).  The VQ-VAE image
tokenizer is a STUB per the assignment: input_specs() provides precomputed
token ids whose vocabulary includes the image-token span.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    layer_pattern=(LayerSpec("ga"),),
    qk_norm=True,
    tied_embeddings=False,
    frontend="vlm_stub",
    act="silu",
)
