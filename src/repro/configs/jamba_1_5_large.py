"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave, MoE.

[arXiv:2403.19887; hf] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.  Jamba block structure: period 8 with one
attention layer (position 4) per 7 Mamba layers, MoE on every other layer.
bf16 optimizer moments: 398B params * (2+2+2) bytes / 256 chips ~= 9.3 GiB —
fp32 moments would not fit a 16 GiB v5e chip (DESIGN.md §6).
"""
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern=(
        LayerSpec("mamba", "dense"),
        LayerSpec("mamba", "moe"),
        LayerSpec("mamba", "dense"),
        LayerSpec("mamba", "moe"),
        LayerSpec("ga", "dense"),
        LayerSpec("mamba", "moe"),
        LayerSpec("mamba", "dense"),
        LayerSpec("mamba", "moe"),
    ),
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        n_shared=0,
        d_expert=24576,
        capacity_factor=1.25,
    ),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tied_embeddings=False,
    moment_dtype="bfloat16",
    act="silu",
)
