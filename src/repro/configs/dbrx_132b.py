"""dbrx-132b [moe]: 16 experts top-4, fine-grained.

[hf:databricks/dbrx-base; unverified] — 40L d_model=6144 48H (GQA kv=8)
d_ff=10752 (per expert) vocab=100352.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    layer_pattern=(LayerSpec("ga", "moe"),),
    moe=MoEConfig(
        n_experts=16,
        top_k=4,
        n_shared=0,
        d_expert=10752,
        capacity_factor=1.25,
    ),
    rope_theta=500_000.0,
    tied_embeddings=False,
    act="silu",
)
