"""gemma2-27b [dense]: local+global alternating, logit softcapping.

[arXiv:2408.00118; hf] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.  head_dim=128 (published).  attn softcap 50.0, final softcap
30.0, post-block RMSNorms, sliding window 4096 on local layers.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    layer_pattern=(LayerSpec("swa"), LayerSpec("ga")),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norms=True,
    scale_embedding=True,
    tied_embeddings=True,
    act="gelu",
)
