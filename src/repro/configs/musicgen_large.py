"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] — 48L d_model=2048 32H (GQA kv=32, i.e. MHA)
d_ff=8192 vocab=2048.  The EnCodec neural-codec frontend is a STUB per the
assignment: input_specs() provides precomputed frame-token ids (the 4-codebook
delay pattern collapsed to one summed embedding stream).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    layer_pattern=(LayerSpec("ga"),),
    tied_embeddings=False,
    frontend="audio_stub",
    act="gelu",
)
