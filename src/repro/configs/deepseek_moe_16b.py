"""deepseek-moe-16b [moe]: fine-grained experts, 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf] — 28L d_model=2048 16H (GQA kv=16, i.e. MHA)
d_ff=1408 (per fine-grained expert) vocab=102400.  Layer 0 is a dense FFN
(width 10944, the published DeepSeekMoE-16B value); remaining 27 layers are
MoE.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense layer-0 width; expert width is moe.d_expert
    vocab_size=102400,
    layer_pattern=(LayerSpec("ga", "moe"),),
    first_k_dense=1,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared=2,
        d_expert=1408,
        capacity_factor=1.25,
    ),
    tied_embeddings=False,
    act="silu",
)
