"""Cross-process session stitching: one timeline for the whole fleet.

Since the router split serving into a frontdoor process plus N replica
processes, a request's trace is sharded: the frontdoor session holds the
``request``/``route``/``outcome`` spans, each replica session holds the
``rpc → request → prefill → dispatch`` subtree that actually served it, and
the only link between them is the :class:`repro.core.events.SpanContext`
the frontdoor injected over HTTP.  ``stitch()`` merges those sessions into
one — Adaptyst's cross-process ambition (profile a *program*, not a
process) applied to this framework's span trees.  Three transformations:

* **Span-id namespacing** — span ids are process-unique, so two sessions
  collide.  Each input's ids are shifted by a per-session offset strictly
  above every id seen so far (the same allocate-above-the-max trick
  :mod:`repro.trace.device` uses for device slices), preserving intra-
  session ordering — ``span_tree``'s parent-id < child-id sanity check
  keeps holding.
* **Clock alignment** — event timestamps are ``time.monotonic()`` with a
  per-process epoch.  Every session records a clock anchor (paired
  monotonic/wall samples, see :func:`repro.trace.session.run_metadata`)
  mapping its events onto its own wall clock; residual *cross-host* skew is
  then estimated NTP-style from the request handshake pairs the frontdoor
  recorded (its send/recv wall stamps vs. the replica's recv/send stamps):
  ``theta = ((t1 - t0) + (t2 - t3)) / 2`` per pair, median over all pairs
  per origin.  The merged timeline is the frontdoor's wall clock.
* **Remote re-linking** — a replica ``rpc`` span carries its frontdoor
  route span as a ``remote`` payload ref (origin + span id in the origin's
  id space).  Once both sessions share one id space, the rpc's ``parent``
  is re-pointed at the mapped route span, so every consumer — ``report
  --tree``, the Perfetto/speedscope/flamegraph exporters, ``diff
  --by-path`` — sees replica subtrees under their owning frontdoor request
  with no code changes.

Provenance: the stitched session's ``meta["stitch"]`` records every input
(path, origin, event count, id offset + resulting span-id range, clock
offset, estimated skew, torn-span count) plus re-link totals, and is what
:func:`repro.trace.export.to_chrome_trace` uses to split the merged trace
back into per-process Perfetto tracks with cross-process flow arrows.
"""
from __future__ import annotations

import dataclasses
import glob
import os
import statistics
from typing import Any, Iterable, Optional

from repro.core.events import Event, _pair_key, remote_ref
from repro.trace.collector import Span, resolve_spans
from repro.trace.session import Session

HOPS = ("frontdoor_queue", "network", "replica_queue", "service")


# -- input discovery ----------------------------------------------------------


def discover_inputs(frontdoor_path: str) -> list[str]:
    """Replica session dirs belonging to a frontdoor session.

    Primary source: the ``replica_sessions`` manifest key the router CLI
    maintains as replicas announce their trace dirs.  Fallback (manifest
    torn, or the router died before any replica came up): every streaming
    dir under ``<frontdoor-dir>/replicas/*/`` — the layout the router CLI
    creates.  Missing dirs are silently skipped (a replica may have been
    SIGKILLed before writing anything).
    """
    from repro.trace.stream import is_stream_dir, load_any

    out: list[str] = []
    try:
        meta = load_any(frontdoor_path).meta
    except Exception:
        meta = {}
    for rec in meta.get("replica_sessions") or []:
        td = rec.get("trace_dir") if isinstance(rec, dict) else None
        if td and os.path.isdir(td) and td not in out:
            out.append(td)
    if not out and os.path.isdir(frontdoor_path):
        for d in sorted(glob.glob(os.path.join(frontdoor_path, "replicas", "*"))):
            if is_stream_dir(d) and d not in out:
                out.append(d)
    return out


# -- clock alignment ----------------------------------------------------------


def _clock_offset(sess: Session) -> float:
    """Offset mapping this session's monotonic timestamps to its wall clock.

    From the recorded anchor when present; for pre-anchor sessions, fall
    back to assuming the first event landed at ``created_unix``.
    """
    clock = sess.meta.get("clock")
    if isinstance(clock, dict):
        try:
            return float(clock["unix"]) - float(clock["monotonic"])
        except (KeyError, TypeError, ValueError):
            pass
    created = sess.meta.get("created_unix")
    if isinstance(created, (int, float)) and sess.events:
        return float(created) - min(e.t for e in sess.events)
    return 0.0


def _handshake_skews(ref: Session) -> dict[str, list[float]]:
    """Per-origin NTP-style skew samples from the reference session's
    ``outcome`` events (``theta`` = origin wall clock minus reference wall
    clock; positive = the origin's clock runs ahead)."""
    out: dict[str, list[float]] = {}
    for e in ref.events:
        p = e.payload
        if e.kind != "route" or not isinstance(p, dict):
            continue
        hs = p.get("hs")
        if not isinstance(hs, dict):
            continue
        try:
            t0 = float(hs["sent_unix"])
            t1 = float(hs["replica_recv_unix"])
            t2 = float(hs["replica_sent_unix"])
            t3 = float(hs["recv_unix"])
            origin = str(hs["origin"])
        except (KeyError, TypeError, ValueError):
            continue
        out.setdefault(origin, []).append(((t1 - t0) + (t2 - t3)) / 2.0)
    return out


def _max_id(events: Iterable[Event]) -> int:
    return max((max(e.span, e.parent) for e in events), default=0)


def _close_torn(events: list[Event]) -> tuple[list[Event], int]:
    """Synthesize exit events for spans a dead process left open.

    ``resolve_spans`` closes an unpaired spawn at the *whole* event list's
    last timestamp; after stitching, that attributes the merged fleet's
    remaining lifetime to a span whose process was SIGKILLed long before.
    Cap each input's open spans at that input's own last event instead —
    the latest instant the process was provably alive — and flag the spawn
    payload (``torn: true``) so consumers can tell a salvaged span from a
    clean close.
    """
    open_by_key: dict[Any, list[int]] = {}
    stack_by_name: dict[str, list[int]] = {}
    for i, e in enumerate(events):
        if e.kind == "spawn":
            key = _pair_key(e)
            if key is not None:
                open_by_key.setdefault((e.name, key), []).append(i)
            else:
                stack_by_name.setdefault(e.name, []).append(i)
        elif e.kind == "exit":
            key = _pair_key(e)
            opened = open_by_key.get((e.name, key)) if key is not None else None
            if opened:
                opened.pop()
            elif key is None and stack_by_name.get(e.name):
                stack_by_name[e.name].pop()
    idxs = ([i for lst in open_by_key.values() for i in lst]
            + [i for lst in stack_by_name.values() for i in lst])
    if not idxs:
        return events, 0
    t_last = max(e.t for e in events)
    out = list(events)
    tails: list[Event] = []
    for i in idxs:
        s = out[i]
        if isinstance(s.payload, dict):
            out[i] = dataclasses.replace(s, payload={**s.payload, "torn": True})
        tails.append(Event(t_last, "exit", s.name, out[i].payload,
                           s.span, s.parent))
    return out + tails, len(idxs)


# -- the merge ----------------------------------------------------------------


def stitch_sessions(inputs: list[tuple[str, Session]], *,
                    skew_correct: bool = True) -> Session:
    """Merge loaded sessions into one; the first input is the reference
    (its wall clock is the merged timeline, its span ids keep their values,
    and its handshake records drive skew estimation) — pass the frontdoor
    session first.
    """
    if not inputs:
        raise ValueError("stitch needs at least one input session")
    ref = inputs[0][1]
    skews = _handshake_skews(ref) if skew_correct else {}

    merged: list[Event] = []
    origin_offset: dict[str, int] = {}
    records: list[dict[str, Any]] = []
    skipped: list[dict[str, Any]] = []
    base = 0  # all ids assigned so far are <= base
    for i, (path, sess) in enumerate(inputs):
        origin = str(sess.meta.get("origin") or f"proc{i}")
        if origin in origin_offset:
            skipped.append({"path": path, "origin": origin,
                            "reason": "duplicate origin"})
            continue
        offset = base  # reference keeps its ids (base starts at 0)
        hi = _max_id(sess.events)
        clock_off = _clock_offset(sess)
        skew = (statistics.median(skews[origin])
                if origin in skews and i > 0 else 0.0)
        shift = clock_off - skew
        origin_offset[origin] = offset
        base += hi
        capped, torn = _close_torn(list(sess.events))
        for e in capped:
            merged.append(dataclasses.replace(
                e, t=e.t + shift,
                span=e.span + offset if e.span else 0,
                parent=e.parent + offset if e.parent else 0))
        records.append({
            "path": path, "origin": origin, "events": len(sess.events),
            "id_offset": offset, "span_ids": [offset + 1, offset + hi],
            "clock_offset_s": round(clock_off, 6),
            "skew_s": round(skew, 6),
            "torn_spans": torn,
        })

    # re-link remote parents: a spawn/exit pair whose payload names a
    # remote (origin, span) now has that parent in the shared id space
    relinked = 0
    unmatched = 0
    for i, e in enumerate(merged):
        ref_p = remote_ref(e.payload)
        if ref_p is None:
            continue
        off = origin_offset.get(str(ref_p["origin"]))
        if off is None:
            unmatched += 1 if e.kind == "spawn" else 0
            continue
        merged[i] = dataclasses.replace(e, parent=ref_p["span"] + off)
        relinked += 1 if e.kind == "spawn" else 0
    merged.sort(key=lambda e: e.t)

    meta = dict(ref.meta)
    meta["stitch"] = {
        "inputs": records,
        "skipped": skipped,
        "relinked_spans": relinked,
        "unmatched_remote": unmatched,
        "events": len(merged),
        "skew_corrected": bool(skew_correct),
    }
    return Session(
        meta=meta, events=merged,
        dropped=sum(s.dropped for _, s in inputs),
        capacity=ref.capacity,
        decisions=[d for _, s in inputs for d in s.decisions],
        store=ref.store, chip=ref.chip,
        collector_stats=ref.collector_stats,
    )


def stitch(paths: list[str], *, skew_correct: bool = True,
           discover: bool = True) -> Session:
    """Load and merge sessions/streaming dirs (frontdoor first).

    With ``discover`` (default), a frontdoor streaming session's announced
    replica dirs are appended automatically — ``repro.trace stitch
    <frontdoor-dir>`` alone stitches the whole fleet.
    """
    from repro.trace.stream import load_any

    paths = list(paths)
    if discover:
        for d in discover_inputs(paths[0]):
            if d not in paths:
                paths.append(d)
    return stitch_sessions([(p, load_any(p)) for p in paths],
                           skew_correct=skew_correct)


# -- chain + hop analysis -----------------------------------------------------


def _span_children(spans: list[Span]) -> dict[int, list[Span]]:
    kids: dict[int, list[Span]] = {}
    for s in spans:
        if s.parent:
            kids.setdefault(s.parent, []).append(s)
    return kids


def chain_report(session: Session) -> dict[str, Any]:
    """Cross-process chain coverage: of the completed requests (terminal
    outcome ``ok``/``retried``), how many have a full frontdoor → replica
    chain — request → route → (re-linked) rpc → engine request?

    ``broken`` samples up to 10 unchained requests (outcome payloads) for
    debugging; ``orphaned_remote`` counts rpc spans whose remote parent
    never resolved (origin missing from the stitched inputs).
    """
    spans = resolve_spans(session.events)
    kids = _span_children(spans)
    completed = 0
    chained = 0
    broken: list[dict[str, Any]] = []
    for s in spans:
        p = s.payload
        if (s.name != "outcome" or not isinstance(p, dict)
                or p.get("outcome") not in ("ok", "retried")):
            continue
        completed += 1
        ok = False
        for route in kids.get(s.parent, []):
            if route.name != "route":
                continue
            for rpc in kids.get(route.span, []):
                if rpc.name == "rpc" and any(
                        c.name == "request" for c in kids.get(rpc.span, [])):
                    ok = True
        if ok:
            chained += 1
        elif len(broken) < 10:
            broken.append(p)
    orphaned = sum(1 for s in spans
                   if s.remote is not None
                   and str(s.remote.get("origin")) not in
                   {r["origin"] for r in
                    (session.meta.get("stitch") or {}).get("inputs", [])})
    return {
        "completed": completed,
        "chained": chained,
        "fraction": (chained / completed) if completed else 0.0,
        "orphaned_remote": orphaned,
        "broken": broken,
    }


def hop_rows(session: Session) -> list[dict[str, Any]]:
    """One row per completed request carrying a hop decomposition:
    ``{hops: {...}, latency_ms, sum_ms, replica, outcome}``."""
    rows: list[dict[str, Any]] = []
    for e in session.events:
        p = e.payload
        if (e.kind != "route" or e.name != "outcome"
                or not isinstance(p, dict)
                or not isinstance(p.get("hops"), dict)):
            continue
        hops = {h: float(p["hops"].get(h, 0.0)) for h in HOPS}
        rows.append({
            "hops": hops,
            "latency_ms": float(p.get("latency_ms") or 0.0),
            "sum_ms": sum(hops.values()),
            "replica": p.get("replica"),
            "outcome": p.get("outcome"),
        })
    return rows


def hop_summary(rows: list[dict[str, Any]]) -> dict[str, Any]:
    """Aggregate hop stats + the sum-vs-latency consistency check."""
    def stats(vals: list[float]) -> dict[str, float]:
        if not vals:
            return {"count": 0}
        vs = sorted(vals)
        return {
            "count": len(vs),
            "mean": sum(vs) / len(vs),
            "p50": vs[len(vs) // 2],
            "p95": vs[min(len(vs) - 1, int(len(vs) * 0.95))],
            "max": vs[-1],
        }

    within = sum(1 for r in rows
                 if r["latency_ms"] > 0
                 and abs(r["sum_ms"] - r["latency_ms"]) <= 0.05 * r["latency_ms"])
    return {
        "requests": len(rows),
        "within_5pct": within,
        "hops": {h: stats([r["hops"][h] for r in rows]) for h in HOPS},
        "latency_ms": stats([r["latency_ms"] for r in rows]),
    }


def merge_for_report(paths: list[str]) -> Session:
    """Load N sessions for one ``report`` invocation without id collisions.

    The namespacing/re-linking machinery of :func:`stitch_sessions` with
    discovery and skew estimation as stitch defaults — loading two sessions
    from different processes previously cross-linked their span ids
    silently (span id 7 of the frontdoor adopted span id 7's children from
    the replica).
    """
    return stitch(paths)


__all__ = [
    "HOPS", "chain_report", "discover_inputs", "hop_rows", "hop_summary",
    "merge_for_report", "stitch", "stitch_sessions",
]
