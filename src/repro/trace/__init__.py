"""repro.trace — continuous trace collection, export, cross-run persistence.

The observability layer over :mod:`repro.core.events`:

* :mod:`repro.trace.collector` — bounded ring-buffer :class:`TraceCollector`
  (capacity + dropped-event accounting, per-track views, span resolution);
* :mod:`repro.trace.export` — Chrome Trace Event JSON (Perfetto), speedscope,
  folded flamegraph stacks;
* :mod:`repro.trace.session` — one-file run snapshots (events + dispatch
  decisions + ProfileStore + chip + git/config metadata) with warm-start
  reload;
* :mod:`repro.trace.cli` — ``python -m repro.trace {report,export,diff}``.
"""
from repro.trace.collector import Span, TraceCollector, resolve_spans
from repro.trace.export import export, to_chrome_trace, to_folded, to_speedscope
from repro.trace.session import (
    Session,
    artifact_meta,
    diff_artifacts,
    diff_sessions,
    load_profile_store,
    load_profile_stores,
)

__all__ = [
    "Span",
    "TraceCollector",
    "resolve_spans",
    "export",
    "to_chrome_trace",
    "to_folded",
    "to_speedscope",
    "Session",
    "artifact_meta",
    "diff_artifacts",
    "diff_sessions",
    "load_profile_store",
    "load_profile_stores",
]
