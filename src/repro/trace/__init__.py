"""repro.trace — continuous trace collection, export, cross-run persistence.

The observability layer over :mod:`repro.core.events`:

* :mod:`repro.trace.collector` — bounded ring-buffer :class:`TraceCollector`
  (capacity + dropped-event accounting, reserved per-track rings, per-track
  views, span resolution, streaming sink hook);
* :mod:`repro.trace.export` — Chrome Trace Event JSON (Perfetto), speedscope,
  folded flamegraph stacks;
* :mod:`repro.trace.session` — one-file run snapshots (events + dispatch
  decisions + ProfileStore + chip + git/config metadata) with warm-start
  reload, diffing and CI regression gating;
* :mod:`repro.trace.stream` — durable :class:`StreamingSession` sinks
  (rotated, fsynced JSONL segments + manifest; a crash loses at most the
  open segment) and crash recovery back into sessions;
* :mod:`repro.trace.device` — ``jax.profiler`` dump adapter: device slices
  aligned under their owning host spans (per-device tracks below host rows);
* :mod:`repro.trace.liveprof` — live duty-cycled device profiling: capture
  windows under the overhead budget, merged into the running trace with
  exact ``span=`` annotation alignment;
* :mod:`repro.trace.stitch` — cross-process session stitching (span-id
  namespacing, handshake clock-skew correction, remote-parent re-linking)
  plus per-hop latency decomposition over the stitched chain;
* :mod:`repro.trace.cli` — ``python -m repro.trace {report,export,diff,compact,device,stitch,hops}``.
"""
from repro.trace.collector import Span, SpanNode, TraceCollector, resolve_spans, span_tree
from repro.trace.device import (
    align_device_slices,
    alignment_summary,
    load_profiler_trace,
    merge_device_trace,
)
from repro.trace.export import export, to_chrome_trace, to_folded, to_speedscope
from repro.trace.liveprof import (
    LiveDeviceProfiler,
    SyntheticProfilerBackend,
    device_annotation,
)
from repro.trace.session import (
    Session,
    age_out_profiles,
    artifact_meta,
    artifact_regressions,
    diff_artifacts,
    diff_sessions,
    load_profile_store,
    load_profile_stores,
    path_diff,
    path_regressions,
    session_regressions,
)
from repro.trace.stitch import (
    chain_report,
    hop_rows,
    hop_summary,
    stitch,
    stitch_sessions,
)
from repro.trace.stream import (
    StreamingSession,
    load_any,
    load_metrics_timeline,
    load_stream,
)

__all__ = [
    "Span",
    "SpanNode",
    "TraceCollector",
    "LiveDeviceProfiler",
    "SyntheticProfilerBackend",
    "align_device_slices",
    "alignment_summary",
    "device_annotation",
    "load_profiler_trace",
    "merge_device_trace",
    "resolve_spans",
    "span_tree",
    "export",
    "to_chrome_trace",
    "to_folded",
    "to_speedscope",
    "Session",
    "StreamingSession",
    "age_out_profiles",
    "chain_report",
    "hop_rows",
    "hop_summary",
    "stitch",
    "stitch_sessions",
    "artifact_meta",
    "artifact_regressions",
    "diff_artifacts",
    "diff_sessions",
    "load_any",
    "load_metrics_timeline",
    "load_profile_store",
    "load_profile_stores",
    "load_stream",
    "path_diff",
    "path_regressions",
    "session_regressions",
]
