"""Device-timeline adapter: fold ``jax.profiler`` traces under host spans.

The collector only sees *host* lifecycle events — a request span covers the
wall time of its prefill, but says nothing about what the accelerator ran
inside it.  ``jax.profiler.trace(dir)`` captures exactly that missing half:
its TensorBoard dump contains a Chrome-format trace (``*.trace.json.gz``
under ``plugins/profile/<run>/``) whose per-device processes list every XLA
op executed.  This module parses that dump and merges the device slices into
a :class:`~repro.trace.session.Session` as ``device``-kind events **parented
to the host span that was open when they ran**, so ``report --tree`` shows
accelerator time nested under the request/step that caused it and the
Perfetto export renders host tracks above per-device tracks.

Alignment is two-level:

* **explicit span hints** — a slice whose name or args carry ``span=<id>``
  (e.g. from ``jax.profiler.TraceAnnotation(f"span={sid}")`` around the
  dispatched call) binds to that span directly;
* **time-window containment** — otherwise the slice's midpoint (after
  shifting by ``offset_s``; estimated by aligning trace starts when not
  given — profiler clocks and our monotonic clock share no epoch) picks the
  innermost host span whose window contains it.  Slices matching no span
  become device-track roots rather than being dropped.
"""
from __future__ import annotations

import dataclasses
import glob
import gzip
import json
import os
import re
from typing import Any, Iterable, Optional

from repro.core.events import Event
from repro.trace.collector import resolve_spans

DEVICE_KIND = "device"

# process names jax/XLA give device rows in its chrome dump ("/device:TPU:0",
# "GPU:0 Stream #12", "TPU:0 XLA Ops", ...)
_DEVICE_PID_RE = re.compile(r"device|tpu|gpu|xla|stream", re.IGNORECASE)
_SPAN_HINT_RE = re.compile(r"\bspan[=:](\d+)\b")


@dataclasses.dataclass(frozen=True)
class DeviceSlice:
    """One complete event from the profiler dump, in its own clock (seconds)."""

    name: str
    t0: float
    t1: float
    device: str
    args: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    @property
    def span_hint(self) -> int:
        """Host span id embedded by a TraceAnnotation, 0 when absent."""
        v = self.args.get("span")
        if isinstance(v, int) and v > 0:
            return v
        for text in (str(v) if v is not None else "", self.name):
            m = _SPAN_HINT_RE.search(text)
            if m:
                return int(m.group(1))
        return 0


def _find_trace_files(path: str) -> list[str]:
    """Resolve a profiler dump directory to its chrome trace file(s).

    A one-shot ``jax.profiler.trace`` dump holds a single file; a duty-cycled
    live-capture directory (:mod:`repro.trace.liveprof`) holds one per
    window — all of them belong to the run, so all are returned.
    """
    if os.path.isfile(path):
        return [path]
    for pattern in ("*.trace.json.gz", "*.trace.json", "*.json.gz", "*.json"):
        hits = sorted(glob.glob(os.path.join(path, "**", pattern), recursive=True))
        if hits:
            return hits
    xplanes = glob.glob(os.path.join(path, "**", "*.xplane.pb"), recursive=True)
    if xplanes:
        raise ValueError(
            f"{path} holds only raw xplane protos ({os.path.basename(xplanes[0])}); "
            "install xprof/tensorboard-plugin-profile to convert them, or point "
            "at the *.trace.json.gz it produces"
        )
    raise FileNotFoundError(f"no chrome trace (*.trace.json[.gz]) under {path}")


def _find_trace_file(path: str) -> str:
    return _find_trace_files(path)[0]


def _parse_trace_file(file: str) -> list[DeviceSlice]:
    opener = gzip.open if file.endswith(".gz") else open
    with opener(file, "rt") as f:
        doc = json.load(f)
    rows = doc["traceEvents"] if isinstance(doc, dict) else doc
    pid_names: dict[Any, str] = {}
    for r in rows:
        if r.get("ph") == "M" and r.get("name") == "process_name":
            pid_names[r.get("pid")] = str(r.get("args", {}).get("name", ""))
    out: list[DeviceSlice] = []
    for r in rows:
        if r.get("ph") != "X" or not isinstance(r.get("ts"), (int, float)):
            continue
        device = pid_names.get(r.get("pid")) or f"pid:{r.get('pid')}"
        t0 = r["ts"] * 1e-6
        dur = r.get("dur", 0) or 0
        out.append(DeviceSlice(
            name=str(r.get("name", "?")),
            t0=t0,
            t1=t0 + dur * 1e-6,
            device=device,
            args=r.get("args") or {},
        ))
    return out


def load_profiler_trace(path: str, *, device_only: bool = True) -> list[DeviceSlice]:
    """Parse a ``jax.profiler`` dump (file or TensorBoard dir) into slices.

    Reads the Chrome Trace Event JSON (gzipped or plain), maps ``pid`` rows
    to their ``process_name`` metadata, and returns every complete (``X``)
    event as a :class:`DeviceSlice` with timestamps in seconds.  Directories
    holding several trace files (one per duty-cycled capture window) are
    merged.  ``device_only`` keeps only device-looking processes when the
    dump names any (host python threads stay host-side — the collector
    already has them); dumps with no recognisable device rows are returned
    whole.
    """
    out: list[DeviceSlice] = []
    for file in _find_trace_files(path):
        out.extend(_parse_trace_file(file))
    if device_only:
        dev = [s for s in out if _DEVICE_PID_RE.search(s.device)]
        if dev:  # host-only dumps (pure-CPU smoke runs) are returned whole
            out = dev
    out.sort(key=lambda s: s.t0)
    return out


def align_device_slices(
    host_events: Iterable[Event],
    slices: Iterable[DeviceSlice],
    *,
    offset_s: Optional[float] = None,
    id_alloc: Optional[Any] = None,
    stats: Optional[dict[str, int]] = None,
) -> list[Event]:
    """Turn profiler slices into ``device`` events parented to host spans.

    Each returned event carries ``kind="device"``, a fresh span id of its
    own (so device slices are real span-tree nodes), and
    ``payload={"dur_s", "device", "align", ...}`` — exactly what
    :func:`repro.trace.collector.resolve_spans` needs to rebuild the device
    span and :mod:`repro.trace.export` needs to render per-device tracks.
    ``payload["align"]`` records how the parent was found: ``"span"``
    (explicit annotation hint), ``"window"`` (time containment fallback) or
    ``"none"`` (device-track root).

    ``id_alloc`` is a zero-arg callable producing fresh span ids.  Live
    merges (same process as the recording run) must pass
    :func:`repro.core.events.next_span_id` so device ids share the host
    counter; the default — allocate strictly above every id the host events
    mention — is for post-hoc merges where the recording process's counter
    is gone.  ``stats``, when given, accumulates counts per alignment mode
    (keys ``span``/``window``/``none``/``total``).
    """
    host_events = sorted(host_events, key=lambda e: e.t)
    slices = list(slices)
    if not slices:
        return []
    if offset_s is None:
        host_t0 = host_events[0].t if host_events else 0.0
        offset_s = host_t0 - slices[0].t0  # align trace starts
    spans = [s for s in resolve_spans(host_events) if s.span]
    by_id = {s.span: s for s in spans}

    if id_alloc is None:
        # Device span ids must not collide with the session's host ids: the
        # session was recorded in another process, so this process's global
        # counter is meaningless here — allocate strictly above every id the
        # host events mention (span_tree treats parent >= own id as corrupt).
        base = 1 + max((max(e.span, e.parent) for e in host_events), default=0)
        counter = iter(range(base, base + len(slices)))
        id_alloc = lambda: next(counter)

    # innermost-containing-span lookup via a single time sweep: spans enter
    # the active set at t0 and leave at t1, so each slice midpoint consults
    # only the handful of concurrently-open spans instead of scanning all of
    # them (real profiler dumps carry 10k+ slices).
    mids = sorted(range(len(slices)),
                  key=lambda i: (slices[i].t0 + slices[i].t1) / 2)
    starts = sorted(spans, key=lambda s: s.t0)
    active: dict[int, Any] = {}
    owners: dict[int, int] = {}
    modes: dict[int, str] = {}
    si = 0
    for i in mids:
        mid = (slices[i].t0 + slices[i].t1) / 2 + offset_s
        while si < len(starts) and starts[si].t0 <= mid:
            active[starts[si].span] = starts[si]
            si += 1
        for sid in [sid for sid, s in active.items() if s.t1 < mid]:
            del active[sid]
        hint = slices[i].span_hint
        if hint and hint in by_id:
            owners[i] = hint
            modes[i] = "span"
        elif active:
            owners[i] = min(active.values(), key=lambda s: s.dur).span
            modes[i] = "window"
        else:
            owners[i] = 0
            modes[i] = "none"

    out: list[Event] = []
    for i, sl in enumerate(slices):
        t0, t1 = sl.t0 + offset_s, sl.t1 + offset_s
        payload: dict[str, Any] = {"dur_s": max(0.0, t1 - t0),
                                   "device": sl.device, "align": modes[i]}
        if sl.args:
            payload["args"] = {k: v for k, v in sl.args.items()
                               if isinstance(v, (int, float, str, bool))}
        out.append(Event(t0, DEVICE_KIND, sl.name, payload,
                         span=id_alloc(), parent=owners[i]))
        if stats is not None:
            stats[modes[i]] = stats.get(modes[i], 0) + 1
            stats["total"] = stats.get("total", 0) + 1
    return out


def alignment_summary(events: Iterable[Event]) -> dict[str, Any]:
    """Per-mode counts + annotated fraction over merged ``device`` events."""
    counts = {"span": 0, "window": 0, "none": 0, "total": 0}
    for e in events:
        if e.kind != DEVICE_KIND or not isinstance(e.payload, dict):
            continue
        mode = e.payload.get("align")
        if mode not in counts:
            mode = "none"
        counts[mode] += 1
        counts["total"] += 1
    counts["annotated_fraction"] = (
        counts["span"] / counts["total"] if counts["total"] else 0.0
    )
    return counts


def merge_device_trace(
    session: Any, path: str, *, offset_s: Optional[float] = None
) -> int:
    """Merge a profiler dump into a loaded Session, in place.

    Returns the number of device events merged; records the dump path,
    count and per-mode alignment stats under
    ``session.meta["device_trace"]``.
    """
    stats: dict[str, int] = {}
    merged = align_device_slices(
        session.events, load_profiler_trace(path), offset_s=offset_s,
        stats=stats,
    )
    session.events = sorted(session.events + merged, key=lambda e: e.t)
    session.meta["device_trace"] = {
        "path": path, "events": len(merged), "align": stats,
    }
    return len(merged)
