"""Trace exporters: Chrome Trace Event JSON, speedscope, folded stacks.

Everything a standard viewer can open:

* :func:`to_chrome_trace` — the Trace Event Format (``traceEvents``) that
  Perfetto / ``chrome://tracing`` load directly.  Spawn/exit pairs become
  ``B``/``E`` duration events, dispatch decisions become ``X`` complete
  events spanning their measured execution, loose marks/probes become ``i``
  instants.  Tracks map to ``tid`` rows under one ``pid``.
* :func:`to_speedscope` — a sampled speedscope profile per track (each
  closed span is one weighted sample), https://speedscope.app loads it.
* :func:`to_folded` — ``track;name count`` folded stacks for classic
  ``flamegraph.pl`` / inferno tooling (counts in integer microseconds).
"""
from __future__ import annotations

import json
from typing import Any, Iterable, Optional

from repro.core.events import Event
from repro.trace.collector import TRACKS, Span, TraceCollector, resolve_spans

PID = 1  # single-process traces; tracks are threads


def _track_ids(tracks: Iterable[str]) -> dict[str, int]:
    order = {t: i for i, t in enumerate(TRACKS)}
    # canonical tracks keep stable tids; custom tracks get distinct tids after
    # them (alphabetical), one viewer row each
    uniq = sorted(set(tracks), key=lambda t: (order.get(t, len(order)), t))
    return {t: i + 1 for i, t in enumerate(uniq)}


def _payload_args(payload: Any) -> dict[str, Any]:
    if isinstance(payload, dict):
        return {k: v if isinstance(v, (int, float, str, bool, type(None))) else repr(v)
                for k, v in payload.items()}
    if payload is None:
        return {}
    return {"payload": payload if isinstance(payload, (int, float, str, bool)) else repr(payload)}


def _tracker(collector: Optional[TraceCollector]):
    if collector is not None:
        return collector.track_name
    from repro.trace.collector import TRACK_OF

    return lambda e: "dispatch" if e.kind == "dispatch" else TRACK_OF.get(e.name, "other")


def to_chrome_trace(
    events: Iterable[Event],
    *,
    collector: Optional[TraceCollector] = None,
    meta: Optional[dict[str, Any]] = None,
) -> dict[str, Any]:
    """Trace Event Format dict: ``{"traceEvents": [...], "otherData": ...}``.

    Timestamps are microseconds relative to the first event (Perfetto is
    happiest with small positive ``ts``).
    """
    events = sorted(events, key=lambda e: e.t)
    track_name = _tracker(collector)
    tids = _track_ids(track_name(e) for e in events)

    def start_of(e: Event) -> float:
        # dispatch events are recorded at completion; their X row starts
        # measured_s earlier, and the epoch must cover that
        if e.kind == "dispatch" and isinstance(e.payload, dict) and isinstance(
            e.payload.get("measured_s"), (int, float)
        ):
            return e.t - e.payload["measured_s"]
        return e.t

    def async_id(e: Event) -> Optional[str]:
        """Pairing id for spawn/exit: concurrent units must not be matched by
        the viewer's per-tid LIFO stack (interleaved requests would swap)."""
        if e.span:
            return str(e.span)
        try:
            hash(e.payload)
        except TypeError:
            return None
        if e.payload is None:
            return None
        return f"{e.name}:{e.payload!r}"

    t0 = min((start_of(e) for e in events), default=0.0)
    us = lambda t: round((t - t0) * 1e6, 3)  # noqa: E731

    rows: list[dict[str, Any]] = [
        {"ph": "M", "pid": PID, "name": "process_name", "args": {"name": "repro"}}
    ]
    for track, tid in tids.items():
        rows.append({"ph": "M", "pid": PID, "tid": tid, "name": "thread_name",
                     "args": {"name": track}})
    for e in events:
        tid = tids[track_name(e)]
        base = {"name": e.name, "pid": PID, "tid": tid, "ts": us(e.t),
                "args": _payload_args(e.payload)}
        if e.span:
            base["args"]["span"] = e.span
        if e.kind in ("spawn", "exit"):
            # async b/e (paired by id) when the event carries an identity;
            # sync B/E (viewer LIFO) only for legacy identity-less events
            aid = async_id(e)
            ph = {"spawn": ("b" if aid else "B"), "exit": ("e" if aid else "E")}[e.kind]
            row = {**base, "ph": ph, "cat": "lifecycle"}
            if aid:
                row["id"] = aid
            rows.append(row)
        elif e.kind == "dispatch" and isinstance(e.payload, dict) and isinstance(
            e.payload.get("measured_s"), (int, float)
        ):
            dur = round(e.payload["measured_s"] * 1e6, 3)
            rows.append({**base, "ph": "X", "cat": "dispatch",
                         "ts": us(start_of(e)), "dur": dur})
        else:
            rows.append({**base, "ph": "i", "cat": e.kind, "s": "t"})
    out: dict[str, Any] = {"traceEvents": rows, "displayTimeUnit": "ms"}
    if meta:
        out["otherData"] = _payload_args(meta)
    return out


def to_speedscope(
    events: Iterable[Event],
    *,
    collector: Optional[TraceCollector] = None,
    name: str = "repro.trace",
    meta: Optional[dict[str, Any]] = None,
) -> dict[str, Any]:
    """Speedscope file: one sampled profile per track, spans as samples.

    ``meta`` (session provenance) titles the profile with the run's git SHA
    so stacked speedscope tabs from different runs stay distinguishable.
    """
    if meta and meta.get("git_sha") and name == "repro.trace":
        name = f"repro.trace@{meta['git_sha']}"
    spans = resolve_spans(sorted(events, key=lambda e: e.t), _tracker(collector))
    frames: list[dict[str, str]] = []
    frame_idx: dict[str, int] = {}

    def frame(n: str) -> int:
        if n not in frame_idx:
            frame_idx[n] = len(frames)
            frames.append({"name": n})
        return frame_idx[n]

    by_track: dict[str, list[Span]] = {}
    for s in spans:
        if s.dur > 0:
            by_track.setdefault(s.track, []).append(s)
    profiles = []
    for track, ss in sorted(by_track.items()):
        profiles.append({
            "type": "sampled",
            "name": track,
            "unit": "seconds",
            "startValue": 0.0,
            "endValue": sum(s.dur for s in ss),
            "samples": [[frame(s.name)] for s in ss],
            "weights": [s.dur for s in ss],
        })
    return {
        "$schema": "https://www.speedscope.app/file-format-schema.json",
        "name": name,
        "shared": {"frames": frames},
        "profiles": profiles,
        "activeProfileIndex": 0,
        "exporter": "repro.trace",
    }


def to_folded(
    events: Iterable[Event],
    *,
    collector: Optional[TraceCollector] = None,
    meta: Optional[dict[str, Any]] = None,  # accepted for exporter uniformity
) -> str:
    """Folded flamegraph stacks: ``track;name <microseconds>`` per line."""
    spans = resolve_spans(sorted(events, key=lambda e: e.t), _tracker(collector))
    agg: dict[str, int] = {}
    for s in spans:
        if s.dur <= 0:
            continue
        stack = f"{s.track};{s.name}"
        if isinstance(s.payload, dict) and "backend" in s.payload:
            stack += f";{s.payload['backend']}"
        agg[stack] = agg.get(stack, 0) + int(round(s.dur * 1e6))
    return "\n".join(f"{k} {v}" for k, v in sorted(agg.items())) + ("\n" if agg else "")


FORMATS = {
    "chrome": lambda evs, **kw: json.dumps(to_chrome_trace(evs, **kw), indent=1),
    "speedscope": lambda evs, **kw: json.dumps(to_speedscope(evs, **kw), indent=1),
    "folded": lambda evs, **kw: to_folded(evs, **kw),
}


def export(events: Iterable[Event], fmt: str, **kw: Any) -> str:
    """Render ``events`` in ``fmt`` (one of {chrome, speedscope, folded})."""
    try:
        render = FORMATS[fmt]
    except KeyError:
        raise ValueError(f"unknown format {fmt!r}; choose from {sorted(FORMATS)}") from None
    return render(events, **kw)
