"""Trace exporters: Chrome Trace Event JSON, speedscope, folded stacks.

Everything a standard viewer can open:

* :func:`to_chrome_trace` — the Trace Event Format (``traceEvents``) that
  Perfetto / ``chrome://tracing`` load directly.  Spawn/exit pairs become
  async ``b``/``e`` duration events **grouped by their root span id**, so a
  request and every descendant (prefill, nested lifecycles) nest on one
  async track exactly like the span tree; dispatch decisions become ``X``
  complete events spanning their measured execution with ``s``/``f`` flow
  links from the request span that caused them; device events (merged via
  :mod:`repro.trace.device`) become ``X`` rows on per-device tracks below
  the host tracks; loose marks/probes become ``i`` instants.
* :func:`to_speedscope` — an **evented** speedscope profile per track
  (open/close events follow the span tree, rebalanced where siblings
  overlap so the file always validates), https://speedscope.app loads it.
* :func:`to_folded` — ``track;name count`` folded stacks for classic
  ``flamegraph.pl`` / inferno tooling (counts in integer microseconds).
"""
from __future__ import annotations

import json
from typing import Any, Iterable, Optional

from repro.core.events import Event
from repro.trace.collector import (
    TRACKS,
    Span,
    TraceCollector,
    default_track,
    resolve_spans,
    span_tree,
)

PID = 1  # single-process traces; tracks are threads


def _track_ids(tracks: Iterable[str]) -> dict[str, int]:
    order = {t: i for i, t in enumerate(TRACKS)}
    # canonical tracks keep stable tids; custom tracks (including device:*)
    # get distinct tids after them (alphabetical), one viewer row each —
    # host rows therefore always render above device rows
    uniq = sorted(set(tracks), key=lambda t: (order.get(t, len(order)), t))
    return {t: i + 1 for i, t in enumerate(uniq)}


def _payload_args(payload: Any) -> dict[str, Any]:
    if isinstance(payload, dict):
        return {k: v if isinstance(v, (int, float, str, bool, type(None))) else repr(v)
                for k, v in payload.items()}
    if payload is None:
        return {}
    return {"payload": payload if isinstance(payload, (int, float, str, bool)) else repr(payload)}


def _tracker(collector: Optional[TraceCollector]):
    return collector.track_name if collector is not None else default_track


def _parent_index(events: Iterable[Event]) -> dict[int, int]:
    """span id -> parent id, from every event that carries both."""
    out: dict[int, int] = {}
    for e in events:
        if e.span and e.parent:
            out.setdefault(e.span, e.parent)
    return out


def _root_of(span: int, parents: dict[int, int]) -> int:
    """Topmost ancestor of ``span`` (cycle-guarded: parents precede children)."""
    seen = set()
    while span in parents and span not in seen:
        seen.add(span)
        span = parents[span]
    return span


def to_chrome_trace(
    events: Iterable[Event],
    *,
    collector: Optional[TraceCollector] = None,
    meta: Optional[dict[str, Any]] = None,
) -> dict[str, Any]:
    """Trace Event Format dict: ``{"traceEvents": [...], "otherData": ...}``.

    Timestamps are microseconds relative to the first event (Perfetto is
    happiest with small positive ``ts``).

    Stitched sessions (``meta["stitch"]``, see :mod:`repro.trace.stitch`)
    render **multi-process**: each input session's span-id range maps to its
    own Perfetto pid named by the process origin, and every re-linked
    cross-process parent link (a replica rpc span under a frontdoor route
    span) gets an ``s``/``f`` flow arrow crossing the two processes.
    Sessions without stitch metadata render exactly as before (one pid).
    """
    events = sorted(events, key=lambda e: e.t)
    track_name = _tracker(collector)
    tids = _track_ids(track_name(e) for e in events)
    parents = _parent_index(events)
    spawn_of = {e.span: e for e in events if e.kind == "spawn" and e.span}
    # any span-carrying, non-exit event (route instants included): flow-arrow
    # sources for cross-process parent links
    span_event_of: dict[int, Event] = {}
    for e in events:
        if e.span and e.kind != "exit":
            span_event_of.setdefault(e.span, e)

    # (lo, hi, pid, origin) per stitched input session, from the provenance
    # manifest's namespaced span-id ranges
    procs: list[tuple[int, int, int, str]] = []
    for i, inp in enumerate(((meta or {}).get("stitch") or {}).get("inputs", [])):
        ids = inp.get("span_ids") or [0, -1]
        procs.append((int(ids[0]), int(ids[1]), i + 1,
                      str(inp.get("origin") or f"proc{i}")))

    def pid_of_id(sid: int) -> int:
        for lo, hi, pid, _ in procs:
            if lo <= sid <= hi:
                return pid
        return PID

    def pid_of(e: Event) -> int:
        if not procs:
            return PID
        sid = e.span or e.parent
        return pid_of_id(sid) if sid else procs[0][2]

    def start_of(e: Event) -> float:
        # dispatch events are recorded at completion; their X row starts
        # measured_s earlier, and the epoch must cover that
        if e.kind == "dispatch" and isinstance(e.payload, dict) and isinstance(
            e.payload.get("measured_s"), (int, float)
        ):
            return e.t - e.payload["measured_s"]
        return e.t

    def proc_root_of(span: int) -> int:
        """Topmost ancestor of ``span`` *within its own process* — async
        grouping must not follow a re-linked parent into another pid
        (Perfetto scopes async ids per pid)."""
        seen = set()
        while span in parents and span not in seen:
            p = parents[span]
            if procs and pid_of_id(p) != pid_of_id(span):
                break
            seen.add(span)
            span = p
        return span

    def async_id(e: Event) -> Optional[str]:
        """Async grouping id for spawn/exit.  Parent-linked spans share their
        ROOT span's id, so Perfetto nests the whole subtree by timestamp on
        one async track — real parent nesting, not per-tid LIFO guessing.
        Unlinked spans fall back to their own id / payload identity."""
        if e.span:
            return str(proc_root_of(e.span))
        try:
            hash(e.payload)
        except TypeError:
            return None
        if e.payload is None:
            return None
        return f"{e.name}:{e.payload!r}"

    def flow_source(e: Event) -> Optional[Event]:
        """The spawn event a dispatch decision's flow arrow starts from: the
        nearest ancestor on the ``request`` track (the paper's unit of
        concurrency), else the direct parent span."""
        sid, fallback = e.parent, None
        while sid:
            src = spawn_of.get(sid)
            if src is None:
                break
            if fallback is None:
                fallback = src
            if track_name(src) == "request":
                return src
            sid = parents.get(sid, 0)
        return fallback

    t0 = min((start_of(e) for e in events), default=0.0)
    us = lambda t: round((t - t0) * 1e6, 3)  # noqa: E731

    rows: list[dict[str, Any]] = []
    if procs:
        for _, _, pid, origin in procs:
            rows.append({"ph": "M", "pid": pid, "name": "process_name",
                         "args": {"name": origin}})
        for pid, track in sorted({(pid_of(e), track_name(e)) for e in events}):
            rows.append({"ph": "M", "pid": pid, "tid": tids[track],
                         "name": "thread_name", "args": {"name": track}})
    else:
        rows.append({"ph": "M", "pid": PID, "name": "process_name",
                     "args": {"name": "repro"}})
        for track, tid in tids.items():
            rows.append({"ph": "M", "pid": PID, "tid": tid, "name": "thread_name",
                         "args": {"name": track}})
    n_flows = 0
    for e in events:
        tid = tids[track_name(e)]
        pid = pid_of(e)
        base = {"name": e.name, "pid": pid, "tid": tid, "ts": us(e.t),
                "args": _payload_args(e.payload)}
        if e.span:
            base["args"]["span"] = e.span
        if e.parent:
            base["args"]["parent"] = e.parent
        if e.kind in ("spawn", "exit"):
            # async b/e (grouped by root span id -> nested subtree) when the
            # event carries an identity; sync B/E (viewer LIFO) only for
            # legacy identity-less events
            aid = async_id(e)
            ph = {"spawn": ("b" if aid else "B"), "exit": ("e" if aid else "E")}[e.kind]
            row = {**base, "ph": ph, "cat": "lifecycle"}
            if aid:
                row["id"] = aid
            rows.append(row)
            if e.kind == "spawn" and procs and e.parent:
                # re-linked remote parent: draw the hop crossing processes
                src = span_event_of.get(e.parent)
                if src is not None and pid_of(src) != pid:
                    n_flows += 1
                    fid = str(n_flows)
                    rows.append({"ph": "s", "cat": "flow", "name": "rpc",
                                 "id": fid, "pid": pid_of(src),
                                 "tid": tids[track_name(src)], "ts": us(src.t)})
                    rows.append({"ph": "f", "bp": "e", "cat": "flow",
                                 "name": "rpc", "id": fid, "pid": pid,
                                 "tid": tid, "ts": us(e.t)})
        elif e.kind == "dispatch" and isinstance(e.payload, dict) and isinstance(
            e.payload.get("measured_s"), (int, float)
        ):
            dur = round(e.payload["measured_s"] * 1e6, 3)
            rows.append({**base, "ph": "X", "cat": "dispatch",
                         "ts": us(start_of(e)), "dur": dur})
            src = flow_source(e)
            if src is not None:
                # flow arrow: the request/step span that caused this dispatch
                n_flows += 1
                fid = str(n_flows)
                rows.append({"ph": "s", "cat": "flow", "name": "dispatch",
                             "id": fid, "pid": pid_of(src),
                             "tid": tids[track_name(src)], "ts": us(src.t)})
                rows.append({"ph": "f", "bp": "e", "cat": "flow", "name": "dispatch",
                             "id": fid, "pid": pid, "tid": tid,
                             "ts": us(start_of(e))})
        elif e.kind == "device" and isinstance(e.payload, dict) and isinstance(
            e.payload.get("dur_s"), (int, float)
        ):
            rows.append({**base, "ph": "X", "cat": "device",
                         "dur": round(e.payload["dur_s"] * 1e6, 3)})
        else:
            rows.append({**base, "ph": "i", "cat": e.kind, "s": "t"})
    out: dict[str, Any] = {"traceEvents": rows, "displayTimeUnit": "ms"}
    if meta:
        out["otherData"] = _payload_args(meta)
    return out


def _evented_profile(track: str, spans: list[Span], epoch: float, frame) -> dict[str, Any]:
    """One speedscope ``evented`` profile for a track's spans.

    ``frame`` interns a span name into the shared frame table.  Open/close
    events are emitted in timestamp order with stack discipline enforced:
    when a span closes while a later-opened sibling is still on the stack
    (concurrent requests interleave on one track), the intervening frames
    are closed and immediately reopened — the rebalancing every chrome-trace
    importer applies, preserving per-frame weight while keeping the file
    valid.
    """
    # (t, kind, idx): closes sort before opens at the same instant so a
    # zero-gap back-to-back pair doesn't nest; ties between closes resolve
    # by reverse open order via the stack rebalancing below
    marks: list[tuple[float, int, int]] = []
    for i, s in enumerate(spans):
        marks.append((s.t0, 1, i))
        marks.append((s.t1, 0, i))
    marks.sort(key=lambda m: (m[0], m[1]))
    events: list[dict[str, Any]] = []
    stack: list[int] = []

    def emit(typ: str, idx: int, t: float) -> None:
        events.append({"type": typ, "frame": frame(spans[idx].name), "at": t - epoch})

    for t, kind, idx in marks:
        if kind == 1:
            stack.append(idx)
            emit("O", idx, t)
        else:
            if idx not in stack:
                continue
            reopen: list[int] = []
            while stack and stack[-1] != idx:
                top = stack.pop()
                emit("C", top, t)
                reopen.append(top)
            stack.pop()
            emit("C", idx, t)
            for top in reversed(reopen):
                stack.append(top)
                emit("O", top, t)
    end = max((s.t1 for s in spans), default=epoch)
    while stack:  # defensive: truncated spans are pre-closed by resolve_spans
        emit("C", stack.pop(), end)
    return {
        "type": "evented",
        "name": track,
        "unit": "seconds",
        "startValue": min((s.t0 for s in spans), default=epoch) - epoch,
        "endValue": end - epoch,
        "events": events,
    }


def to_speedscope(
    events: Iterable[Event],
    *,
    collector: Optional[TraceCollector] = None,
    name: str = "repro.trace",
    meta: Optional[dict[str, Any]] = None,
) -> dict[str, Any]:
    """Speedscope file: one **evented** profile per track.

    Each track's spans become open/close frame events whose nesting follows
    the span tree (a request frame encloses its prefill frame, which
    encloses nothing a sibling owns), instead of the flat one-weighted-
    sample-per-span profiles the exporter used to emit.  ``meta`` (session
    provenance) titles the profile with the run's git SHA so stacked
    speedscope tabs from different runs stay distinguishable.
    """
    if meta and meta.get("git_sha") and name == "repro.trace":
        name = f"repro.trace@{meta['git_sha']}"
    spans = resolve_spans(sorted(events, key=lambda e: e.t), _tracker(collector))
    frames: list[dict[str, str]] = []
    frame_idx: dict[str, int] = {}

    def frame(n: str) -> int:
        if n not in frame_idx:
            frame_idx[n] = len(frames)
            frames.append({"name": n})
        return frame_idx[n]

    by_track: dict[str, list[Span]] = {}
    for s in spans:
        if s.dur > 0:
            by_track.setdefault(s.track, []).append(s)
    epoch = min((s.t0 for ss in by_track.values() for s in ss), default=0.0)
    profiles = [
        _evented_profile(track, ss, epoch, frame)
        for track, ss in sorted(by_track.items())
    ]
    return {
        "$schema": "https://www.speedscope.app/file-format-schema.json",
        "name": name,
        "shared": {"frames": frames},
        "profiles": profiles,
        "activeProfileIndex": 0,
        "exporter": "repro.trace",
    }


def to_folded(
    events: Iterable[Event],
    *,
    collector: Optional[TraceCollector] = None,
    meta: Optional[dict[str, Any]] = None,  # accepted for exporter uniformity
) -> str:
    """Folded flamegraph stacks: full ancestor paths, one line per leaf.

    Parent links turn the old flat ``track;name`` pairs into real stacks —
    ``request;prefill;serve_prefill`` style — weighted by each node's
    exclusive time so the flamegraph's column widths sum correctly.
    """
    spans = resolve_spans(sorted(events, key=lambda e: e.t), _tracker(collector))
    agg: dict[str, int] = {}

    def leaf_name(s: Span) -> str:
        n = s.name
        if isinstance(s.payload, dict) and "backend" in s.payload:
            n += f";{s.payload['backend']}"
        return n

    def walk(node, prefix: str) -> None:
        s = node.span
        stack = f"{prefix};{leaf_name(s)}" if prefix else f"{s.track};{leaf_name(s)}"
        us = int(round(node.exclusive * 1e6))
        if s.dur > 0 and us > 0:
            agg[stack] = agg.get(stack, 0) + us
        for c in node.children:
            walk(c, stack)

    for root in span_tree(spans):
        walk(root, "")
    return "\n".join(f"{k} {v}" for k, v in sorted(agg.items())) + ("\n" if agg else "")


FORMATS = {
    "chrome": lambda evs, **kw: json.dumps(to_chrome_trace(evs, **kw), indent=1),
    "speedscope": lambda evs, **kw: json.dumps(to_speedscope(evs, **kw), indent=1),
    "folded": lambda evs, **kw: to_folded(evs, **kw),
}


def export(events: Iterable[Event], fmt: str, **kw: Any) -> str:
    """Render ``events`` in ``fmt`` (one of {chrome, speedscope, folded})."""
    try:
        render = FORMATS[fmt]
    except KeyError:
        raise ValueError(f"unknown format {fmt!r}; choose from {sorted(FORMATS)}") from None
    return render(events, **kw)
