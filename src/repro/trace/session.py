"""Run snapshots: one JSON file per run, reloadable across processes.

A *session* is everything a later analysis (or a warm-started dispatcher)
needs from a run: the event trace, every dispatch decision, the measured
:class:`~repro.dispatch.profiles.ProfileStore`, the chip model it was priced
against, and provenance metadata (schema version, git SHA, wall-clock
timestamp, argv).  ``launch.serve --trace-out t.json`` writes one;
``python -m repro.trace {report,export,diff}`` consumes them; ``--profile-in``
feeds the stored profiles back into a new dispatcher so it skips the
exploration phase entirely (the measured warm-start crossover).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import subprocess
import sys
import time
from typing import Any, Optional

from repro.core.events import Event, EventLog
from repro.dispatch.profiles import ProfileStore
from repro.trace.collector import Span, SpanNode, resolve_spans, span_tree

SESSION_SCHEMA = "repro.trace.session/v1"
ARTIFACT_SCHEMA = "repro.bench/v1"


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_metadata(extra: Optional[dict[str, Any]] = None) -> dict[str, Any]:
    """Provenance stamp shared by sessions and bench artifacts."""
    meta = {
        "git_sha": git_sha(),
        "created_unix": time.time(),
        "argv": list(sys.argv),
        "python": sys.version.split()[0],
        # paired monotonic/wall samples taken at the same instant: the clock
        # anchor stitch uses to map this process's event timestamps
        # (monotonic, arbitrary epoch) onto a shared wall-clock timeline
        "clock": {"monotonic": time.monotonic(), "unix": time.time()},
    }
    if extra:
        meta.update(extra)
    return meta


def artifact_meta(extra: Optional[dict[str, Any]] = None) -> dict[str, Any]:
    """Stamp for benchmark output JSON (``repro.trace diff``-comparable)."""
    from repro.hw.specs import default_chip

    meta = {"schema": ARTIFACT_SCHEMA, **run_metadata(extra)}
    meta["chip"] = dataclasses.asdict(default_chip())
    return meta


def _sanitize(obj: Any) -> Any:
    """Round-trip ``obj`` through JSON semantics (repr for the unencodable)."""
    return json.loads(json.dumps(obj, default=repr))


@dataclasses.dataclass
class Session:
    """An in-memory run snapshot (see module docstring for the file story)."""

    meta: dict[str, Any]
    events: list[Event]
    dropped: int = 0
    capacity: Optional[int] = None
    decisions: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    store: Optional[ProfileStore] = None
    chip: Optional[dict[str, Any]] = None
    collector_stats: Optional[dict[str, Any]] = None

    # -- construction --------------------------------------------------------

    @classmethod
    def capture(
        cls,
        log: EventLog,
        *,
        dispatcher: Any = None,
        store: Optional[ProfileStore] = None,
        meta: Optional[dict[str, Any]] = None,
        collector_stats: Optional[dict[str, Any]] = None,
    ) -> "Session":
        """Snapshot a live run.

        ``dispatcher`` (a :class:`repro.dispatch.dispatcher.Dispatcher`)
        contributes its decisions, profile store and chip model; any of the
        three can also be absent (trace-only runs).  ``collector_stats``
        (``TraceCollector.stats()``) rides along so drop accounting survives
        serialisation; when omitted it is pulled from the log if available.
        """
        decisions: list[dict[str, Any]] = []
        chip = None
        if dispatcher is not None:
            decisions = [d.payload() for d in dispatcher.decisions]
            store = store if store is not None else dispatcher.store
            chip = dataclasses.asdict(dispatcher.chip)
        if collector_stats is None:
            stats_fn = getattr(log, "stats", None)
            if callable(stats_fn):
                collector_stats = stats_fn()
        return cls(
            meta={"schema": SESSION_SCHEMA, **run_metadata(meta)},
            events=log.events(),
            dropped=log.dropped,
            capacity=log.maxlen,
            decisions=decisions,
            store=store,
            chip=chip,
            collector_stats=collector_stats,
        )

    # -- persistence ---------------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return _sanitize({
            "meta": self.meta,
            "trace": {
                "dropped": self.dropped,
                "capacity": self.capacity,
                "stats": self.collector_stats,
                "events": [dataclasses.asdict(e) for e in self.events],
            },
            "dispatch": {
                "decisions": self.decisions,
                "profiles": json.loads(self.store.to_json()) if self.store else None,
                "chip": self.chip,
            },
        })

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path

    @classmethod
    def from_dict(cls, raw: dict[str, Any]) -> "Session":
        trace = raw.get("trace", {})
        disp = raw.get("dispatch", {})
        profiles = disp.get("profiles")
        return cls(
            meta=raw.get("meta", {}),
            events=[Event(**row) for row in trace.get("events", [])],
            dropped=trace.get("dropped", 0),
            capacity=trace.get("capacity"),
            decisions=disp.get("decisions", []),
            store=ProfileStore.from_json(json.dumps(profiles)) if profiles else None,
            chip=disp.get("chip"),
            collector_stats=trace.get("stats"),
        )

    @classmethod
    def load(cls, path: str) -> "Session":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- analysis ------------------------------------------------------------

    def spans(self) -> list[Span]:
        return resolve_spans(sorted(self.events, key=lambda e: e.t))

    def span_tree(self) -> list[SpanNode]:
        """The session's spans folded into a parent-linked forest."""
        return span_tree(self.spans())

    def tree_report(self) -> list[dict[str, Any]]:
        """Aggregated span-tree rows (the ``report --tree`` view).

        Sibling spans are grouped by (track, name) at each depth — a serve
        run shows one ``request`` row with count 12, its ``prefill`` child
        row, and the ``dispatch`` decisions nested below — with inclusive
        (span duration) and exclusive (minus children) totals per node.
        """
        rows: list[dict[str, Any]] = []

        def visit(nodes: list[SpanNode], depth: int) -> None:
            groups: dict[tuple[str, str], list[SpanNode]] = {}
            for n in nodes:
                groups.setdefault((n.span.track, n.span.name), []).append(n)
            for (track, name), ns in sorted(
                groups.items(), key=lambda kv: min(x.span.t0 for x in kv[1])
            ):
                rows.append({
                    "depth": depth,
                    "track": track,
                    "name": name,
                    "count": len(ns),
                    "inclusive_ms": sum(n.span.dur for n in ns) * 1e3,
                    "exclusive_ms": sum(n.exclusive for n in ns) * 1e3,
                    "truncated": sum(1 for n in ns if n.span.truncated),
                })
                visit([c for n in ns for c in n.children], depth + 1)

        visit(self.span_tree(), 0)
        return rows

    def path_report(self, max_depth: int = 4) -> dict[str, dict[str, Any]]:
        """Exclusive time aggregated per span-tree *path* (depth-capped).

        A path is the ``/``-joined chain of span names from a root down
        (``request/prefill/matmul``); nodes deeper than ``max_depth`` fold
        their exclusive time into their depth-capped ancestor, so totals are
        conserved whatever the cap.  Truncated spans contribute their
        (force-closed) children's structure but no time of their own — a cut
        exit is not a measurement.
        """
        out: dict[str, dict[str, Any]] = {}

        def visit(node: SpanNode, names: tuple[str, ...]) -> None:
            names = names + (node.span.name,)
            capped = names[:max_depth]
            path = "/".join(capped)
            row = out.setdefault(path, {"count": 0, "exclusive_ms": 0.0,
                                        "truncated": 0, "depth": len(capped)})
            if node.span.truncated:
                row["truncated"] += 1
            else:
                row["exclusive_ms"] += node.exclusive * 1e3
                if len(names) <= max_depth:
                    row["count"] += 1
            for c in node.children:
                visit(c, names)

        for root in self.span_tree():
            visit(root, ())
        return {p: r for p, r in out.items() if r["count"] or r["truncated"]}

    def report(self) -> dict[str, Any]:
        """Deterministic per-op / per-backend tables (the CLI renders these).

        Computed only from serialised fields, so ``save → load → report`` is
        bit-identical to reporting the live session.
        """
        spans = self.spans()
        lat: dict[str, dict[str, float]] = {}
        truncated = 0
        for s in spans:
            if s.truncated:
                # force-closed at an arbitrary cut point, not a measurement:
                # one evicted exit would otherwise inflate mean/max by the
                # whole remaining run and trip the diff --fail-over-pct gate
                truncated += 1
                continue
            if s.dur <= 0:
                continue
            row = lat.setdefault(f"{s.track}/{s.name}", {"count": 0, "total_ms": 0.0,
                                                         "min_ms": float("inf"), "max_ms": 0.0})
            ms = s.dur * 1e3
            row["count"] += 1
            row["total_ms"] += ms
            row["min_ms"] = min(row["min_ms"], ms)
            row["max_ms"] = max(row["max_ms"], ms)
        for row in lat.values():
            row["mean_ms"] = row["total_ms"] / row["count"]

        by_op: dict[str, dict[str, dict[str, float]]] = {}
        by_source: dict[str, int] = {}
        for d in self.decisions:
            op, backend = d.get("op", "?"), d.get("backend", "?")
            cell = by_op.setdefault(op, {}).setdefault(
                backend, {"count": 0, "total_ms": 0.0, "measured": 0}
            )
            cell["count"] += 1
            if isinstance(d.get("measured_s"), (int, float)):
                cell["measured"] += 1
                cell["total_ms"] += d["measured_s"] * 1e3
            src = d.get("source", "?")
            by_source[src] = by_source.get(src, 0) + 1
        for backends in by_op.values():
            for cell in backends.values():
                cell["mean_ms"] = cell["total_ms"] / cell["measured"] if cell["measured"] else None

        cstats = self.collector_stats or {}
        return {
            "meta": {k: self.meta.get(k) for k in ("schema", "git_sha", "created_unix")},
            "events": len(self.events),
            "dropped": self.dropped,
            # loss accounting at top level: a report whose rings shed events
            # should say so up front, not three dicts deep in session meta
            "dropped_by_track": {k: v for k, v in
                                 (cstats.get("dropped_by_track") or {}).items() if v},
            "sampled_out": cstats.get("sampled_out", 0),
            "truncated_spans": truncated,
            "latency": lat,
            "dispatch": {
                "decisions": len(self.decisions),
                "by_op": by_op,
                "by_source": by_source,
                "profiled_keys": len(self.store) if self.store else 0,
            },
        }


def is_session(raw: dict[str, Any]) -> bool:
    return raw.get("meta", {}).get("schema") == SESSION_SCHEMA


def load_profile_store(path: str) -> ProfileStore:
    """Read a ProfileStore from a session file OR a bare store JSON file."""
    with open(path) as f:
        raw = json.load(f)
    if is_session(raw):
        profiles = raw.get("dispatch", {}).get("profiles")
        if not profiles:
            raise ValueError(f"session {path} carries no profile store")
        return ProfileStore.from_json(json.dumps(profiles))
    if "entries" not in raw:
        # reject arbitrary JSON (a chrome export, a bench artifact, …): a
        # silently-empty store would make --profile-in a no-op with no signal
        raise ValueError(
            f"{path} is neither a trace session nor a ProfileStore JSON "
            "(expected an 'entries' key)"
        )
    return ProfileStore.from_json(json.dumps(raw))


def load_profile_stores(paths: list[str]) -> ProfileStore:
    """Load one or more profile files and merge them into a single store."""
    stores = [load_profile_store(p) for p in paths]
    base = stores[0]
    for s in stores[1:]:
        base.merge(s)
    return base


def age_out_profiles(store: ProfileStore, chip_name: str) -> list[dict[str, str]]:
    """Invalidate ``--profile-in`` entries measured on different code/hardware.

    Compares each entry's git SHA / chip stamp against the *current* repo SHA
    and the given chip, evicting mismatches so the dispatcher re-explores
    instead of trusting stale timings.  Every eviction is logged to stderr
    with its reason (drivers surface the count in their JSON output).
    """
    aged = store.age_out(git_sha=git_sha(), chip=chip_name)
    for a in aged:
        print(f"profile-in: aged out {a['key']}: {a['reason']}", file=sys.stderr)
    return aged


# -- diffing ----------------------------------------------------------------


def diff_sessions(a: Session, b: Session) -> dict[str, Any]:
    """Per-key latency + dispatch-choice deltas between two sessions."""
    ra, rb = a.report(), b.report()
    lat: dict[str, Any] = {}
    for key in sorted(set(ra["latency"]) | set(rb["latency"])):
        la, lb = ra["latency"].get(key), rb["latency"].get(key)
        if la and lb:
            lat[key] = {
                "a_mean_ms": la["mean_ms"], "b_mean_ms": lb["mean_ms"],
                "delta_pct": (lb["mean_ms"] / la["mean_ms"] - 1.0) * 100 if la["mean_ms"] else None,
            }
        else:
            lat[key] = {"only_in": "a" if la else "b"}

    def modal_backend(rep: dict, op: str) -> Optional[str]:
        cells = rep["dispatch"]["by_op"].get(op)
        return max(cells, key=lambda b: cells[b]["count"]) if cells else None

    choices: dict[str, Any] = {}
    ops = set(ra["dispatch"]["by_op"]) | set(rb["dispatch"]["by_op"])
    for op in sorted(ops):
        ca, cb = modal_backend(ra, op), modal_backend(rb, op)
        choices[op] = {"a": ca, "b": cb, "changed": ca != cb}
    return {
        "a": ra["meta"], "b": rb["meta"],
        "latency": lat,
        "dispatch_choices": choices,
        "by_source": {"a": ra["dispatch"]["by_source"], "b": rb["dispatch"]["by_source"]},
    }


def path_diff(a: Session, b: Session, max_depth: int = 4) -> list[dict[str, Any]]:
    """Diff mean exclusive time per span-tree path (``diff --by-path``).

    Attributes a regression to the tree node that actually grew rather than
    the whole request: a slower ``request/prefill/matmul`` shows up on that
    path, while ``request`` itself (exclusive of children) stays flat.
    Rows are sorted most-changed first; paths present on only one side are
    reported but carry no delta.
    """
    ra, rb = a.path_report(max_depth), b.path_report(max_depth)
    rows: list[dict[str, Any]] = []
    for path in sorted(set(ra) | set(rb)):
        pa, pb = ra.get(path), rb.get(path)
        if pa and pb and pa["count"] and pb["count"]:
            ma = pa["exclusive_ms"] / pa["count"]
            mb = pb["exclusive_ms"] / pb["count"]
            rows.append({
                "path": path,
                "a_mean_exclusive_ms": ma,
                "b_mean_exclusive_ms": mb,
                "a_count": pa["count"],
                "b_count": pb["count"],
                "delta_pct": (mb / ma - 1.0) * 100 if ma else None,
            })
        else:
            present = pa if pa else pb
            rows.append({"path": path, "only_in": "a" if pa else "b",
                         "count": present["count"] if present else 0})
    rows.sort(key=lambda r: -(abs(r["delta_pct"])
                              if isinstance(r.get("delta_pct"), (int, float))
                              else -1.0))
    return rows


def path_regressions(
    rows: list[dict[str, Any]], fail_over_pct: float
) -> list[dict[str, Any]]:
    """Regressed rows from a :func:`path_diff` (feeds the CI exit-3 gate)."""
    regs: list[dict[str, Any]] = []
    for r in rows:
        d = r.get("delta_pct")
        if isinstance(d, (int, float)) and d > fail_over_pct:
            regs.append({"key": r["path"], "a": r["a_mean_exclusive_ms"],
                         "b": r["b_mean_exclusive_ms"], "delta_pct": d,
                         "kind": "path-exclusive"})
    return regs


def _numeric_leaves(obj: Any, prefix: str = "") -> dict[str, float]:
    out: dict[str, float] = {}
    if isinstance(obj, bool):
        return out
    if isinstance(obj, (int, float)):
        out[prefix or "<root>"] = float(obj)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_numeric_leaves(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(_numeric_leaves(v, f"{prefix}[{i}]"))
    return out


def diff_artifacts(a: dict[str, Any], b: dict[str, Any], top: int = 20) -> dict[str, Any]:
    """Generic numeric diff for stamped benchmark artifacts (out_all.json).

    Skips provenance stamps (timestamps/SHAs always differ) and ranks shared
    numeric leaves by relative change.
    """
    la, lb = _numeric_leaves(a), _numeric_leaves(b)
    skip = ("meta.", "created_unix", "timestamp")
    rows = []
    for key in sorted(set(la) & set(lb)):
        if any(s in key for s in skip):
            continue
        va, vb = la[key], lb[key]
        if va == vb:
            continue
        # None, not inf, for 0 -> nonzero: json.dumps(Infinity) is not JSON
        rel = (vb / va - 1.0) * 100 if va else None
        rows.append({"key": key, "a": va, "b": vb, "delta_pct": rel})
    rows.sort(key=lambda r: -(abs(r["delta_pct"]) if r["delta_pct"] is not None else float("inf")))
    return {
        "a_meta": a.get("meta", {}).get("git_sha"),
        "b_meta": b.get("meta", {}).get("git_sha"),
        "changed": rows[:top],
        "total_changed": len(rows),
        "only_in_a": sorted(set(la) - set(lb))[:top],
        "only_in_b": sorted(set(lb) - set(la))[:top],
    }


# -- regression gating (CI) --------------------------------------------------
#
# `repro.trace diff --fail-over-pct P` turns a diff into a failing check:
# latency-like metrics that grew by more than P%, or throughput-like metrics
# that shrank by more than P%, are regressions.  Keys are classified by their
# leaf name so provenance stamps and counters never trip the gate.

_THROUGHPUT_HINTS = ("per_s", "throughput", "flops")
_TIME_HINTS = ("latency", "wall", "duration")
_TIME_SUFFIXES = ("_ms", "_s", "_us", "_seconds")


def _leaf_name(key: str) -> str:
    return key.rsplit(".", 1)[-1].split("[", 1)[0].lower()


def artifact_regressions(
    a: dict[str, Any], b: dict[str, Any], fail_over_pct: float
) -> list[dict[str, Any]]:
    """Regressed time/throughput leaves between two stamped bench artifacts."""
    la, lb = _numeric_leaves(a), _numeric_leaves(b)
    skip = ("meta.", "created_unix", "timestamp")
    regs: list[dict[str, Any]] = []
    for key in sorted(set(la) & set(lb)):
        if any(s in key for s in skip):
            continue
        va, vb = la[key], lb[key]
        if va == vb or not va:
            continue
        delta = (vb / va - 1.0) * 100
        leaf = _leaf_name(key)
        if any(h in leaf for h in _THROUGHPUT_HINTS):
            if delta < -fail_over_pct:
                regs.append({"key": key, "a": va, "b": vb, "delta_pct": delta,
                             "kind": "throughput"})
        elif leaf.endswith(_TIME_SUFFIXES) or any(h in leaf for h in _TIME_HINTS):
            if delta > fail_over_pct:
                regs.append({"key": key, "a": va, "b": vb, "delta_pct": delta,
                             "kind": "latency"})
    return regs


def session_regressions(
    diff: dict[str, Any], fail_over_pct: float
) -> list[dict[str, Any]]:
    """Regressed per-track latency rows from a :func:`diff_sessions` output."""
    regs: list[dict[str, Any]] = []
    for key, row in sorted(diff.get("latency", {}).items()):
        d = row.get("delta_pct")
        if isinstance(d, (int, float)) and d > fail_over_pct:
            regs.append({"key": key, "a": row["a_mean_ms"], "b": row["b_mean_ms"],
                         "delta_pct": d, "kind": "latency"})
    return regs
