"""Analysis CLI for trace sessions.

  PYTHONPATH=src python -m repro.trace report  t.json
  PYTHONPATH=src python -m repro.trace export  t.json --format chrome -o t.chrome.json
  PYTHONPATH=src python -m repro.trace diff    a.json b.json [--fail-over-pct 25]
  PYTHONPATH=src python -m repro.trace compact run_dir/ -o session.json
  PYTHONPATH=src python -m repro.trace tail    run_dir/ [--once]
  PYTHONPATH=src python -m repro.trace device  run_dir/ [--json]
  PYTHONPATH=src python -m repro.trace stitch  frontdoor_dir/ [replica_dir/...] -o stitched.json
  PYTHONPATH=src python -m repro.trace hops    stitched.json [--json]
  PYTHONPATH=src python -m repro.trace push-profiles run_dir/ --fleet http://host:8377

``report`` prints per-op / per-backend latency tables for one session —
``--tree`` renders the span hierarchy instead (indented parent/child nodes
with inclusive/exclusive times); ``export`` renders it for a standard viewer
(Perfetto / speedscope / flamegraph.pl); both accept ``--device-trace DIR``
to fold a ``jax.profiler`` dump under the host spans first (see
:mod:`repro.trace.device`).  ``diff`` compares two sessions — or two stamped
benchmark artifacts (``benchmarks/out_all.json``) — across runs / PRs, and
with ``--fail-over-pct`` exits non-zero on latency/throughput regressions
past the threshold (the CI gate); ``compact`` folds a streaming segment
directory (``--trace-dir``) back into the one-file session format.
``report``, ``export`` and ``diff`` also accept segment directories directly.

``stitch`` merges a frontdoor session with its replica sessions into one
cross-process timeline (span-id namespacing, handshake clock-skew
correction, remote-parent re-linking — see :mod:`repro.trace.stitch`);
replica dirs announced in the frontdoor manifest are discovered
automatically, so ``stitch <frontdoor-dir>`` alone stitches the whole
fleet.  ``hops`` prints the per-hop latency decomposition
(frontdoor_queue | network | replica_queue | service) recorded on each
routed request, with the sum-vs-end-to-end consistency check.  ``report``
also accepts several sessions at once — they are stitched first, so span
ids from different processes never collide.

``tail`` follows a live ``--trace-dir`` like ``tail -f`` (one line per event
with track + duration; ``--once`` drains and exits); ``device`` summarises a
run's device side — live-capture window coverage, per-device time, and the
annotated-vs-time-window alignment ratio (see :mod:`repro.trace.liveprof`);
``push-profiles`` backfills the fleet profile service (:mod:`repro.fleet`)
from a recorded session or segment directory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any

from repro.trace.export import FORMATS
from repro.trace.export import export as render
from repro.trace.session import (
    Session,
    artifact_regressions,
    diff_artifacts,
    diff_sessions,
    is_session,
    path_diff,
    path_regressions,
    session_regressions,
)
from repro.trace.stream import (
    MANIFEST_NAME,
    load_any,
    load_metrics_timeline,
    load_stream,
    tail_stream,
)

EXIT_REGRESSION = 3  # distinct from argparse (2) and generic failure (1)


def _fmt_ms(v: Any) -> str:
    return f"{v:10.3f}" if isinstance(v, (int, float)) else f"{'-':>10}"


def _print_report(rep: dict[str, Any]) -> None:
    m = rep["meta"]
    print(f"session  schema={m.get('schema')}  git={m.get('git_sha')}  "
          f"created={m.get('created_unix')}")
    print(f"events   {rep['events']}  (dropped by ring: {rep['dropped']})"
          + (f"  ({rep['truncated_spans']} truncated spans excluded)"
             if rep.get("truncated_spans") else ""))
    dbt = {k or "main": v for k, v in (rep.get("dropped_by_track") or {}).items() if v}
    if dbt:
        print(f"WARNING: ring drops by track: {dbt}")
    if rep.get("sampled_out"):
        print(f"sampled out (adaptive capture shedding): {rep['sampled_out']} events")
    if rep["latency"]:
        print(f"\n{'track/name':<28}{'count':>7}{'mean_ms':>10}{'min_ms':>10}{'max_ms':>10}")
        for key, row in sorted(rep["latency"].items()):
            print(f"{key:<28}{row['count']:>7}"
                  + _fmt_ms(row["mean_ms"]) + _fmt_ms(row["min_ms"]) + _fmt_ms(row["max_ms"]))
    d = rep["dispatch"]
    if d["decisions"]:
        print(f"\ndispatch: {d['decisions']} decisions, {d['profiled_keys']} profiled keys, "
              f"sources={d['by_source']}")
        print(f"{'op':<22}{'backend':<10}{'count':>7}{'mean_ms':>10}")
        for op, backends in sorted(d["by_op"].items()):
            for b, cell in sorted(backends.items()):
                print(f"{op:<22}{b:<10}{cell['count']:>7}" + _fmt_ms(cell.get("mean_ms")))


def _print_tree(rows: list[dict[str, Any]]) -> None:
    print(f"{'span tree':<44}{'count':>7}{'incl_ms':>11}{'excl_ms':>11}")
    for row in rows:
        label = "  " * row["depth"] + f"{row['track']}/{row['name']}"
        if row["truncated"]:
            label += " …"  # exits evicted / trace cut while open
        print(f"{label:<44}{row['count']:>7}"
              f"{row['inclusive_ms']:>11.3f}{row['exclusive_ms']:>11.3f}")


def _maybe_merge_device(sess: Session, args: argparse.Namespace) -> int:
    """Fold a ``--device-trace`` dump into the loaded session.

    Returns 0 on success (or nothing to do), 2 on a bad dump — an
    xplane-only directory (no chrome trace without xprof installed) or a
    missing path gets a one-line error instead of a traceback."""
    if not getattr(args, "device_trace", None):
        return 0
    from repro.trace.device import merge_device_trace

    try:
        n = merge_device_trace(sess, args.device_trace,
                               offset_s=args.device_offset_s)
    except (ValueError, FileNotFoundError) as exc:
        print(f"error: --device-trace {args.device_trace}: {exc}",
              file=sys.stderr)
        return 2
    print(f"merged {n} device events from {args.device_trace}",
          file=sys.stderr)
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    if len(args.session) > 1:
        # several sessions from different processes: merge through the
        # stitcher so their span ids are namespaced (and remote parents
        # re-linked) instead of silently colliding
        from repro.trace.stitch import merge_for_report

        sess = merge_for_report(args.session)
    else:
        sess = load_any(args.session[0])
    rc = _maybe_merge_device(sess, args)
    if rc:
        return rc
    if args.tree:
        rows = sess.tree_report()
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            _print_tree(rows)
        return 0
    rep = sess.report()
    if args.json:
        print(json.dumps(rep, indent=1))
    else:
        _print_report(rep)
        stream = sess.meta.get("stream")
        if stream:
            print(f"\nstream   {stream['segments']} closed segments"
                  + (f", {stream['open_segments']} open "
                     f"(salvaged {stream['salvaged_events']} events)"
                     if stream["open_segments"] else "")
                  + (f", {stream['skipped_lines']} torn lines skipped"
                     if stream["skipped_lines"] else ""))
    return 0


def cmd_export(args: argparse.Namespace) -> int:
    sess = load_any(args.session)
    rc = _maybe_merge_device(sess, args)
    if rc:
        return rc
    text = render(sess.events, args.format, meta=sess.meta)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out} ({args.format}, {len(sess.events)} events)")
    else:
        sys.stdout.write(text)
    return 0


def cmd_compact(args: argparse.Namespace) -> int:
    sess = load_stream(args.dir)
    path = sess.save(args.out)
    stream = sess.meta["stream"]
    print(f"compacted {stream['segments']} closed + {stream['open_segments']} open "
          f"segments -> {path} ({len(sess.events)} events"
          + (f", {stream['skipped_lines']} torn lines skipped"
             if stream["skipped_lines"] else "") + ")")
    return 0


def cmd_stitch(args: argparse.Namespace) -> int:
    """Merge a frontdoor session with its replica sessions (see
    :mod:`repro.trace.stitch`).  Prints per-input provenance (origin, id
    offset, clock offset, estimated skew) and the cross-process chain
    coverage of the result."""
    from repro.trace.stitch import chain_report, stitch

    try:
        sess = stitch(args.sessions, skew_correct=not args.no_skew_correct)
    except (ValueError, FileNotFoundError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    path = sess.save(args.out)
    prov = sess.meta["stitch"]
    chain = chain_report(sess)
    if args.json:
        print(json.dumps({"out": path, "stitch": prov, "chain": chain}, indent=1))
        return 0
    print(f"stitched {len(prov['inputs'])} session(s) -> {path} "
          f"({prov['events']} events, {prov['relinked_spans']} remote spans "
          f"re-linked"
          + (f", {prov['unmatched_remote']} unmatched"
             if prov["unmatched_remote"] else "") + ")")
    print(f"\n{'origin':<24}{'events':>8}{'id_offset':>11}"
          f"{'clock_off_s':>17}{'skew_ms':>9}  path")
    for r in prov["inputs"]:
        print(f"{r['origin']:<24}{r['events']:>8}{r['id_offset']:>11}"
              f"{r['clock_offset_s']:>17.3f}{r['skew_s'] * 1e3:>9.3f}  {r['path']}")
    for r in prov["skipped"]:
        print(f"skipped {r['path']}: {r['reason']}")
    print(f"\nchain    {chain['chained']}/{chain['completed']} completed "
          f"requests have a full frontdoor->replica chain "
          f"({chain['fraction']:.1%})"
          + (f", {chain['orphaned_remote']} orphaned remote parents"
             if chain["orphaned_remote"] else ""))
    return 0


def cmd_hops(args: argparse.Namespace) -> int:
    """Per-hop latency decomposition table for a (stitched or frontdoor)
    session: where each routed request spent its time."""
    from repro.trace.stitch import HOPS, hop_rows, hop_summary

    try:
        sess = load_any(args.session)
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    rows = hop_rows(sess)
    summary = hop_summary(rows)
    if args.json:
        print(json.dumps({"summary": summary, "rows": rows}, indent=1))
        return 0
    if not rows:
        print("no hop decompositions recorded (the frontdoor adds them when "
              "replicas report their handler timings)", file=sys.stderr)
        return 1
    print(f"{'hop':<18}{'count':>7}{'mean_ms':>10}{'p50_ms':>10}"
          f"{'p95_ms':>10}{'max_ms':>10}")
    for hop in HOPS:
        st = summary["hops"][hop]
        print(f"{hop:<18}{st['count']:>7}"
              + _fmt_ms(st.get("mean")) + _fmt_ms(st.get("p50"))
              + _fmt_ms(st.get("p95")) + _fmt_ms(st.get("max")))
    lat = summary["latency_ms"]
    print(f"{'end_to_end':<18}{lat['count']:>7}"
          + _fmt_ms(lat.get("mean")) + _fmt_ms(lat.get("p50"))
          + _fmt_ms(lat.get("p95")) + _fmt_ms(lat.get("max")))
    print(f"\nsum check: {summary['within_5pct']}/{summary['requests']} "
          f"requests' hops sum to end-to-end latency within 5%")
    return 0


def cmd_tail(args: argparse.Namespace) -> int:
    try:
        return tail_stream(args.dir, once=args.once, poll_s=args.poll)
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1


def cmd_device(args: argparse.Namespace) -> int:
    """Device-side summary of a recorded run.

    Reports live-capture coverage (windows, captured fraction, measured
    overhead vs budget — from the session/manifest ``device_capture``
    record), per-device time, and how the merged slices aligned to host
    spans (``span=`` annotation vs time-window fallback vs unparented).
    """
    try:
        sess = load_any(args.session)
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    rc = _maybe_merge_device(sess, args)
    if rc:
        return rc
    import re as _re

    from repro.trace.device import DEVICE_KIND, alignment_summary

    align = alignment_summary(sess.events)
    by_device: dict[str, dict[str, float]] = {}
    by_op: dict[str, dict[str, float]] = {}
    for e in sess.events:
        if e.kind != DEVICE_KIND or not isinstance(e.payload, dict):
            continue
        dur_ms = 1e3 * float(e.payload.get("dur_s") or 0.0)
        dev = str(e.payload.get("device") or "?")
        row = by_device.setdefault(dev, {"slices": 0, "total_ms": 0.0})
        row["slices"] += 1
        row["total_ms"] += dur_ms
        op = _re.sub(r"\bspan[=:]\d+\s*", "", e.name).strip() or "?"
        row = by_op.setdefault(op, {"slices": 0, "total_ms": 0.0})
        row["slices"] += 1
        row["total_ms"] += dur_ms
    capture = sess.meta.get("device_capture") or (
        sess.meta.get("device_trace"))
    out = {
        "session": args.session,
        "device_events": align["total"],
        "align": align,
        "by_device": {d: {"slices": r["slices"],
                          "total_ms": round(r["total_ms"], 3)}
                      for d, r in sorted(by_device.items())},
        "by_op": {o: {"slices": r["slices"], "total_ms": round(r["total_ms"], 3)}
                  for o, r in sorted(by_op.items())},
        "capture": capture,
    }
    if args.json:
        print(json.dumps(out, indent=1))
        return 0
    if isinstance(capture, dict) and "windows" in capture:
        cov = capture.get("coverage") or {}
        budget = capture.get("budget") or {}
        print(f"capture  backend={capture.get('backend')}  "
              f"windows={capture.get('windows')}  "
              f"coverage={cov.get('fraction', 0):.1%} "
              f"({cov.get('captured_s', 0):g}s of {cov.get('run_s', 0):g}s)")
        print(f"budget   overhead={budget.get('overhead_pct', 0):g}%  "
              f"budget={budget.get('budget_pct', 0):g}%  "
              f"on_fraction={budget.get('on_fraction', 0):g}  "
              f"adjustments={budget.get('adjustments', 0)}")
        if capture.get("degraded"):
            print(f"WARNING: capture degraded: {capture['degraded']}")
    elif isinstance(capture, dict):
        print(f"capture  post-hoc merge of {capture.get('path')} "
              f"({capture.get('events')} events)")
    else:
        print("capture  none recorded (run with --jax-profile, or merge a "
              "dump with --device-trace)")
    if not align["total"]:
        print("no device events in this session")
        return 0
    print(f"align    span={align['span']}  window={align['window']}  "
          f"none={align['none']}  annotated={align['annotated_fraction']:.1%}")
    print(f"\n{'device':<28}{'slices':>8}{'total_ms':>12}")
    for dev, row in sorted(by_device.items()):
        print(f"{dev:<28}{row['slices']:>8}{row['total_ms']:>12.3f}")
    print(f"\n{'op':<28}{'slices':>8}{'total_ms':>12}")
    top = sorted(by_op.items(), key=lambda kv: -kv[1]["total_ms"])[:20]
    for op, row in top:
        print(f"{op[:27]:<28}{row['slices']:>8}{row['total_ms']:>12.3f}")
    if len(by_op) > 20:
        print(f"... {len(by_op) - 20} more ops")
    return 0


def cmd_push_profiles(args: argparse.Namespace) -> int:
    """Backfill the fleet store from a recorded session / segment directory."""
    from repro.fleet.cli import PUSH_RESULT_KEYS, push_source
    from repro.fleet.client import FleetError

    try:
        res = push_source(args.session, args.fleet, args.git_sha, args.chip,
                          force=args.force, token=args.token)
    except (FleetError, ValueError, FileNotFoundError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    print(json.dumps({k: res.get(k) for k in PUSH_RESULT_KEYS}))
    return 0


def _fmt_series(m: dict[str, Any]) -> str:
    labels = m.get("labels") or {}
    ltxt = ("{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"
            if labels else "")
    return f"{m.get('name')}{ltxt}"


def _print_snapshot(snap: dict[str, Any]) -> None:
    hists = [m for m in snap.get("metrics", []) if m.get("kind") == "histogram"]
    scalars = [m for m in snap.get("metrics", []) if m.get("kind") != "histogram"]
    if scalars:
        width = max(len(_fmt_series(m)) for m in scalars)
        for m in scalars:
            print(f"  {_fmt_series(m):<{width}}  {m.get('value'):g}")
    if hists:
        print(f"\n  {'histogram':<44}{'count':>8}{'p50_ms':>10}{'p95_ms':>10}"
              f"{'p99_ms':>10}")
        for m in hists:
            print(f"  {_fmt_series(m):<44}{m.get('count', 0):>8}"
                  + _fmt_ms(m.get("p50")) + _fmt_ms(m.get("p95"))
                  + _fmt_ms(m.get("p99")))


def cmd_metrics(args: argparse.Namespace) -> int:
    """Final + per-rotation metric snapshots of a recorded run.

    Reads only the manifest / ``metrics.jsonl`` sidecar (or session meta) —
    never the event stream — so it is cheap even on huge traces.
    """
    final: Any = None
    timeline: list[dict[str, Any]] = []
    drops: Any = None
    if os.path.isdir(args.session):
        mpath = os.path.join(args.session, MANIFEST_NAME)
        manifest: dict[str, Any] = {}
        if os.path.exists(mpath):
            with open(mpath) as f:
                manifest = json.load(f)
        final = manifest.get("metrics")
        drops = manifest.get("drops")
        timeline = load_metrics_timeline(args.session)
    else:
        with open(args.session) as f:
            raw = json.load(f)
        if not is_session(raw):
            print(f"error: {args.session} is not a trace session", file=sys.stderr)
            return 2
        meta = raw.get("meta", {})
        final = meta.get("metrics")
        drops = meta.get("drops")
        timeline = meta.get("metrics_timeline") or []
    if final is None and timeline:
        final = timeline[-1].get("metrics")
    if args.json:
        print(json.dumps({"final": final, "timeline": timeline, "drops": drops},
                         indent=1))
        return 0
    if final is None:
        print("no metric snapshots recorded (run with the metrics plane "
              "enabled: --metrics-port and/or --trace-overhead-budget-pct)",
              file=sys.stderr)
        return 1
    if timeline:
        print(f"timeline  {len(timeline)} rotation snapshot(s)")
        for row in timeline:
            series = row.get("metrics", {}).get("metrics", [])
            events = sum(m.get("value", 0) for m in series
                         if m.get("name") == "repro_trace_events_total")
            overhead = next((m.get("value") for m in series
                             if m.get("name") == "repro_trace_overhead_pct"), None)
            print(f"  t={row.get('t', 0):.3f}  segment={row.get('segment')}"
                  f"  events={events:g}"
                  + (f"  overhead_pct={overhead:g}" if overhead is not None else ""))
    print("\nfinal snapshot:")
    _print_snapshot(final)
    if drops:
        print(f"\nlosses: dropped={drops.get('dropped', 0)} "
              f"sampled_out={drops.get('sampled_out', 0)} "
              f"by_track={drops.get('by_track', {})}")
    return 0


def _load_raw(path: str) -> dict[str, Any]:
    """A session/artifact JSON dict from a file — or a segment directory."""
    if os.path.isdir(path):
        return load_stream(path).to_dict()
    with open(path) as f:
        return json.load(f)


def _gate(regs: list[dict[str, Any]], pct: float) -> int:
    # all gate chatter goes to stderr: with --json, stdout carries exactly one
    # machine-readable document
    if not regs:
        print(f"\nregression gate: OK (no latency/throughput change over {pct:g}%)",
              file=sys.stderr)
        return 0
    print(f"\nregression gate FAILED: {len(regs)} metric(s) worse by more than "
          f"{pct:g}%", file=sys.stderr)
    for r in regs:
        print(f"  REGRESSION {r['kind']:<10} {r['key']}: "
              f"{r['a']:.6g} -> {r['b']:.6g} ({r['delta_pct']:+.1f}%)",
              file=sys.stderr)
    return EXIT_REGRESSION


def cmd_diff(args: argparse.Namespace) -> int:
    raw_a, raw_b = _load_raw(args.a), _load_raw(args.b)
    if is_session(raw_a) != is_session(raw_b):
        which = args.a if is_session(raw_a) else args.b
        other = args.b if is_session(raw_a) else args.a
        ap_err = (f"cannot diff a trace session ({which}) against a non-session "
                  f"JSON ({other}); pass two sessions or two bench artifacts")
        print(ap_err, file=sys.stderr)
        return 2
    if args.by_path and not (is_session(raw_a) and is_session(raw_b)):
        print("--by-path needs two trace sessions (bench artifacts have no "
              "span tree)", file=sys.stderr)
        return 2
    regressions: list[dict[str, Any]] = []
    if is_session(raw_a) and is_session(raw_b):
        sa, sb = Session.from_dict(raw_a), Session.from_dict(raw_b)
        out = diff_sessions(sa, sb)
        if args.by_path:
            out["by_path"] = path_diff(sa, sb, args.path_depth)
        if args.fail_over_pct is not None:
            regressions = session_regressions(out, args.fail_over_pct)
            if args.by_path:
                regressions += path_regressions(out["by_path"], args.fail_over_pct)
        if args.json:
            print(json.dumps({**out, "regressions": regressions}, indent=1))
        else:
            print(f"a: git={out['a'].get('git_sha')}  b: git={out['b'].get('git_sha')}")
            if out["latency"]:
                print(f"\n{'track/name':<28}{'a_mean_ms':>10}{'b_mean_ms':>10}{'delta_%':>9}")
                for key, row in sorted(out["latency"].items()):
                    if "only_in" in row:
                        print(f"{key:<28}  (only in {row['only_in']})")
                    else:
                        d = row["delta_pct"]
                        print(f"{key:<28}" + _fmt_ms(row["a_mean_ms"]) + _fmt_ms(row["b_mean_ms"])
                              + (f"{d:>+9.1f}" if d is not None else f"{'-':>9}"))
            if args.by_path and out["by_path"]:
                print(f"\n{'span-tree path (exclusive)':<44}{'a_mean_ms':>10}"
                      f"{'b_mean_ms':>10}{'delta_%':>9}")
                for row in out["by_path"]:
                    if "only_in" in row:
                        print(f"{row['path']:<44}  (only in {row['only_in']})")
                    else:
                        d = row["delta_pct"]
                        print(f"{row['path']:<44}"
                              + _fmt_ms(row["a_mean_exclusive_ms"])
                              + _fmt_ms(row["b_mean_exclusive_ms"])
                              + (f"{d:>+9.1f}" if d is not None else f"{'-':>9}"))
            changed = {op: r for op, r in out["dispatch_choices"].items() if r["changed"]}
            if out["dispatch_choices"]:
                print(f"\ndispatch choices changed: {len(changed)}/{len(out['dispatch_choices'])}")
                for op, r in sorted(changed.items()):
                    print(f"  {op}: {r['a']} -> {r['b']}")
                print(f"exploration (source counts): a={out['by_source']['a']}  "
                      f"b={out['by_source']['b']}")
    else:
        out = diff_artifacts(raw_a, raw_b)
        if args.fail_over_pct is not None:
            regressions = artifact_regressions(raw_a, raw_b, args.fail_over_pct)
        if args.json:
            print(json.dumps({**out, "regressions": regressions}, indent=1))
        else:
            print(f"a: git={out['a_meta']}  b: git={out['b_meta']}  "
                  f"changed leaves: {out['total_changed']}")
            print(f"{'key':<52}{'a':>12}{'b':>12}{'delta_%':>9}")
            for row in out["changed"]:
                d = row["delta_pct"]
                print(f"{row['key']:<52}{row['a']:>12.4g}{row['b']:>12.4g}"
                      + (f"{d:>+9.1f}" if d is not None else f"{'new':>9}"))
    if args.fail_over_pct is not None:
        return _gate(regressions, args.fail_over_pct)
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.trace", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def _add_device_args(p: argparse.ArgumentParser) -> None:
        p.add_argument("--device-trace", default=None, metavar="PATH",
                       help="jax.profiler dump (dir or *.trace.json[.gz]) to "
                            "fold under the host spans before rendering")
        p.add_argument("--device-offset-s", type=float, default=None,
                       metavar="S", help="device->host clock offset override "
                       "(default: align trace starts)")

    p = sub.add_parser("report", help="per-op / per-backend latency tables for one session")
    p.add_argument("session", nargs="+",
                   help="session JSON or streaming segment directory; several "
                        "sessions are stitched first (span ids namespaced)")
    p.add_argument("--tree", action="store_true",
                   help="render the span hierarchy (indented, with "
                        "inclusive/exclusive times per node)")
    p.add_argument("--json", action="store_true", help="machine-readable output")
    _add_device_args(p)
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("export", help="render a session for a standard trace viewer")
    p.add_argument("session", help="session JSON or streaming segment directory")
    p.add_argument("--format", choices=sorted(FORMATS), default="chrome")
    p.add_argument("-o", "--out", default=None, help="output path (default: stdout)")
    _add_device_args(p)
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser("compact",
                       help="fold a streaming segment directory into one session file")
    p.add_argument("dir", help="directory written by --trace-dir")
    p.add_argument("-o", "--out", default="session.json", help="output session path")
    p.set_defaults(fn=cmd_compact)

    p = sub.add_parser("stitch",
                       help="merge a frontdoor session with its replica "
                            "sessions into one cross-process timeline")
    p.add_argument("sessions", nargs="+",
                   help="frontdoor session first, then replica sessions "
                        "(dirs announced in the frontdoor manifest are "
                        "auto-discovered)")
    p.add_argument("-o", "--out", default="stitched.json",
                   help="output session path")
    p.add_argument("--no-skew-correct", action="store_true",
                   help="skip NTP-style handshake skew estimation (keep "
                        "each session on its own wall clock)")
    p.add_argument("--json", action="store_true", help="machine-readable output")
    p.set_defaults(fn=cmd_stitch)

    p = sub.add_parser("hops",
                       help="per-hop latency decomposition (frontdoor_queue | "
                            "network | replica_queue | service)")
    p.add_argument("session", help="stitched or frontdoor session / segment dir")
    p.add_argument("--json", action="store_true", help="machine-readable output")
    p.set_defaults(fn=cmd_hops)

    p = sub.add_parser("tail", help="follow a live --trace-dir like tail -f")
    p.add_argument("dir", help="directory written by --trace-dir")
    p.add_argument("--once", action="store_true",
                   help="drain what exists now and exit (tests/scripting)")
    p.add_argument("--poll", type=float, default=0.2, metavar="SECONDS",
                   help="poll interval while following")
    p.set_defaults(fn=cmd_tail)

    p = sub.add_parser("device",
                       help="device-side summary: capture coverage, per-device "
                            "time, annotation alignment ratio")
    p.add_argument("session", help="session JSON or streaming segment directory")
    p.add_argument("--json", action="store_true", help="machine-readable output")
    _add_device_args(p)
    p.set_defaults(fn=cmd_device)

    p = sub.add_parser("push-profiles",
                       help="backfill the fleet profile service from a recorded run")
    p.add_argument("session", help="session JSON or streaming segment directory")
    p.add_argument("--fleet", required=True, metavar="URL|DIR",
                   help="fleet daemon URL (http://host:port) or store directory")
    p.add_argument("--git-sha", default=None,
                   help="bucket key override (default: the session's own SHA)")
    p.add_argument("--chip", default=None,
                   help="bucket key override (default: the session's own chip)")
    p.add_argument("--force", action="store_true",
                   help="push even if the run already fed this fleet live "
                        "(accepts the double count)")
    p.add_argument("--token", default=None, metavar="TOKEN",
                   help="bearer token for a --token-protected fleet daemon")
    p.set_defaults(fn=cmd_push_profiles)

    p = sub.add_parser("diff", help="compare two sessions (or two bench artifacts)")
    p.add_argument("a", help="session JSON, segment directory, or bench artifact")
    p.add_argument("b", help="session JSON, segment directory, or bench artifact")
    p.add_argument("--json", action="store_true")
    p.add_argument("--by-path", action="store_true",
                   help="also diff mean exclusive time per span-tree path, "
                        "attributing a regression to the node that grew "
                        "(sessions only)")
    p.add_argument("--path-depth", type=int, default=4, metavar="N",
                   help="span-tree path depth cap for --by-path (deeper "
                        "nodes fold into their ancestor)")
    p.add_argument("--fail-over-pct", type=float, default=None, metavar="PCT",
                   help="exit non-zero if any latency grew (or throughput "
                        "shrank) by more than PCT%% — the CI regression gate; "
                        "with --by-path, per-path exclusive regressions gate too")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("metrics",
                       help="print a run's final + per-rotation metric snapshots")
    p.add_argument("session", help="session JSON or streaming segment directory")
    p.add_argument("--json", action="store_true", help="machine-readable output")
    p.set_defaults(fn=cmd_metrics)

    args = ap.parse_args(argv)
    return args.fn(args)
