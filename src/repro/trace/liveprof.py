"""Live device profiling: duty-cycled ``jax.profiler`` capture windows.

The post-hoc ``--device-trace`` merge (:mod:`repro.trace.device`) answers
the paper's device-side question once, after the run.  This module answers
it *while the run is alive*, the same way the adaptive controller keeps
host-span tracing affordable: capture runs in **windows** scheduled by a
second, device-specific budget loop
(:class:`repro.metrics.controller.DeviceCaptureBudget`).  Each cycle:

1. ``backend.start(window_dir)`` opens a profiler window
   (``jax.profiler.start_trace`` for the real backend);
2. after the planned on-time, ``backend.stop()`` closes it, the dump is
   parsed (:func:`~repro.trace.device.load_profiler_trace`) and aligned
   (:func:`~repro.trace.device.align_device_slices`) against the host
   events recorded so far — **in-process**, so span ids come from the live
   counter and annotated slices bind exactly;
3. the merged ``device`` events are re-recorded through the collector, so
   they ride the normal sink path into the live
   :class:`~repro.trace.stream.StreamingSession` and the metrics plane;
4. the whole window's machinery cost (start+stop+parse+align wall time) is
   fed to the budget loop, which widens/narrows the window-on fraction —
   and stretches the off time, because the per-window cost is largely
   fixed — to hold measured overhead under ``--trace-overhead-budget-pct``.

Alignment is exact rather than fuzzy because the dispatch and engine paths
wrap device work in ``jax.profiler.TraceAnnotation(f"span={sid}")``
(via :func:`device_annotation`), so the profiler's own slices carry the
host span id and ``align_device_slices`` binds them directly instead of
falling back to time-window containment.

Degradation is graceful: a missing/failing profiler backend (or a CPU-only
jax whose dump holds raw xplane protos and no chrome trace) records **one**
warning event on the essential controller track and the run proceeds
untraced on the device side.  CI never needs real TPU/GPU hardware: the
:class:`SyntheticProfilerBackend` snoops the collector during a window and
writes a TensorBoard-shaped chrome-trace dump of its own, exercising every
byte of the window/parse/align/merge path.
"""
from __future__ import annotations

import contextlib
import gzip
import json
import os
import threading
import time
from typing import Any, Callable, Optional

from repro.core.events import next_span_id
from repro.metrics.controller import DEFAULT_BUDGET_PCT, DeviceCaptureBudget
from repro.trace.device import align_device_slices, load_profiler_trace

BACKENDS = ("auto", "jax", "synthetic")
DEFAULT_PERIOD_S = 2.0


class DeviceCaptureUnavailable(RuntimeError):
    """No usable profiler backend — the run proceeds without device capture."""


# -- span annotations ---------------------------------------------------------

# Annotation stamping is enabled only while a LiveDeviceProfiler is active:
# the dispatch/engine hot paths consult one module flag instead of threading
# a profiler handle everywhere.
_ANNOTATE = False
_ANNOTATION_CLS: Optional[Any] = None


def set_annotations(on: bool) -> None:
    global _ANNOTATE, _ANNOTATION_CLS
    if on and _ANNOTATION_CLS is None:
        try:
            import jax

            _ANNOTATION_CLS = jax.profiler.TraceAnnotation
        except Exception:
            _ANNOTATION_CLS = None
    _ANNOTATE = bool(on) and _ANNOTATION_CLS is not None


def annotations_enabled() -> bool:
    return _ANNOTATE


def device_annotation(span_id: int) -> Any:
    """Context manager naming the enclosed device work after its host span.

    Inside an active profiler window this wraps the region in
    ``jax.profiler.TraceAnnotation(f"span={span_id}")`` so every XLA slice
    launched under it carries the host span id; when no profiler is active
    (or ``span_id`` is 0) it is a free null context.
    """
    if not _ANNOTATE or not span_id or _ANNOTATION_CLS is None:
        return contextlib.nullcontext()
    return _ANNOTATION_CLS(f"span={span_id}")


# -- backends -----------------------------------------------------------------


class JaxProfilerBackend:
    """The real thing: ``jax.profiler.start_trace``/``stop_trace``.

    ``offset_s = None`` — the profiler dump runs on its own clock, so the
    aligner estimates the offset from trace starts.
    """

    name = "jax"
    offset_s: Optional[float] = None

    def __init__(self) -> None:
        try:
            import jax.profiler

            self._profiler = jax.profiler
        except Exception as exc:  # pragma: no cover - environment-dependent
            raise DeviceCaptureUnavailable(
                f"jax.profiler unavailable: {type(exc).__name__}: {exc}")
        if not hasattr(self._profiler, "start_trace"):
            raise DeviceCaptureUnavailable(
                "jax.profiler has no start_trace/stop_trace")

    def start(self, window_dir: str) -> None:
        self._profiler.start_trace(window_dir)

    def stop(self) -> None:
        self._profiler.stop_trace()


class SyntheticProfilerBackend:
    """Profiler stub for CI: snoops the collector, dumps a chrome trace.

    During a window it registers as a sampled sink on the collector and
    turns completed host lifecycles (``prefill``/``decode_tick`` by default)
    plus measured dispatch decisions into device slices on a pretend
    ``/device:SYNTH:0``.  ``stop()`` writes them as a gzipped TensorBoard
    layout (``plugins/profile/<run>/local.trace.json.gz``) — byte-compatible
    with what :func:`~repro.trace.device.load_profiler_trace` expects from a
    real dump — so the entire window/parse/align/merge path runs in CI with
    no accelerator.  Slices from spanned host events are named
    ``span=<sid> <op>`` (the TraceAnnotation analogue); span-less events
    produce unhinted slices, which is what the mixed alignment tests lean
    on.  Timestamps are host-monotonic, hence ``offset_s = 0``.
    """

    name = "synthetic"
    offset_s = 0.0
    device = "/device:SYNTH:0"

    def __init__(self, collector: Any,
                 op_names: tuple[str, ...] = ("prefill", "decode_tick",
                                              "step")) -> None:
        self.collector = collector
        self.op_names = frozenset(op_names)
        self._open: dict[tuple[str, int], float] = {}
        self._slices: list[tuple[str, int, float, float]] = []
        self._dir: Optional[str] = None
        self._lock = threading.Lock()

    def _on_event(self, e: Any) -> None:
        if e.kind == "spawn" and e.name in self.op_names:
            with self._lock:
                self._open[(e.name, e.span)] = e.t
        elif e.kind == "exit" and e.name in self.op_names:
            with self._lock:
                t0 = self._open.pop((e.name, e.span), None)
                if t0 is not None:
                    self._slices.append((e.name, e.span, t0, e.t))
        elif e.kind == "dispatch" and isinstance(e.payload, dict):
            dur = e.payload.get("measured_s")
            if isinstance(dur, (int, float)) and dur >= 0:
                op = str(e.payload.get("op") or e.name)
                with self._lock:
                    self._slices.append((op, e.span, e.t - dur, e.t))

    def start(self, window_dir: str) -> None:
        self._dir = window_dir
        with self._lock:
            self._open.clear()
            self._slices.clear()
        self.collector.add_sink(self._on_event, sampled=True)

    def stop(self) -> None:
        self.collector.remove_sink(self._on_event)
        assert self._dir is not None
        with self._lock:
            slices = list(self._slices)
        rows: list[dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": 1,
             "args": {"name": self.device}},
        ]
        for op, span, t0, t1 in slices:
            name = f"span={span} {op}" if span else op
            rows.append({
                "ph": "X", "pid": 1, "tid": 1, "name": name,
                "ts": t0 * 1e6, "dur": max(0.0, t1 - t0) * 1e6,
            })
        out = os.path.join(self._dir, "plugins", "profile", "synth")
        os.makedirs(out, exist_ok=True)
        with gzip.open(os.path.join(out, "local.trace.json.gz"), "wt") as f:
            json.dump({"traceEvents": rows}, f)


def make_backend(kind: str, collector: Any) -> Any:
    """Resolve a ``--jax-profile-backend`` value to a backend instance."""
    if kind == "synthetic":
        return SyntheticProfilerBackend(collector)
    if kind in ("jax", "auto"):
        return JaxProfilerBackend()
    raise DeviceCaptureUnavailable(
        f"unknown device-profiler backend {kind!r} (choose from {BACKENDS})")


# -- the live profiler --------------------------------------------------------


class LiveDeviceProfiler:
    """Duty-cycled device capture, merging each window into the live trace.

    Thread lifecycle mirrors the AdaptiveController: ``start()`` launches a
    daemon loop that alternates profiler-on windows and budget-stretched
    off gaps; ``stop()`` force-closes any open window (so even a run
    shorter than one period merges at least one window) and exports the
    end-state gauges.  ``open_window()``/``close_window()`` are public and
    deterministic so tests and benchmarks can drive cycles themselves.

    ``snapshot()`` doubles as the :class:`~repro.trace.stream
    .StreamingSession` ``device_provider``: every rotation records
    per-window coverage in the manifest.
    """

    def __init__(
        self,
        collector: Any,
        out_dir: str,
        *,
        budget: Optional[DeviceCaptureBudget] = None,
        registry: Optional[Any] = None,
        backend: str = "auto",
        budget_pct: float = DEFAULT_BUDGET_PCT,
        period_s: float = DEFAULT_PERIOD_S,
        id_alloc: Callable[[], int] = next_span_id,
    ) -> None:
        self.collector = collector
        self.out_dir = out_dir
        self.budget = budget if budget is not None else DeviceCaptureBudget(
            registry, budget_pct=budget_pct, period_s=period_s)
        self.backend_kind = backend
        self.backend: Optional[Any] = None
        self.degraded: Optional[str] = None
        self.windows: list[dict[str, Any]] = []
        self.merged_events = 0
        self.align_stats: dict[str, int] = {}
        self._id_alloc = id_alloc
        self._window_open = False
        self._window_dir: Optional[str] = None
        self._window_t0 = 0.0
        self._window_cost = 0.0
        self._started_t: Optional[float] = None
        self._last_cycle_t: Optional[float] = None
        self._lock = threading.RLock()
        self._stop_ev = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._g_coverage = self._g_quality = None
        if registry is not None:
            self._g_coverage = registry.gauge(
                "repro_device_capture_coverage",
                "fraction of run wall time covered by capture windows")
            self._g_quality = registry.gauge(
                "repro_device_alignment_annotated_fraction",
                "device slices bound by span= annotation / total merged")
        os.makedirs(out_dir, exist_ok=True)
        try:
            self.backend = make_backend(backend, collector)
        except DeviceCaptureUnavailable as exc:
            self._degrade(str(exc))

    # -- degradation ---------------------------------------------------------

    def _degrade(self, reason: str) -> None:
        """One warning event (essential controller track), then proceed."""
        if self.degraded is not None:
            return
        self.degraded = reason
        self.budget.capture_enabled = False
        self.budget.export()
        try:
            self.collector.record("mark", "device_window", {
                "warning": f"device capture disabled: {reason}",
                "backend": self.backend_kind,
            })
        except Exception:
            pass
        import sys

        print(f"live device profiling disabled: {reason}; run proceeds "
              "host-side only", file=sys.stderr)

    # -- window mechanics ----------------------------------------------------

    def open_window(self) -> bool:
        """Start one capture window; False if degraded/already open."""
        with self._lock:
            if self.degraded or self._window_open or self.backend is None:
                return False
            wdir = os.path.join(self.out_dir, f"window-{len(self.windows):04d}")
            os.makedirs(wdir, exist_ok=True)
            t0 = time.perf_counter()
            try:
                self.backend.start(wdir)
            except Exception as exc:
                self._degrade(f"{type(exc).__name__}: {exc}")
                return False
            self._window_cost = time.perf_counter() - t0
            self._window_dir = wdir
            self._window_t0 = time.monotonic()
            self._window_open = True
            if self._started_t is None:
                self._started_t = self._window_t0
            return True

    def close_window(self) -> int:
        """Stop the open window, parse + align + merge its dump live.

        Returns the number of device events merged into the collector (and,
        through its sink, the streaming session).  The full machinery cost
        is wall-clocked and fed to the budget loop.
        """
        with self._lock:
            if not self._window_open:
                return 0
            self._window_open = False
            wdir = self._window_dir
            t0 = time.perf_counter()
            merged = 0
            stats: dict[str, int] = {}
            try:
                self.backend.stop()
                slices = load_profiler_trace(wdir)
                evs = align_device_slices(
                    self.collector.events(), slices,
                    offset_s=getattr(self.backend, "offset_s", None),
                    id_alloc=self._id_alloc, stats=stats,
                )
                for ev in evs:
                    self.collector.record("device", ev.name, ev.payload,
                                          span=ev.span, parent=ev.parent,
                                          t=ev.t)
                merged = len(evs)
            except Exception as exc:
                self._degrade(f"{type(exc).__name__}: {exc}")
            self._window_cost += time.perf_counter() - t0
            now = time.monotonic()
            win = {
                "dir": os.path.basename(wdir or ""),
                "t0": round(self._window_t0, 6),
                "t1": round(now, 6),
                "on_s": round(now - self._window_t0, 6),
                "cost_s": round(self._window_cost, 6),
                "events": merged,
                "align": stats,
            }
            self.windows.append(win)
            self.merged_events += merged
            for k, v in stats.items():
                self.align_stats[k] = self.align_stats.get(k, 0) + v
            ref = self._last_cycle_t if self._last_cycle_t is not None \
                else self._started_t
            elapsed = max(now - (ref or now), win["on_s"], 1e-9)
            self._last_cycle_t = now
            overhead = self.budget.observe(self._window_cost, elapsed)
            if self.degraded is None:
                self.collector.record("mark", "device_window", {
                    **win, "overhead_pct": round(overhead, 4),
                })
            self._export_gauges(now)
            return merged

    def _export_gauges(self, now: float) -> None:
        if self._g_coverage is not None and self._started_t is not None:
            run_s = max(now - self._started_t, 1e-9)
            cov = min(1.0, sum(w["on_s"] for w in self.windows) / run_s)
            self._g_coverage.set(round(cov, 4))
        if self._g_quality is not None:
            total = self.align_stats.get("total", 0)
            if total:
                self._g_quality.set(
                    round(self.align_stats.get("span", 0) / total, 4))

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "LiveDeviceProfiler":
        if self._thread is not None or self.degraded is not None:
            set_annotations(self.degraded is None)
            return self
        set_annotations(True)
        self._started_t = time.monotonic()
        self.collector.record("mark", "device_window", {
            "phase": "start",
            "backend": getattr(self.backend, "name", self.backend_kind),
            "budget_pct": self.budget.budget_pct,
            "period_s": self.budget.period_s,
            "out_dir": self.out_dir,
        })
        self._stop_ev.clear()
        self._thread = threading.Thread(
            target=self._run, name="repro-device-capture", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop_ev.is_set():
            try:
                on_s, off_s = self.budget.plan()
                if on_s > 0 and self.degraded is None:
                    if self.open_window():
                        if self._stop_ev.wait(on_s):
                            break  # stop() force-closes the window
                        self.close_window()
                if self.degraded is not None:
                    return  # measure-only: nothing left to schedule
                if self._stop_ev.wait(max(off_s, 0.01)):
                    break
            except Exception:  # the capture loop must never kill the run
                return

    def stop(self) -> None:
        self._stop_ev.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if self._window_open:
            self.close_window()  # short runs still merge their one window
        set_annotations(False)
        self._export_gauges(time.monotonic())
        self.budget.export()

    # -- reporting -----------------------------------------------------------

    def snapshot(self) -> dict[str, Any]:
        """Coverage + alignment summary; the stream's ``device_provider``."""
        with self._lock:
            now = time.monotonic()
            run_s = (now - self._started_t) if self._started_t else 0.0
            on_s = sum(w["on_s"] for w in self.windows)
            total = self.align_stats.get("total", 0)
            return {
                "backend": getattr(self.backend, "name", self.backend_kind),
                "out_dir": self.out_dir,
                "degraded": self.degraded,
                "windows": len(self.windows),
                "merged_events": self.merged_events,
                "align": {
                    **self.align_stats,
                    "annotated_fraction": (
                        self.align_stats.get("span", 0) / total if total
                        else 0.0),
                },
                "coverage": {
                    "captured_s": round(on_s, 6),
                    "run_s": round(run_s, 6),
                    "fraction": round(min(1.0, on_s / run_s), 4)
                    if run_s > 0 else 0.0,
                },
                "budget": self.budget.snapshot(),
                "window_log": self.windows[-64:],
            }
