"""Bounded trace collector: the perf-buffer front end of ``repro.trace``.

A :class:`TraceCollector` IS an :class:`~repro.core.events.EventLog` (it
subclasses it), so every component that takes ``log=`` — the serving engine,
the train supervisor, the dispatcher, uprobes, tracepoint callbacks — can
write into a bounded collector unchanged.  On top of the raw log it adds:

* **capacity + drop accounting** — bounded by default (``capacity`` events);
  ``stats()`` reports how many events the ring evicted, mirroring the
  perf-buffer "lost samples" counter the paper's pipeline watches;
* **tracks** — the per-unit views (step / microbatch / request / checkpoint /
  dispatch) a trace viewer renders as rows; event names map onto tracks via
  ``TRACK_OF`` (extensible per collector);
* **closed spans** — spawn/exit pairs resolved into ``Span`` records (by span
  id / payload identity, interleaving-safe), the unit every exporter in
  :mod:`repro.trace.export` consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Optional

from repro.core.events import Event, EventLog, _pair_key

DEFAULT_CAPACITY = 1 << 16  # 65536 events

# Canonical track per event name.  Anything unlisted lands on "other" unless
# the collector was constructed with extra mappings.
TRACK_OF: dict[str, str] = {
    "step": "step",
    "train_step": "step",
    "microbatch": "microbatch",
    "request": "request",
    "prefill": "request",
    "decode_tick": "request",
    "checkpoint": "checkpoint",
    "restart": "checkpoint",
    "elastic_resize": "checkpoint",
}

TRACKS = ("step", "microbatch", "request", "checkpoint", "dispatch", "other")


@dataclasses.dataclass(frozen=True)
class Span:
    """A closed spawn/exit pair (or a zero-length instant for loose events)."""

    name: str
    track: str
    t0: float
    t1: float
    payload: Any = None
    span: int = 0

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class TraceCollector(EventLog):
    """Bounded EventLog with track views and span resolution."""

    def __init__(
        self,
        capacity: int | None = DEFAULT_CAPACITY,
        *,
        track_of: Optional[Mapping[str, str]] = None,
    ) -> None:
        super().__init__(maxlen=capacity)
        self._track_of = dict(TRACK_OF)
        if track_of:
            self._track_of.update(track_of)

    # -- track views ---------------------------------------------------------

    def track_name(self, event: Event) -> str:
        """The viewer row an event belongs to (dispatch is kind-keyed)."""
        if event.kind == "dispatch":
            return "dispatch"
        return self._track_of.get(event.name, "other")

    def track(self, track: str) -> list[Event]:
        return [e for e in self.events() if self.track_name(e) == track]

    def tracks(self) -> dict[str, list[Event]]:
        out: dict[str, list[Event]] = {t: [] for t in TRACKS}
        for e in self.events():
            out.setdefault(self.track_name(e), []).append(e)
        return {t: evs for t, evs in out.items() if evs}

    # -- span resolution -----------------------------------------------------

    def spans(self) -> list[Span]:
        return resolve_spans(self.events(), self.track_name)

    # -- accounting ----------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        per_track = {t: len(evs) for t, evs in self.tracks().items()}
        return {
            "events": len(self),
            "capacity": self.maxlen,
            "dropped": self.dropped,
            "per_track": per_track,
        }


def resolve_spans(events: Iterable[Event], track_name=None) -> list[Span]:
    """Pair spawn/exit events into closed :class:`Span` records.

    Same pairing discipline as :meth:`EventLog.durations` — span id, then
    hashable payload, then LIFO fallback — applied across all names at once.
    Unpaired spawns are dropped (still open when the trace was cut); events
    of other kinds (mark/probe/straggler) become zero-length instants, and
    ``dispatch`` events with a ``measured_s`` payload become spans covering
    their measured execution window.
    """
    if track_name is None:
        track_name = lambda e: "dispatch" if e.kind == "dispatch" else TRACK_OF.get(e.name, "other")  # noqa: E731
    out: list[Span] = []
    open_by_key: dict[Any, list[Event]] = {}
    stack_by_name: dict[str, list[Event]] = {}
    for e in events:
        if e.kind == "spawn":
            key = _pair_key(e)
            if key is not None:
                open_by_key.setdefault((e.name, key), []).append(e)
            else:
                stack_by_name.setdefault(e.name, []).append(e)
        elif e.kind == "exit":
            key = _pair_key(e)
            opened = open_by_key.get((e.name, key)) if key is not None else None
            if opened:
                s = opened.pop()
            elif key is None and stack_by_name.get(e.name):
                s = stack_by_name[e.name].pop()
            else:
                continue  # exit without a visible spawn (evicted from ring)
            out.append(Span(e.name, track_name(s), s.t, e.t, s.payload, s.span))
        else:
            p = e.payload
            if e.kind == "dispatch" and isinstance(p, dict) and isinstance(
                p.get("measured_s"), (int, float)
            ):
                out.append(Span(e.name, track_name(e), e.t - p["measured_s"], e.t, p, e.span))
            else:
                out.append(Span(e.name, track_name(e), e.t, e.t, p, e.span))
    out.sort(key=lambda s: s.t0)
    return out
