"""Bounded trace collector: the perf-buffer front end of ``repro.trace``.

A :class:`TraceCollector` IS an :class:`~repro.core.events.EventLog` (it
subclasses it), so every component that takes ``log=`` — the serving engine,
the train supervisor, the dispatcher, uprobes, tracepoint callbacks — can
write into a bounded collector unchanged.  On top of the raw log it adds:

* **capacity + drop accounting** — bounded by default (``capacity`` events);
  ``stats()`` reports how many events the ring evicted, mirroring the
  perf-buffer "lost samples" counter the paper's pipeline watches;
* **tracks** — the per-unit views (step / microbatch / request / checkpoint /
  dispatch) a trace viewer renders as rows; event names map onto tracks via
  ``TRACK_OF`` (extensible per collector);
* **track-aware sampling** — tracks listed in ``track_capacity`` get their
  own dedicated rings, so a flood of hot request spans cannot evict the few
  tiny-but-precious dispatch or checkpoint events (one global ``maxlen``
  evicts exactly the wrong things under skewed load).  By default the
  ``dispatch`` and ``checkpoint`` tracks are reserved;
* **streaming sinks** — ``set_sink(fn)`` invokes ``fn(event)`` on every
  *captured* record before any ring eviction, which is how a
  :class:`~repro.trace.stream.StreamingSession` persists the full event
  stream even beyond ring capacity; ``add_sink(fn, sampled=False)`` fans in
  extra sinks that see **every** event including sampled-out ones (the
  metrics plane counts what the rings shed);
* **adaptive sampling gate** — ``set_sample_rate(r)`` duty-cycles span
  capture: non-essential events are admitted at rate ``r`` by an error
  accumulator, suppressed spawns remember their span id so the matching
  exit is suppressed too (pairing never tears), and dispatch / checkpoint /
  run / controller tracks are never shed.  Driven by
  :class:`repro.metrics.controller.AdaptiveController`, which reads the
  record-path self-timing (records are wall-clocked end-to-end, every
  ``TIMING_EVERY``-th call) via ``timing_snapshot()``;
* **closed spans** — spawn/exit pairs resolved into ``Span`` records (by span
  id / payload identity, interleaving-safe) carrying parent links, the unit
  every exporter in :mod:`repro.trace.export` consumes;
* **span trees** — :func:`span_tree` folds the parent links into a forest of
  :class:`SpanNode` (orphaned children — parent evicted from the ring — fall
  back to roots), the structure ``report --tree`` and the nested exporters
  render.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Iterable, Mapping, Optional

from repro.core.events import (Event, EventLog, _pair_key, current_span,
                               remote_ref)

DEFAULT_CAPACITY = 1 << 16  # 65536 events

# Canonical track per event name.  Anything unlisted lands on "other" unless
# the collector was constructed with extra mappings.
TRACK_OF: dict[str, str] = {
    "serve_run": "run",
    "train_run": "run",
    "router_run": "run",
    "replica": "router",
    "step": "step",
    "train_step": "step",
    "microbatch": "microbatch",
    "request": "request",
    "rpc": "request",
    "prefill": "request",
    "decode_tick": "request",
    "checkpoint": "checkpoint",
    "restart": "checkpoint",
    "elastic_resize": "checkpoint",
    "controller": "controller",
    "device_window": "controller",
}

# Host tracks order before device tracks (``device:<name>``, sorted after the
# canonical set) so viewers render host rows above their device rows.
TRACKS = ("run", "step", "microbatch", "request", "checkpoint", "dispatch",
          "router", "controller", "other")

# Tracks the sampling gate never sheds: rare, tiny, and load-bearing — the
# run envelope, dispatch/warm-start analysis, recovery lifecycle, and the
# controller's own decision trail.  Device tracks are also exempt (they are
# merged post-hoc and already rate-limited at their source).
ESSENTIAL_TRACKS = frozenset({"run", "dispatch", "checkpoint", "router",
                              "controller"})

# Every Nth record() is timed end-to-end (event build + ring + sinks).  The
# default times EVERY call: two perf_counter reads (~100 ns) against a
# multi-µs record path, and sparse sampling aliases badly with periodic
# in-sink costs — a streaming session fsyncing every 64 events lands the
# rotation on exactly the timed record when N is also 64, extrapolating one
# fsync to the whole stream.
TIMING_EVERY = 1


def default_track(e: Event) -> str:
    """Track of an event without a collector (module-level TRACK_OF only)."""
    if e.kind == "dispatch":
        return "dispatch"
    if e.kind == "route":
        return "router"
    if e.kind == "device":
        dev = e.payload.get("device") if isinstance(e.payload, dict) else None
        return f"device:{dev}" if dev else "device"
    return TRACK_OF.get(e.name, "other")

# Reserved per-track ring sizes: dispatch decisions and checkpoint lifecycle
# events are rare and small but drive warm-start + recovery analysis — they
# must survive a request-span flood that wraps the main ring many times over.
DEFAULT_TRACK_CAPACITY: dict[str, int] = {
    "dispatch": 4096, "checkpoint": 1024, "router": 4096, "controller": 1024,
}


@dataclasses.dataclass(frozen=True)
class Span:
    """A closed spawn/exit pair (or a zero-length instant for loose events).

    ``parent`` is the enclosing span's id (0 = root); ``truncated`` marks a
    span force-closed at the last observed event time because its exit was
    evicted from the ring (or the trace was cut while it was open).

    ``remote`` is the cross-process parent reference (the
    :meth:`repro.core.events.SpanContext.to_payload` dict lifted from the
    spawn payload's ``"remote"`` key) — the parent span lives in *another*
    process's id space and is not required to exist locally.  ``parent``
    stays the local enclosing span so single-session trees render unchanged;
    :mod:`repro.trace.stitch` re-points ``parent`` at the remote span once
    both sessions share one id space.
    """

    name: str
    track: str
    t0: float
    t1: float
    payload: Any = None
    span: int = 0
    parent: int = 0
    truncated: bool = False
    remote: Optional[dict] = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class SpanNode:
    """One node of a span tree: a span plus its resolved children."""

    span: Span
    children: list["SpanNode"] = dataclasses.field(default_factory=list)

    @property
    def exclusive(self) -> float:
        """Self time: duration minus the children's (clamped at 0 — a child
        force-closed past its parent's exit can overshoot)."""
        return max(0.0, self.span.dur - sum(c.span.dur for c in self.children))


class TraceCollector(EventLog):
    """Bounded EventLog with track views, reserved rings and span resolution."""

    def __init__(
        self,
        capacity: int | None = DEFAULT_CAPACITY,
        *,
        track_of: Optional[Mapping[str, str]] = None,
        track_capacity: Optional[Mapping[str, int]] = None,
        sink: Optional[Callable[[Event], None]] = None,
    ) -> None:
        super().__init__(maxlen=capacity)
        self._track_of = dict(TRACK_OF)
        if track_of:
            self._track_of.update(track_of)
        caps = DEFAULT_TRACK_CAPACITY if track_capacity is None else dict(track_capacity)
        self._rings: dict[str, deque[Event]] = {
            t: deque(maxlen=n) for t, n in caps.items() if n
        }
        self._ring_dropped: dict[str, int] = {t: 0 for t in self._rings}
        self._sink = sink
        self._sink_error: Optional[str] = None
        self._extra_sinks: list[tuple[Callable[[Event], None], bool]] = []
        # sampling gate state (all under self._lock)
        self._sample_rate = 1.0
        self._duty = 0.0
        self._suppressed: set[int] = set()
        self._sampled_out = 0
        # record-path self-timing (controller feedback signal)
        self._rec_count = 0
        self._rec_marked = 0
        self._timed_count = 0
        self._timed_total_s = 0.0

    # -- streaming sinks -----------------------------------------------------

    def set_sink(self, sink: Optional[Callable[[Event], None]]) -> None:
        """Install the primary per-event callback (``StreamingSession.emit``).

        The sink sees every *captured* event exactly once, before ring
        eviction, so a durable stream is a superset of the in-memory ring —
        provided the stream is closed only after all recording threads have
        quiesced (the sink runs outside the collector lock, so an in-flight
        record() racing ``StreamingSession.close()`` would be dropped by the
        sealed stream; every driver closes after its run loop has fully
        joined)."""
        self._sink = sink

    def add_sink(self, sink: Callable[[Event], None], *, sampled: bool = True) -> None:
        """Fan in an additional sink.

        ``sampled=True`` sinks mirror the primary slot (captured events
        only); ``sampled=False`` sinks see every event including ones the
        sampling gate sheds — the metrics plane attaches this way so
        counters stay exact while capture is duty-cycled."""
        self._extra_sinks.append((sink, sampled))

    def remove_sink(self, sink: Callable[[Event], None]) -> None:
        self._extra_sinks = [(s, f) for s, f in self._extra_sinks if s is not sink]

    # -- sampling gate -------------------------------------------------------

    @property
    def sample_rate(self) -> float:
        with self._lock:
            return self._sample_rate

    def set_sample_rate(self, rate: float) -> None:
        """Set the capture duty cycle in [0, 1]; 1.0 = capture everything."""
        with self._lock:
            self._sample_rate = min(1.0, max(0.0, float(rate)))

    # -- recording (track-aware) ---------------------------------------------

    def _track_for(self, kind: str, name: str, payload: Any = None) -> str:
        if kind == "dispatch":
            return "dispatch"
        if kind == "route":
            # routing decisions/outcomes mirror dispatch decisions one tier
            # up: rare, tiny, and load-bearing for accounting — own ring
            return "router"
        if kind == "device":
            dev = payload.get("device") if isinstance(payload, dict) else None
            return f"device:{dev}" if dev else "device"
        return self._track_of.get(name, "other")

    def record(
        self,
        kind: str,
        name: str,
        payload: Any = None,
        *,
        span: int = 0,
        parent: Optional[int] = None,
        t: Optional[float] = None,
    ) -> None:
        # racy read of _rec_count is fine: timing needs ~1/TIMING_EVERY calls
        t0 = (time.perf_counter()
              if TIMING_EVERY == 1 or self._rec_count % TIMING_EVERY == 0
              else None)
        if parent is None:
            parent = current_span()
        ev = Event(time.monotonic() if t is None else t, kind, name, payload,
                   span, parent)
        track = self._track_for(kind, name, payload)
        ring = self._rings.get(track)
        with self._lock:
            self._rec_count += 1
            captured = True
            if kind == "exit" and span and span in self._suppressed:
                # spawn was shed: shed the exit too, whatever the gate says now
                self._suppressed.discard(span)
                self._sampled_out += 1
                captured = False
            elif (self._sample_rate < 1.0
                  and track not in ESSENTIAL_TRACKS
                  and not track.startswith("device")
                  and not (kind == "exit" and span)):
                # exits of captured spans always pass (pairing never tears);
                # everything else goes through the duty-cycle accumulator
                self._duty += self._sample_rate
                if self._duty >= 1.0:
                    self._duty -= 1.0
                else:
                    self._sampled_out += 1
                    captured = False
                    if kind == "spawn" and span:
                        if len(self._suppressed) >= 65536:
                            self._suppressed.pop()
                        self._suppressed.add(span)
            if captured:
                if ring is not None:
                    if ring.maxlen is not None and len(ring) == ring.maxlen:
                        self._ring_dropped[track] += 1
                    ring.append(ev)
                else:
                    if self._events.maxlen is not None and len(self._events) == self._events.maxlen:
                        self._dropped += 1
                    self._events.append(ev)
        for extra, wants_sampled in list(self._extra_sinks):
            if wants_sampled and not captured:
                continue
            try:  # outside the lock: sink I/O must not block writers
                extra(ev)
            except Exception as exc:
                self.remove_sink(extra)
                self._sink_error = f"{type(exc).__name__}: {exc}"
                import sys

                print(f"trace sink detached after error: {self._sink_error}",
                      file=sys.stderr)
        sink = self._sink
        if captured and sink is not None:
            try:
                sink(ev)
            except Exception as exc:
                # a broken sink (ENOSPC, closed file) must not take down the
                # traced run: detach it and surface the error via stats()
                self._sink = None
                self._sink_error = f"{type(exc).__name__}: {exc}"
                import sys

                print(f"trace sink detached after error: {self._sink_error}",
                      file=sys.stderr)
        if t0 is not None:
            dt = time.perf_counter() - t0
            with self._lock:
                self._timed_count += 1
                self._timed_total_s += dt

    def timing_snapshot(self) -> dict[str, Any]:
        """Read-and-reset the record-path self-timing accumulators.

        ``timed`` calls were wall-clocked end-to-end out of ``records`` total
        record() calls since the last snapshot — the adaptive controller
        multiplies the per-call cost back up by ``records`` to price the
        whole stream."""
        with self._lock:
            out = {
                "timed": self._timed_count,
                "timed_s": self._timed_total_s,
                "records": self._rec_count - self._rec_marked,
            }
            self._timed_count = 0
            self._timed_total_s = 0.0
            self._rec_marked = self._rec_count
        return out

    def events(self, kind: str | None = None, name: str | None = None) -> list[Event]:
        with self._lock:
            evs = list(self._events)
            for ring in self._rings.values():
                evs.extend(ring)
        evs.sort(key=lambda e: e.t)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        if name is not None:
            evs = [e for e in evs if e.name == name]
        return evs

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped + sum(self._ring_dropped.values())

    def dropped_by_track(self) -> dict[str, int]:
        """Per-reserved-track eviction counts (main-ring losses under ``""``),
        plus spans force-closed because their exit was evicted — an orphaned
        spawn is a lost measurement even though the spawn event itself
        survived, so it belongs in the same loss accounting.

        Spans legitimately still open count too (the resolver cannot tell an
        evicted exit from an in-flight unit): call at run end, after the
        root span has closed, for clean numbers — the drivers do."""
        with self._lock:
            out = dict(self._ring_dropped)
            out[""] = self._dropped
        orphans: dict[str, int] = {}
        resolve_spans(self.events(), self.track_name, orphans=orphans)
        for track, n in orphans.items():
            out[track] = out.get(track, 0) + n
        return out

    def drop_counters(self) -> dict[str, Any]:
        """Cheap loss counters (no span resolution): safe to poll mid-run.

        Unlike :meth:`dropped_by_track` this never walks the event stream,
        so the metrics plane and streaming-session manifests can refresh it
        on every scrape/rotation without perturbing the run."""
        with self._lock:
            by_track = {t: n for t, n in self._ring_dropped.items() if n}
            if self._dropped:
                by_track[""] = self._dropped
            return {
                "dropped": self._dropped + sum(self._ring_dropped.values()),
                "sampled_out": self._sampled_out,
                "by_track": by_track,
            }

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0
            for ring in self._rings.values():
                ring.clear()
            self._ring_dropped = {t: 0 for t in self._rings}
            self._duty = 0.0
            self._suppressed.clear()
            self._sampled_out = 0

    def to_json(self) -> str:
        import json

        rows = [dataclasses.asdict(e) for e in self.events()]
        return json.dumps(
            {"dropped": self.dropped, "maxlen": self.maxlen, "events": rows},
            default=repr,
        )

    # -- track views ---------------------------------------------------------

    def track_name(self, event: Event) -> str:
        """The viewer row an event belongs to (dispatch/device are kind-keyed)."""
        return self._track_for(event.kind, event.name, event.payload)

    def track(self, track: str) -> list[Event]:
        return [e for e in self.events() if self.track_name(e) == track]

    def tracks(self) -> dict[str, list[Event]]:
        out: dict[str, list[Event]] = {t: [] for t in TRACKS}
        for e in self.events():
            out.setdefault(self.track_name(e), []).append(e)
        return {t: evs for t, evs in out.items() if evs}

    # -- span resolution -----------------------------------------------------

    def spans(self) -> list[Span]:
        return resolve_spans(self.events(), self.track_name)

    def span_tree(self) -> list["SpanNode"]:
        """The resolved spans folded into a parent-linked forest."""
        return span_tree(self.spans())

    # -- accounting ----------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        per_track = {t: len(evs) for t, evs in self.tracks().items()}
        with self._lock:
            track_capacity = {t: r.maxlen for t, r in self._rings.items()}
            sampled_out = self._sampled_out
            sample_rate = self._sample_rate
        return {
            "events": len(self),
            "capacity": self.maxlen,
            "dropped": self.dropped,
            "per_track": per_track,
            "track_capacity": track_capacity,
            "dropped_by_track": self.dropped_by_track(),
            "sampled_out": sampled_out,
            "sample_rate": sample_rate,
            "sink_error": self._sink_error,
        }

    def __len__(self) -> int:
        with self._lock:
            return len(self._events) + sum(len(r) for r in self._rings.values())


def resolve_spans(
    events: Iterable[Event],
    track_name=None,
    *,
    orphans: Optional[dict[str, int]] = None,
) -> list[Span]:
    """Pair spawn/exit events into closed :class:`Span` records.

    Same pairing discipline as :meth:`EventLog.durations` — span id, then
    hashable payload, then LIFO fallback — applied across all names at once.
    Parent ids propagate from the spawn event onto the resolved span.

    A spawn whose exit never arrived (evicted from the ring, or the trace
    was cut while the unit was open) is **force-closed at the last observed
    event time** and marked ``truncated`` — silently dropping it would leak
    the whole unit from every report.  ``orphans``, when provided, collects
    per-track counts of those closes (folded into
    :meth:`TraceCollector.dropped_by_track`).

    Events of other kinds (mark/probe/straggler) become zero-length
    instants; ``dispatch`` events with a ``measured_s`` payload become spans
    covering their measured execution window, and ``device`` events with a
    ``dur_s`` payload become device-track spans (see
    :mod:`repro.trace.device`).
    """
    if track_name is None:
        track_name = default_track
    out: list[Span] = []
    open_by_key: dict[Any, list[Event]] = {}
    stack_by_name: dict[str, list[Event]] = {}
    t_last = 0.0
    for e in events:
        t_last = max(t_last, e.t)
        if e.kind == "spawn":
            key = _pair_key(e)
            if key is not None:
                open_by_key.setdefault((e.name, key), []).append(e)
            else:
                stack_by_name.setdefault(e.name, []).append(e)
        elif e.kind == "exit":
            key = _pair_key(e)
            opened = open_by_key.get((e.name, key)) if key is not None else None
            if opened:
                s = opened.pop()
            elif key is None and stack_by_name.get(e.name):
                s = stack_by_name[e.name].pop()
            else:
                continue  # exit without a visible spawn (evicted from ring)
            out.append(Span(e.name, track_name(s), s.t, e.t, s.payload, s.span,
                            s.parent, remote=remote_ref(s.payload)))
        else:
            p = e.payload
            if e.kind == "dispatch" and isinstance(p, dict) and isinstance(
                p.get("measured_s"), (int, float)
            ):
                out.append(Span(e.name, track_name(e), e.t - p["measured_s"], e.t,
                                p, e.span, e.parent))
            elif e.kind == "device" and isinstance(p, dict) and isinstance(
                p.get("dur_s"), (int, float)
            ):
                out.append(Span(e.name, track_name(e), e.t, e.t + p["dur_s"],
                                p, e.span, e.parent))
            else:
                out.append(Span(e.name, track_name(e), e.t, e.t, p, e.span, e.parent))
    for opened in list(open_by_key.values()) + list(stack_by_name.values()):
        for s in opened:
            track = track_name(s)
            out.append(Span(s.name, track, s.t, t_last, s.payload, s.span,
                            s.parent, truncated=True, remote=remote_ref(s.payload)))
            if orphans is not None:
                orphans[track] = orphans.get(track, 0) + 1
    out.sort(key=lambda s: s.t0)
    return out


def span_tree(spans: Iterable[Span]) -> list[SpanNode]:
    """Fold parent links into a forest of :class:`SpanNode`.

    Orphan-to-root fallback: a span whose parent id is not among the
    resolved spans (the parent's events were evicted before the trace was
    read) becomes a root — the subtree survives instead of vanishing.  Span
    ids are allocated before their children's, so a parent id >= the span's
    own id is treated as corrupt and also falls back to root (keeps the
    forest acyclic on torn input).  Roots and children are ordered by start
    time.
    """
    nodes = [SpanNode(s) for s in spans]
    by_id: dict[int, SpanNode] = {}
    for n in nodes:
        if n.span.span:
            by_id.setdefault(n.span.span, n)
    roots: list[SpanNode] = []
    for n in nodes:
        p = n.span.parent
        parent = by_id.get(p) if p else None
        if parent is None or parent is n or (n.span.span and p >= n.span.span):
            roots.append(n)
        else:
            parent.children.append(n)
    for n in nodes:
        n.children.sort(key=lambda c: c.span.t0)
    roots.sort(key=lambda n: n.span.t0)
    return roots
