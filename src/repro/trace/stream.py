"""Durable streaming trace sessions: JSONL segments + manifest + recovery.

:class:`~repro.trace.session.Session` snapshots a run *at the end*; a crash
loses the whole trace.  A :class:`StreamingSession` is the durable
counterpart: every event is appended to an open JSONL segment file as it is
recorded (attach it to a :class:`~repro.trace.collector.TraceCollector` as a
sink), and segments rotate on a size/count budget.  Rotation is the
durability point — the closing segment is flushed **and fsynced** before it
is renamed from ``*.jsonl.open`` to ``*.jsonl``, the manifest is atomically
rewritten, and (when a profile provider is attached) the current
:class:`~repro.dispatch.profiles.ProfileStore` is snapshotted next to it.
A SIGKILLed run therefore loses at most the tail of the one open segment.

On-disk layout of a session directory::

    MANIFEST.json          # schema + git/chip/argv provenance + segment index
    segment-000000.jsonl   # closed (fsynced) segments, one Event per line
    segment-000001.jsonl
    segment-000002.jsonl.open   # the open segment a crash may truncate
    profiles.json          # ProfileStore snapshot as of the last rotation

``python -m repro.trace compact <dir> -o session.json`` folds the segments
back into the one-file session format; ``report``/``export``/``diff`` accept
segment directories directly (they compact in memory).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import threading
from typing import Any, Callable, Optional

from repro.core.events import Event
from repro.dispatch.profiles import ProfileStore
from repro.trace.session import SESSION_SCHEMA, Session, run_metadata
from repro.utils.io import atomic_write as _atomic_write

STREAM_SCHEMA = "repro.trace.stream/v1"
MANIFEST_NAME = "MANIFEST.json"
PROFILES_NAME = "profiles.json"
METRICS_NAME = "metrics.jsonl"
SEGMENT_PREFIX = "segment-"
OPEN_SUFFIX = ".open"

DEFAULT_ROTATE_EVENTS = 2048
DEFAULT_ROTATE_BYTES = 4 << 20  # 4 MiB


class StreamingSession:
    """Appends events incrementally as rotated, fsynced JSONL segments.

    Thread-safe (events arrive from the checkpoint writer thread as well as
    the main loop).  Use as a sink on a collector::

        stream = StreamingSession("run_dir", rotate_events=2048)
        stream.attach(collector)          # every collector.record() streams
        ...
        stream.close(stats=collector.stats())

    ``store_provider`` (a zero-arg callable returning a ProfileStore) makes
    each rotation also persist the measured profiles, so a crashed run keeps
    its warm-start data up to the last closed segment.

    ``max_segments=N`` bounds the directory on long-lived servers: after each
    rotation the oldest closed segments beyond N are deleted (the manifest
    counts them in ``pruned_segments``/``pruned_events``; recovery tolerates
    the resulting gaps in segment numbering).

    ``fleet_push`` (a zero-arg callable, typically
    :meth:`repro.fleet.client.FleetPusher.push`) is invoked best-effort at
    every rotation, so a long-lived server continuously feeds the central
    fleet profile store instead of only at shutdown.  Rotation-time pushes
    run on a background thread — a slow or unreachable fleet must not stall
    the traced (and locked) event path; a push still in flight makes the next
    rotation skip (deltas ride the following push).  ``close()`` pushes
    synchronously so shutdown never loses the final delta.
    """

    def __init__(
        self,
        path: str,
        *,
        rotate_events: int = DEFAULT_ROTATE_EVENTS,
        rotate_bytes: int = DEFAULT_ROTATE_BYTES,
        max_segments: Optional[int] = None,
        meta: Optional[dict[str, Any]] = None,
        chip: Optional[dict[str, Any]] = None,
        store_provider: Optional[Callable[[], ProfileStore]] = None,
        fleet_push: Optional[Callable[[], Any]] = None,
        metrics_provider: Optional[Callable[[], dict[str, Any]]] = None,
        stats_provider: Optional[Callable[[], dict[str, Any]]] = None,
        device_provider: Optional[Callable[[], dict[str, Any]]] = None,
    ) -> None:
        if rotate_events < 1:
            raise ValueError(f"rotate_events must be >= 1, got {rotate_events}")
        if max_segments is not None and max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {max_segments}")
        self.path = path
        self.rotate_events = rotate_events
        self.rotate_bytes = rotate_bytes
        self.max_segments = max_segments
        self.store_provider = store_provider
        self.fleet_push = fleet_push
        self.metrics_provider = metrics_provider
        self.stats_provider = stats_provider
        self.device_provider = device_provider
        if chip is None:
            from repro.hw.specs import default_chip

            chip = dataclasses.asdict(default_chip())
        self._manifest: dict[str, Any] = {
            "schema": STREAM_SCHEMA,
            **run_metadata(meta),
            "chip": chip,
            "rotate_events": rotate_events,
            "rotate_bytes": rotate_bytes,
            "max_segments": max_segments,
            "segments": [],
            "pruned_segments": 0,
            "pruned_events": 0,
            "closed": False,
        }
        self._lock = threading.Lock()
        self._fleet_thread: Optional[threading.Thread] = None
        self._seg_index = 0
        self._seg_events = 0
        self._seg_bytes = 0
        self._seg_file: Optional[Any] = None
        self._total_events = 0
        self._closed = False
        os.makedirs(path, exist_ok=True)
        leftover = glob.glob(os.path.join(path, f"{SEGMENT_PREFIX}*.jsonl*"))
        if leftover or os.path.exists(os.path.join(path, MANIFEST_NAME)):
            # never overwrite or silently merge with a previous session — its
            # segments may be the only copy of a crashed run's trace
            raise FileExistsError(
                f"{path} already holds a streaming trace session; compact it "
                f"(`python -m repro.trace compact {path}`) and remove the "
                "directory, or pass a fresh --trace-dir"
            )
        self._write_manifest()
        self._open_segment()

    # -- wiring ---------------------------------------------------------------

    def attach(self, collector: Any) -> "StreamingSession":
        """Register as the collector's event sink (returns self).

        Also adopts the collector's cheap loss counters
        (:meth:`~repro.trace.collector.TraceCollector.drop_counters`) as the
        manifest's ``drops`` provider unless one was passed explicitly, so
        every rotation records up-to-date drop/shed totals for ``tail`` to
        warn on."""
        collector.set_sink(self.emit)
        if self.stats_provider is None:
            self.stats_provider = getattr(collector, "drop_counters", None)
        return self

    def __enter__(self) -> "StreamingSession":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- segment plumbing -----------------------------------------------------

    def _seg_name(self, index: int) -> str:
        return f"{SEGMENT_PREFIX}{index:06d}.jsonl"

    def _open_segment(self) -> None:
        self._seg_file = open(
            os.path.join(self.path, self._seg_name(self._seg_index) + OPEN_SUFFIX), "w"
        )
        self._seg_events = 0
        self._seg_bytes = 0

    def _write_manifest(self) -> None:
        _atomic_write(
            os.path.join(self.path, MANIFEST_NAME),
            json.dumps(self._manifest, indent=1, default=repr),
        )

    def set_meta(self, key: str, value: Any) -> None:
        """Set one manifest metadata key and rewrite the manifest now.

        For run-level facts learned after the session was opened — e.g. the
        router front door records each replica's trace directory under
        ``replica_sessions`` as replicas come up, so ``repro.trace stitch``
        can discover the fleet's sessions from the frontdoor manifest alone.
        ``load_stream`` surfaces every such key in ``Session.meta``.
        """
        with self._lock:
            if self._closed:
                return
            self._manifest[key] = value
            self._write_manifest()

    def _close_segment_locked(self) -> None:
        """Flush + fsync + rename the open segment; record it in the manifest."""
        f = self._seg_file
        if f is None:
            return
        f.flush()
        os.fsync(f.fileno())
        f.close()
        self._seg_file = None
        name = self._seg_name(self._seg_index)
        os.replace(os.path.join(self.path, name + OPEN_SUFFIX),
                   os.path.join(self.path, name))
        self._manifest["segments"].append(
            {"name": name, "events": self._seg_events, "bytes": self._seg_bytes}
        )
        self._seg_index += 1
        self._prune_locked()
        self._snapshot_profiles_locked()
        self._snapshot_metrics_locked(segment=name)
        self._write_manifest()
        self._fleet_push_locked()

    def _prune_locked(self) -> None:
        """Segment retention: delete the oldest closed segments past
        ``max_segments`` so a long-lived server's --trace-dir stays bounded.
        The manifest records what was lost (count + events) and keeps only the
        surviving segments in its index — recovery tolerates the numbering gap."""
        if self.max_segments is None:
            return
        segments = self._manifest["segments"]
        while len(segments) > self.max_segments:
            victim = segments.pop(0)
            try:
                os.unlink(os.path.join(self.path, victim["name"]))
            except FileNotFoundError:
                pass
            self._manifest["pruned_segments"] += 1
            self._manifest["pruned_events"] += victim.get("events", 0)

    def _fleet_push_locked(self, sync: bool = False) -> None:
        """Feed the fleet profile store at each rotation (best effort): an
        unreachable fleet must not abort — or stall — the traced run, so
        rotation pushes run on a background thread (FleetPusher keeps its
        baseline on failure and is itself thread-safe, so a skipped or failed
        push just means those samples ride the next one).  ``sync=True``
        (close) joins any in-flight push and then pushes inline, so the final
        delta is durable before the process exits."""
        if self.fleet_push is None:
            return

        def run() -> None:
            try:
                self.fleet_push()
            except Exception as exc:
                import sys

                print(f"trace stream: fleet push failed ({type(exc).__name__}: "
                      f"{exc}); segments unaffected", file=sys.stderr)

        prev = self._fleet_thread
        if sync:
            # the push thread never takes the stream lock, so joining here
            # (under it) cannot deadlock
            if prev is not None and prev.is_alive():
                prev.join()
            run()
            return
        if prev is not None and prev.is_alive():
            return  # still pushing the previous delta; this one rides along
        self._fleet_thread = threading.Thread(
            target=run, name="trace-fleet-push", daemon=True)
        self._fleet_thread.start()

    def _snapshot_profiles_locked(self) -> None:
        """Persist the current ProfileStore next to the segments (best
        effort): a failed snapshot must not abort the event stream — the
        segments are the primary artifact, profiles are warm-start gravy."""
        if self.store_provider is None:
            return
        try:
            store = self.store_provider()
            if store is not None:
                _atomic_write(os.path.join(self.path, PROFILES_NAME), store.to_json())
                self._manifest["profiles"] = PROFILES_NAME
        except Exception as exc:
            import sys

            print(f"trace stream: profile snapshot failed ({type(exc).__name__}: "
                  f"{exc}); segments unaffected", file=sys.stderr)

    def _snapshot_metrics_locked(self, segment: Optional[str] = None) -> None:
        """Refresh the manifest's drop counters and append the current metric
        snapshot to ``metrics.jsonl`` (best effort, like profiles): one row
        per rotation gives ``repro.trace metrics`` the run's metric timeline,
        and the manifest always carries the latest snapshot + loss totals."""
        import sys
        import time as _time

        if self.stats_provider is not None:
            try:
                drops = self.stats_provider()
                if drops is not None:
                    self._manifest["drops"] = drops
            except Exception as exc:
                print(f"trace stream: drop-counter refresh failed "
                      f"({type(exc).__name__}: {exc})", file=sys.stderr)
        if self.device_provider is not None:
            # per-window device-capture coverage rides in the manifest so a
            # crashed run still knows which windows made it to disk
            try:
                dev = self.device_provider()
                if dev is not None:
                    self._manifest["device_capture"] = dev
            except Exception as exc:
                print(f"trace stream: device-capture refresh failed "
                      f"({type(exc).__name__}: {exc})", file=sys.stderr)
        if self.metrics_provider is None:
            return
        try:
            snap = self.metrics_provider()
            if snap is None:
                return
            self._manifest["metrics"] = snap
            row = {"t": _time.time(), "segment": segment, "metrics": snap}
            with open(os.path.join(self.path, METRICS_NAME), "a") as f:
                f.write(json.dumps(row, default=repr) + "\n")
        except Exception as exc:
            print(f"trace stream: metrics snapshot failed ({type(exc).__name__}: "
                  f"{exc}); segments unaffected", file=sys.stderr)

    # -- the streaming path ---------------------------------------------------

    def emit(self, event: Event) -> None:
        """Append one event to the open segment (the collector-sink entry)."""
        line = json.dumps(dataclasses.asdict(event), default=repr) + "\n"
        with self._lock:
            if self._closed:
                return
            self._seg_file.write(line)
            self._seg_file.flush()  # crash-visible immediately; fsync on rotate
            self._seg_events += 1
            self._seg_bytes += len(line)
            self._total_events += 1
            if self._seg_events >= self.rotate_events or self._seg_bytes >= self.rotate_bytes:
                self._close_segment_locked()
                self._open_segment()

    def rotate(self) -> None:
        """Force a rotation (e.g. aligned with a checkpoint): make the
        current segment durable even if it is under the rotation budget."""
        with self._lock:
            if self._closed or self._seg_events == 0:
                return
            self._close_segment_locked()
            self._open_segment()

    def close(self, stats: Optional[dict[str, Any]] = None) -> str:
        """Seal the session: final rotation + closed manifest.  Idempotent."""
        with self._lock:
            if self._closed:
                return self.path
            if self._seg_events > 0:
                self._close_segment_locked()
            elif self._seg_file is not None:
                # empty open segment: remove rather than leave a zero-byte file
                name = self._seg_name(self._seg_index) + OPEN_SUFFIX
                self._seg_file.close()
                self._seg_file = None
                os.unlink(os.path.join(self.path, name))
            # final profile + metric snapshots: anything since the last
            # rotation must survive the run (and reach the fleet)
            self._snapshot_profiles_locked()
            self._snapshot_metrics_locked(segment="final")
            self._fleet_push_locked(sync=True)
            self._manifest["closed"] = True
            self._manifest["total_events"] = self._total_events
            if stats is not None:
                self._manifest["collector"] = stats
            self._write_manifest()
            self._closed = True
        return self.path


# -- recovery / compaction ---------------------------------------------------


def is_stream_dir(path: str) -> bool:
    return os.path.isdir(path) and (
        os.path.exists(os.path.join(path, MANIFEST_NAME))
        or bool(glob.glob(os.path.join(path, f"{SEGMENT_PREFIX}*.jsonl*")))
    )


def _read_segment(path: str, lenient: bool) -> tuple[list[Event], int]:
    """Parse one JSONL segment.  ``lenient`` tolerates a torn tail line
    (the open segment of a crashed run); closed segments are fsynced and a
    parse failure there is reported too rather than raising."""
    events: list[Event] = []
    skipped = 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
                events.append(Event(**row))
            except (json.JSONDecodeError, TypeError):
                skipped += 1
                if not lenient:
                    raise
    return events, skipped


def load_stream(path: str) -> Session:
    """Recover a segment directory into a :class:`Session` (crash-safe).

    Reads the manifest for provenance, every closed ``segment-*.jsonl`` in
    order, and salvages complete lines from any ``*.open`` segment the crash
    left behind.  Dispatch decisions are rebuilt from the streamed
    ``dispatch`` events; profiles come from the last rotation's snapshot.
    """
    manifest: dict[str, Any] = {}
    mpath = os.path.join(path, MANIFEST_NAME)
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)

    closed = sorted(glob.glob(os.path.join(path, f"{SEGMENT_PREFIX}*.jsonl")))
    open_segs = sorted(glob.glob(os.path.join(path, f"{SEGMENT_PREFIX}*.jsonl{OPEN_SUFFIX}")))
    if not closed and not open_segs and not manifest:
        raise FileNotFoundError(f"{path} is not a streaming trace session "
                                f"(no {MANIFEST_NAME} or {SEGMENT_PREFIX}*.jsonl)")

    events: list[Event] = []
    skipped = 0
    for seg in closed:
        evs, bad = _read_segment(seg, lenient=True)
        events.extend(evs)
        skipped += bad
    salvaged = 0
    for seg in open_segs:
        evs, bad = _read_segment(seg, lenient=True)
        events.extend(evs)
        salvaged += len(evs)
        skipped += bad
    events.sort(key=lambda e: e.t)

    decisions = [e.payload for e in events
                 if e.kind == "dispatch" and isinstance(e.payload, dict)]
    store = None
    ppath = os.path.join(path, PROFILES_NAME)
    if os.path.exists(ppath):
        with open(ppath) as f:
            store = ProfileStore.from_json(f.read())

    meta = {k: v for k, v in manifest.items()
            if k not in ("schema", "segments", "chip", "closed")}
    meta["schema"] = SESSION_SCHEMA
    timeline = load_metrics_timeline(path)
    if timeline:
        meta["metrics_timeline"] = timeline
    meta["stream"] = {
        "dir": path,
        "schema": manifest.get("schema", STREAM_SCHEMA),
        "closed": manifest.get("closed", False),
        "segments": len(closed),
        "open_segments": len(open_segs),
        "salvaged_events": salvaged,
        "skipped_lines": skipped,
        "pruned_segments": manifest.get("pruned_segments", 0),
        "pruned_events": manifest.get("pruned_events", 0),
    }
    collector_stats = manifest.get("collector") or {}
    return Session(
        meta=meta,
        events=events,
        dropped=collector_stats.get("dropped", 0),
        capacity=collector_stats.get("capacity"),
        decisions=decisions,
        store=store,
        chip=manifest.get("chip"),
        collector_stats=collector_stats or None,
    )


def load_metrics_timeline(path: str) -> list[dict[str, Any]]:
    """Parse a session directory's per-rotation ``metrics.jsonl`` rows
    (lenient: a torn tail line from a crash is skipped, not fatal)."""
    mx = os.path.join(path, METRICS_NAME)
    rows: list[dict[str, Any]] = []
    if not os.path.exists(mx):
        return rows
    with open(mx) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return rows


def load_any(path: str) -> Session:
    """Load a one-file session OR a streaming segment directory."""
    if os.path.isdir(path):
        return load_stream(path)
    return Session.load(path)


# -- live tailing -------------------------------------------------------------


def _seg_indices(path: str) -> list[int]:
    out = set()
    for p in glob.glob(os.path.join(path, f"{SEGMENT_PREFIX}*.jsonl*")):
        digits = os.path.basename(p)[len(SEGMENT_PREFIX):].split(".", 1)[0]
        if digits.isdigit():
            out.add(int(digits))
    return sorted(out)


def _render_event(row: dict[str, Any], open_spans: dict[Any, Any]) -> str:
    """One human line per event: track, kind, depth-marked name, duration.

    ``open_spans`` maps span keys to ``(t0, depth)``; depth comes from the
    event's ``parent`` link when that parent is still open, so nested units
    (request > prefill > dispatch) indent under their ancestors live.
    """
    from repro.trace.collector import TRACK_OF

    t = row.get("t", 0.0)
    kind = str(row.get("kind", "?"))
    name = str(row.get("name", "?"))
    payload = row.get("payload")
    if kind == "dispatch":
        track = "dispatch"
    elif kind == "device":
        dev = payload.get("device") if isinstance(payload, dict) else None
        track = f"device:{dev}" if dev else "device"
    else:
        track = TRACK_OF.get(name, "other")
    key = ("span", row["span"]) if row.get("span") else ("name", name)
    parent = row.get("parent") or 0
    pent = open_spans.get(("span", parent)) if parent else None
    depth = (pent[1] + 1) if pent is not None else 0
    extra = ""
    if kind == "spawn":
        open_spans[key] = (t, depth)
    elif kind == "exit":
        ent = open_spans.pop(key, None)
        if ent is not None:
            extra = f"dur={1e3 * (t - ent[0]):.3f}ms"
            depth = ent[1]
    elif kind == "dispatch" and isinstance(payload, dict):
        extra = f"{payload.get('backend')} ({payload.get('source')})"
        if isinstance(payload.get("measured_s"), (int, float)):
            extra += f" dur={1e3 * payload['measured_s']:.3f}ms"
    elif kind == "device" and isinstance(payload, dict) and isinstance(
        payload.get("dur_s"), (int, float)
    ):
        extra = f"dur={1e3 * payload['dur_s']:.3f}ms"
    marked = "· " * depth + name  # depth markers: one dot per ancestor level
    return f"{t:14.6f}  {track:<10} {kind:<8} {marked:<18} {extra}".rstrip()


class _Tailer:
    """Incremental reader over a live segment directory.

    Tracks (segment index, byte offset); a segment is drained from its
    ``.open`` file and finished when its closed (renamed) form exists — the
    rename preserves content, so the offset carries over.  Pruned/missing
    indices are skipped (retention deletes the oldest closed segments)."""

    def __init__(self, path: str) -> None:
        self.path = path
        indices = _seg_indices(path)
        self.index = indices[0] if indices else 0
        self.offset = 0
        self.open_spans: dict[Any, tuple[float, int]] = {}
        self.last_dropped = 0
        self.last_sampled_out = 0

    def _paths(self, index: int) -> tuple[str, str]:
        name = os.path.join(self.path, f"{SEGMENT_PREFIX}{index:06d}.jsonl")
        return name, name + OPEN_SUFFIX

    def poll(self) -> list[str]:
        """Render every complete line that appeared since the last poll."""
        out: list[str] = []
        while True:
            closed, open_ = self._paths(self.index)
            is_closed = os.path.exists(closed)
            target = closed if is_closed else open_
            if not os.path.exists(target):
                indices = _seg_indices(self.path)
                if self.index in indices:
                    # raced a rotation rename between the closed/open exists
                    # checks: the segment is still there, just under its
                    # other name — re-evaluate, this is not a gap
                    continue
                later = [i for i in indices if i > self.index]
                if later:  # pruned or skipped index: jump the gap, visibly —
                    # a silent skip would read as "those events never happened"
                    out.append(
                        f"# gap: segments {self.index:06d}..{later[0] - 1:06d} "
                        "pruned by retention"
                        + (" (partially shown)" if self.offset else "")
                    )
                    self.index, self.offset = later[0], 0
                    continue
                return out
            try:
                with open(target) as f:
                    f.seek(self.offset)
                    chunk = f.read()
            except FileNotFoundError:
                # raced a rotation rename (or retention unlink) between the
                # exists() check and the open: re-evaluate from the top
                continue
            # only complete lines; a torn tail stays buffered in the file
            end = chunk.rfind("\n") + 1
            for line in chunk[:end].splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn line mid-segment (crash remnant)
                out.append(_render_event(row, self.open_spans))
            self.offset += end
            if is_closed:  # fully drained and sealed: move on
                self.index += 1
                self.offset = 0
            else:
                return out

    def stream_closed(self) -> bool:
        try:
            with open(os.path.join(self.path, MANIFEST_NAME)) as f:
                return bool(json.load(f).get("closed"))
        except (FileNotFoundError, json.JSONDecodeError):
            return False

    def drop_warning(self) -> Optional[str]:
        """One-line warning when the manifest's loss counters grew since the
        previous check (rotations refresh them): drops mean the stream is
        complete but the in-memory rings are lossy — the reader should know
        before trusting ring-derived reports."""
        try:
            with open(os.path.join(self.path, MANIFEST_NAME)) as f:
                drops = json.load(f).get("drops") or {}
        except (FileNotFoundError, json.JSONDecodeError):
            return None
        dropped = int(drops.get("dropped") or 0)
        sampled = int(drops.get("sampled_out") or 0)
        if dropped <= self.last_dropped and sampled <= self.last_sampled_out:
            return None
        parts = []
        if dropped > self.last_dropped:
            by = {k or "main": v for k, v in (drops.get("by_track") or {}).items() if v}
            parts.append(f"{dropped} events dropped by bounded rings "
                         f"(+{dropped - self.last_dropped}) by_track={by}")
        if sampled > self.last_sampled_out:
            parts.append(f"{sampled} events shed by adaptive sampling "
                         f"(+{sampled - self.last_sampled_out})")
        self.last_dropped, self.last_sampled_out = dropped, sampled
        return "# WARNING: " + "; ".join(parts)


def tail_stream(path: str, *, once: bool = False, poll_s: float = 0.2,
                out: Any = None) -> int:
    """Follow a ``--trace-dir`` like ``tail -f`` (one rendered line/event).

    Re-stats on rotation (the open segment's rename to its closed form is
    detected and the offset carried over), skips pruned segment indices, and
    returns once the manifest reports the session closed and every line has
    been printed.  ``once=True`` drains what exists now and returns (tests,
    scripting).  Ctrl-C returns 0.
    """
    import sys
    import time as _time

    out = sys.stdout if out is None else out
    if not is_stream_dir(path):
        raise FileNotFoundError(f"{path} is not a streaming trace session")
    tailer = _Tailer(path)
    try:
        while True:
            for line in tailer.poll():
                print(line, file=out)
            warning = tailer.drop_warning()
            if warning:
                print(warning, file=out)
            out.flush()
            if once or tailer.stream_closed():
                # one final drain: lines written between poll and the closed
                # manifest must not be lost
                for line in tailer.poll():
                    print(line, file=out)
                warning = tailer.drop_warning()
                if warning:
                    print(warning, file=out)
                out.flush()
                return 0
            _time.sleep(poll_s)
    except KeyboardInterrupt:
        return 0
