"""Design spaces: the candidate config grid per (kernel op, backend tier).

A :class:`KernelSpace` names the tunable knobs of one kernel entry point
(block/tile sizes, scan chunk lengths), the hand-picked defaults they ship
with, and a fixed *sweep workload* — the shape the tuner measures on, chosen
to match the kernel-suite benchmarks.  Enumeration is constraint-aware:

* **alignment** — Pallas matmul block dims must be MXU_ALIGN (128) multiples
  for full systolic-array utilisation; chunked-path loop lengths need only
  VPU sublane (8) alignment;
* **divisibility** — a block/chunk must tile the workload dim it walks
  (the chunked scans assert ``T % chunk == 0``);
* **VMEM feasibility** — a config whose double-buffered tiles + scratch
  exceed the chip's VMEM budget (:func:`repro.core.roofline.fits_vmem`)
  is never enumerated, let alone timed.

Each space also prices a point a priori (:meth:`KernelSpace.roofline_s`):
compute + memory roofline terms plus a per-block launch/loop overhead and
the padding waste of blocks that don't divide evenly.  That surface is what
the :class:`~repro.tune.prune.RooflinePruner` cuts against and what the
``synthetic`` sweep mode returns as a deterministic pseudo-measurement.

This module is deliberately jax-free: the fleet daemon, CI smoke jobs, and
multiprocessing sweep workers in ``synthetic`` mode all enumerate and price
spaces without paying for a jax import.  Real measurement lives in
:mod:`repro.tune.explore`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
from typing import Any, Callable, Mapping, Optional

from repro.core.roofline import fits_vmem, vmem_footprint_bytes
from repro.dispatch.profiles import encode_config
from repro.hw.specs import MXU_ALIGN, VPU_SUBLANES, ChipSpec, default_chip

# Static per-tier cost factors (mirrors repro.dispatch.registry, which is
# jax-importing): sustained fraction of peak FLOP/s, sustained fraction of
# HBM bandwidth, per-launch/per-loop-iteration overhead in seconds.
_TIER = {
    "pallas": (0.85, 0.6, 2e-6),
    "chunked": (0.65, 0.5, 4e-6),
    "ref": (0.6, 0.4, 2e-7),
}

F32 = 4  # sweep workloads are float32


def _sig(*arrays: tuple[str, tuple[int, ...]]) -> str:
    """Analytic ``repro.dispatch.profiles.signature`` of a workload, computed
    without materialising arrays (or importing jax)."""
    return ";".join(
        f"{dtype}[{','.join(map(str, shape))}]" for dtype, shape in arrays
    )


def _pad(n: int, block: int) -> int:
    """Elements after padding ``n`` up to a multiple of ``block``."""
    return ((n + block - 1) // block) * block


@dataclasses.dataclass(frozen=True)
class ConfigPoint:
    """One candidate configuration of one (op, backend)."""

    op: str
    backend: str
    params: Mapping[str, Any]

    @property
    def config(self) -> str:
        return encode_config(self.params)


@dataclasses.dataclass(frozen=True)
class KernelSpace:
    """The tunable design space of one kernel entry point on one backend.

    ``grid`` maps each knob to its candidate values; ``defaults`` is the
    hand-picked shipping config (always enumerated, never pruned — the tuner
    must beat it on equal terms, not by forgetting it).  ``divides`` maps a
    knob to the workload dim it must tile exactly.  ``cost`` returns
    ``(flops, hbm_bytes, launches)`` for a param dict; ``tiles`` returns
    ``(tiles, scratch)`` rows for the VMEM footprint model.
    """

    op: str
    backend: str
    impl: str
    grid: Mapping[str, tuple[int, ...]]
    defaults: Mapping[str, int]
    align: Mapping[str, int]
    divides: Mapping[str, str]
    workload: Mapping[str, int]
    sig: str
    cost: Callable[[Mapping[str, int], Mapping[str, int]], tuple[float, float, float]]
    tiles: Callable[[Mapping[str, int], Mapping[str, int]], tuple[list, list]]

    @property
    def key(self) -> str:
        return f"{self.op}/{self.backend}"

    @property
    def default_config(self) -> str:
        return encode_config(self.defaults)

    def feasible(self, params: Mapping[str, int],
                 chip: Optional[ChipSpec] = None) -> bool:
        chip = chip or default_chip()
        for name, value in params.items():
            if value % self.align.get(name, 1) != 0:
                return False
            dim = self.divides.get(name)
            if dim is not None and self.workload[dim] % min(value, self.workload[dim]) != 0:
                return False
            if value <= 0:
                return False
        tiles, scratch = self.tiles(params, self.workload)
        return fits_vmem(vmem_footprint_bytes(tiles, scratch), chip)

    def points(self, chip: Optional[ChipSpec] = None) -> list[ConfigPoint]:
        """Feasible candidate points, defaults included, deterministic order."""
        chip = chip or default_chip()
        names = sorted(self.grid)
        seen: list[ConfigPoint] = []
        for values in itertools.product(*(self.grid[n] for n in names)):
            params = dict(zip(names, values))
            if self.feasible(params, chip):
                seen.append(ConfigPoint(self.op, self.backend, params))
        if not any(p.params == dict(self.defaults) for p in seen):
            # hand-picked defaults are known-good: enumerate them even if the
            # grid was narrowed past them
            seen.append(ConfigPoint(self.op, self.backend, dict(self.defaults)))
        return seen

    def roofline_s(self, params: Mapping[str, int],
                   chip: Optional[ChipSpec] = None) -> float:
        """A-priori cost of one point: roofline terms + launch overhead."""
        chip = chip or default_chip()
        flop_eff, hbm_eff, launch_s = _TIER[self.backend]
        flops, hbm_bytes, launches = self.cost(params, self.workload)
        return (
            flops / (flop_eff * chip.peak_flops_f32)
            + hbm_bytes / (hbm_eff * chip.hbm_bw)
            + launches * launch_s
        )

    def synthetic_s(self, params: Mapping[str, int],
                    chip: Optional[ChipSpec] = None) -> float:
        """Deterministic pseudo-measurement for ``--tune-mode synthetic``.

        The roofline prediction perturbed by a stable per-config hash (±5%),
        so sweeps are reproducible across processes and worker counts while
        still exercising the measured-beats-predicted argmin path.
        """
        digest = hashlib.sha1(
            f"{self.op}|{self.backend}|{encode_config(params)}".encode()
        ).digest()
        jitter = 1.0 + 0.05 * (digest[0] / 255.0)
        return self.roofline_s(params, chip) * jitter


# ---------------------------------------------------------------------------
# Per-kernel space definitions
# ---------------------------------------------------------------------------


def _flash_cost(backend: str):
    def cost(p: Mapping[str, int], w: Mapping[str, int]):
        B, S, H, D = w["B"], w["S"], w["H"], w["D"]
        bq = p.get("block_q", S)
        bk = p["block_k"]
        sq, sk = _pad(S, bq), _pad(S, bk)
        flops = 4.0 * B * H * sq * sk * D  # qk^T + pv, causal ~x0.5 folded out
        if backend == "chunked":
            flops /= 2.0  # lax.scan skips fully-masked KV blocks' second half
        hbm = F32 * B * H * S * D * 4  # q, k, v read + o write
        launches = B * H * math.ceil(S / bq) * math.ceil(S / bk)
        return flops, hbm, launches

    return cost


def _flash_tiles(p: Mapping[str, int], w: Mapping[str, int]):
    D = w["D"]
    bq = p.get("block_q", 128)
    bk = p["block_k"]
    tiles = [((bq, D), F32), ((bk, D), F32), ((bk, D), F32), ((bq, D), F32)]
    scratch = [((bq, bk), F32), ((bq,), F32), ((bq,), F32)]  # scores, m, l
    return tiles, scratch


def _decode_cost(p: Mapping[str, int], w: Mapping[str, int]):
    B, S, H, D = w["B"], w["S"], w["H"], w["D"]
    bs = p["block_s"]
    s_eff = _pad(S, bs)
    flops = 4.0 * B * H * s_eff * D
    hbm = F32 * B * (2 * S * H * D + 2 * H * D)  # caches + q/o
    launches = B * math.ceil(S / bs)
    return flops, hbm, launches


def _decode_tiles(p: Mapping[str, int], w: Mapping[str, int]):
    H, D = w["H"], w["D"]
    bs = p["block_s"]
    tiles = [((H, D), F32), ((bs, H, D), F32), ((bs, H, D), F32), ((H, D), F32)]
    scratch = [((H, bs), F32)]
    return tiles, scratch


def _gmm_cost(p: Mapping[str, int], w: Mapping[str, int]):
    E, C, D, Fdim = w["E"], w["C"], w["D"], w["F"]
    bc, bf, bd = p["block_c"], p["block_f"], p["block_d"]
    c_eff, f_eff, d_eff = _pad(C, bc), _pad(Fdim, bf), _pad(D, bd)
    flops = 2.0 * E * c_eff * d_eff * f_eff
    hbm = F32 * E * (C * D + D * Fdim + C * Fdim)
    launches = E * math.ceil(C / bc) * math.ceil(Fdim / bf) * math.ceil(D / bd)
    return flops, hbm, launches


def _gmm_tiles(p: Mapping[str, int], w: Mapping[str, int]):
    bc, bf, bd = p["block_c"], p["block_f"], p["block_d"]
    tiles = [((bc, bd), F32), ((bd, bf), F32), ((bc, bf), F32)]
    scratch = [((bc, bf), F32)]  # f32 accumulator
    return tiles, scratch


def _scan_cost(state_cols: str):
    """Chunked linear-scan cost: within-chunk pairwise work is O(T·L), the
    chunk loop costs one launch per T/L iterations — the classic small-chunk
    (loop-bound) vs large-chunk (compute/memory-bound) trade."""

    def cost(p: Mapping[str, int], w: Mapping[str, int]):
        B, T = w["B"], w["T"]
        width = w[state_cols]
        rows = w.get("K", w.get("DI"))
        L = min(p["chunk"], T)
        flops = 4.0 * B * T * L * rows + 2.0 * B * T * rows * width
        hbm = F32 * B * T * rows * 6
        launches = math.ceil(T / L)
        return flops, hbm, launches

    return cost


def _rwkv_tiles(p: Mapping[str, int], w: Mapping[str, int]):
    H, K, V = w["H"], w["K"], w["V"]
    L = min(p["chunk"], w["T"])
    tiles = [((L, H, K), F32)] * 4 + [((L, H, V), F32)]
    scratch = [((L, L, K), F32), ((H, K, V), F32)]  # pairwise decay + state
    return tiles, scratch


def _mamba_tiles(p: Mapping[str, int], w: Mapping[str, int]):
    DI, N = w["DI"], w["N"]
    L = min(p["chunk"], w["T"])
    tiles = [((L, DI), F32)] * 2 + [((L, N), F32)] * 2
    scratch = [((L, DI, N), F32)]  # per-chunk expanded state
    return tiles, scratch


def default_spaces() -> dict[str, KernelSpace]:
    """The shipped design spaces, keyed ``"op/backend"``.

    Workload shapes mirror ``benchmarks/kernel_bench.py`` so tuned winners
    transfer directly to the bench suite and the serving/training drivers.
    """
    spaces = [
        KernelSpace(
            op="flash_attention", backend="pallas", impl="pallas",
            grid={"block_q": (128, 256, 512), "block_k": (128, 256, 512)},
            defaults={"block_q": 128, "block_k": 128},
            align={"block_q": MXU_ALIGN, "block_k": MXU_ALIGN},
            divides={"block_q": "S", "block_k": "S"},
            workload={"B": 1, "S": 512, "H": 4, "D": 64},
            sig=_sig(("float32", (1, 512, 4, 64)), ("float32", (1, 512, 4, 64)),
                     ("float32", (1, 512, 4, 64))),
            cost=_flash_cost("pallas"), tiles=_flash_tiles,
        ),
        KernelSpace(
            op="flash_attention", backend="chunked", impl="chunked",
            grid={"block_k": (32, 64, 128, 256, 512)},
            defaults={"block_k": 512},
            align={"block_k": VPU_SUBLANES},
            divides={"block_k": "S"},
            workload={"B": 1, "S": 512, "H": 4, "D": 64},
            sig=_sig(("float32", (1, 512, 4, 64)), ("float32", (1, 512, 4, 64)),
                     ("float32", (1, 512, 4, 64))),
            cost=_flash_cost("chunked"), tiles=_flash_tiles,
        ),
        KernelSpace(
            op="decode_attention", backend="pallas", impl="pallas",
            grid={"block_s": (128, 256, 512, 1024)},
            defaults={"block_s": 512},
            align={"block_s": MXU_ALIGN},
            divides={"block_s": "S"},
            workload={"B": 4, "S": 1024, "H": 4, "D": 64},
            sig=_sig(("float32", (4, 4, 64)), ("float32", (4, 1024, 4, 64)),
                     ("float32", (4, 1024, 4, 64)), ("int32", (4, 1024)),
                     ("int32", (4,))),
            cost=_decode_cost, tiles=_decode_tiles,
        ),
        KernelSpace(
            op="moe_gmm", backend="pallas", impl="pallas",
            grid={"block_c": (128, 256), "block_f": (128, 256),
                  "block_d": (128, 256)},
            defaults={"block_c": 128, "block_f": 128, "block_d": 256},
            align={"block_c": MXU_ALIGN, "block_f": MXU_ALIGN,
                   "block_d": MXU_ALIGN},
            divides={"block_c": "C", "block_f": "F", "block_d": "D"},
            workload={"E": 4, "C": 256, "D": 256, "F": 256},
            sig=_sig(("float32", (4, 256, 256)), ("float32", (4, 256, 256))),
            cost=_gmm_cost, tiles=_gmm_tiles,
        ),
        KernelSpace(
            op="rwkv6_scan", backend="chunked", impl="chunked",
            grid={"chunk": (8, 16, 32, 64, 128)},
            defaults={"chunk": 32},
            align={"chunk": VPU_SUBLANES},
            divides={"chunk": "T"},
            workload={"B": 1, "T": 256, "H": 4, "K": 64, "V": 64},
            sig=_sig(("float32", (1, 256, 4, 64)), ("float32", (1, 256, 4, 64)),
                     ("float32", (1, 256, 4, 64)), ("float32", (1, 256, 4, 64)),
                     ("float32", (4, 64)), ("float32", (1, 4, 64, 64))),
            cost=_scan_cost("V"), tiles=_rwkv_tiles,
        ),
        KernelSpace(
            op="mamba_scan", backend="chunked", impl="chunked",
            grid={"chunk": (16, 32, 64, 128, 256)},
            defaults={"chunk": 128},
            align={"chunk": VPU_SUBLANES},
            divides={"chunk": "T"},
            workload={"B": 1, "T": 256, "DI": 256, "N": 16},
            sig=_sig(("float32", (1, 256, 256)), ("float32", (1, 256, 256)),
                     ("float32", (256, 16)), ("float32", (1, 256, 16)),
                     ("float32", (1, 256, 16)), ("float32", (256,)),
                     ("float32", (1, 256, 16))),
            cost=_scan_cost("N"), tiles=_mamba_tiles,
        ),
    ]
    return {s.key: s for s in spaces}
