"""Roofline pruning: cut the design space before any timing happens.

The sweep's cost is dominated by measured points (each pays warmup + repeats
of a real kernel execution); the roofline model is free.  So the pruner
prices every candidate a priori and drops the ones predicted worse than
``ratio`` x the best prediction — the "achievable bound" for this space.

Two safety rails:

* the hand-picked **default point is never pruned** — the tuner's claim is
  "measured winner beats the shipped default", which is only meaningful if
  the default was measured in the same sweep;
* ``ratio`` is deliberately loose (4x by default): the model only has to be
  right about *order of magnitude*, not ranking — a point the model misprices
  by less than the ratio still gets timed, so the measured argmin corrects
  the model (measured-beats-estimated, same contract as the dispatcher).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.hw.specs import ChipSpec, default_chip
from repro.tune.space import ConfigPoint, KernelSpace

DEFAULT_PRUNE_RATIO = 4.0


@dataclasses.dataclass(frozen=True)
class PrunedPoint:
    point: ConfigPoint
    predicted_s: float
    bound_s: float


class RooflinePruner:
    """Keep candidates predicted within ``ratio`` x the space's best point."""

    def __init__(self, chip: Optional[ChipSpec] = None,
                 ratio: float = DEFAULT_PRUNE_RATIO) -> None:
        if ratio < 1.0:
            raise ValueError(f"prune ratio must be >= 1.0, got {ratio}")
        self.chip = chip or default_chip()
        self.ratio = ratio

    def prune(
        self, space: KernelSpace, points: list[ConfigPoint]
    ) -> tuple[list[ConfigPoint], list[PrunedPoint]]:
        """Split candidates into (survivors, pruned); order preserved."""
        if not points:
            return [], []
        predicted = {p.config: space.roofline_s(p.params, self.chip) for p in points}
        bound = min(predicted.values())
        kept: list[ConfigPoint] = []
        cut: list[PrunedPoint] = []
        for p in points:
            if p.config == space.default_config or predicted[p.config] <= self.ratio * bound:
                kept.append(p)
            else:
                cut.append(PrunedPoint(p, predicted[p.config], bound))
        return kept, cut
