"""The Explorer: parallel design-space sweeps feeding the ProfileStore.

A sweep is the lumos-style allocation-grid enumeration from the ROADMAP:
enumerate candidate config points per kernel (:mod:`repro.tune.space`), cut
the obviously-bad ones with the roofline model (:mod:`repro.tune.prune`),
then time the survivors across a multiprocessing worker pool with per-point
warmup/repeat control.  Every measurement lands in the
:class:`~repro.dispatch.profiles.ProfileStore` as an ordinary sample under
the point's ``(op, backend, sig, config)`` key — so a driver-attached
:class:`~repro.fleet.client.FleetPusher` delta-pushes tuned winners with no
tuner-specific fleet plumbing, and a later run's fleet pull makes every
already-measured point *warm*, which the Explorer skips (``--tune sweep``
on a warm-started run reports ``sweep_points == 0``).

Sweep modes:

* ``real``       time actual kernel executions; Pallas spaces only on TPU;
* ``interpret``  same, but Pallas spaces run under ``interpret=True``
                 off-TPU (functional sweep of the full space on CPU);
* ``synthetic``  deterministic analytic pseudo-measurements, no jax import —
                 CI smoke and the determinism tests.

The whole sweep is one ``tune_run`` lifecycle span; each pruned or measured
point is a ``tune`` event under it, and each per-space winner a ``tune``
event with ``winner: true`` — the metrics sink derives
``repro_tune_points_total{op,pruned}`` and ``repro_tune_best_speedup{op}``
from exactly these.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Mapping, Optional

from repro.core.events import GLOBAL_LOG, EventLog
from repro.dispatch.profiles import ProfileStore, decode_config, encode_config
from repro.hw.specs import ChipSpec, default_chip
from repro.tune.prune import DEFAULT_PRUNE_RATIO, RooflinePruner
from repro.tune.space import KernelSpace, default_spaces

MODES = ("real", "interpret", "synthetic")


@dataclasses.dataclass(frozen=True)
class SweepSettings:
    mode: str = "interpret"
    warmup: int = 1
    repeats: int = 3
    workers: int = 0  # 0 = in-process (deterministic single stream)
    prune_ratio: float = DEFAULT_PRUNE_RATIO

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")


# ---------------------------------------------------------------------------
# Measurement (runs in-process or inside spawn workers)
# ---------------------------------------------------------------------------


def _arr(shape: tuple[int, ...], seed: int):
    """Deterministic float32 inputs in (-0.5, 0.5) without an RNG dependency."""
    import jax.numpy as jnp

    n = math.prod(shape)
    x = (jnp.arange(n, dtype=jnp.float32) * 0.6180339887 + seed * 0.37) % 1.0
    return (x - 0.5).reshape(shape)


def _run_flash(space: KernelSpace, impl: str) -> Callable[[], Any]:
    import jax

    from repro.kernels import ops

    w = space.workload
    shape = (w["B"], w["S"], w["H"], w["D"])
    q, k, v = _arr(shape, 1), _arr(shape, 2), _arr(shape, 3)
    # fresh closure per point (each config must trace — and so read the tuned
    # table — on its own jit cache entry); inputs passed as arguments, not
    # captured constants, or XLA constant-folds the whole workload away
    fn = jax.jit(lambda a, b, c: ops.attention(a, b, c, causal=True, impl=impl))
    return lambda: fn(q, k, v)


def _run_decode(space: KernelSpace, impl: str) -> Callable[[], Any]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    w = space.workload
    B, S, H, D = w["B"], w["S"], w["H"], w["D"]
    q = _arr((B, H, D), 1)
    k_cache, v_cache = _arr((B, S, H, D), 2), _arr((B, S, H, D), 3)
    pos_ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cur_pos = jnp.full((B,), S, dtype=jnp.int32)
    fn = jax.jit(
        lambda *a: ops.decode_attention(*a, impl=impl)
    )
    return lambda: fn(q, k_cache, v_cache, pos_ids, cur_pos)


def _run_gmm(space: KernelSpace, impl: str) -> Callable[[], Any]:
    import jax

    from repro.kernels import ops

    w = space.workload
    x = _arr((w["E"], w["C"], w["D"]), 1)
    wt = _arr((w["E"], w["D"], w["F"]), 2)
    fn = jax.jit(lambda a, b: ops.gmm(a, b, impl=impl))
    return lambda: fn(x, wt)


def _run_rwkv6(space: KernelSpace, impl: str) -> Callable[[], Any]:
    import jax

    from repro.kernels import ops

    wl = space.workload
    B, T, H, K, V = wl["B"], wl["T"], wl["H"], wl["K"], wl["V"]
    r, k, v = _arr((B, T, H, K), 1), _arr((B, T, H, K), 2), _arr((B, T, H, K), 3)
    w = 0.5 + 0.45 * _arr((B, T, H, K), 4)  # decay factors in (0.275, 0.725)
    u = _arr((H, K), 5)
    state = _arr((B, H, K, V), 6)
    fn = jax.jit(lambda *a: ops.rwkv6_scan(*a, impl=impl))
    return lambda: fn(r, k, v, w, u, state)


def _run_mamba(space: KernelSpace, impl: str) -> Callable[[], Any]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    wl = space.workload
    B, T, DI, N = wl["B"], wl["T"], wl["DI"], wl["N"]
    x = _arr((B, T, DI), 1)
    dt = 0.01 + 0.1 * jnp.abs(_arr((B, T, DI), 2))
    A = -0.1 - jnp.abs(_arr((DI, N), 3))
    Bm, C = _arr((B, T, N), 4), _arr((B, T, N), 5)
    D = _arr((DI,), 6)
    state = _arr((B, DI, N), 7)
    fn = jax.jit(lambda *a: ops.mamba_scan(*a, impl=impl))
    return lambda: fn(x, dt, A, Bm, C, D, state)


_RUNNERS: dict[str, Callable[[KernelSpace, str], Callable[[], Any]]] = {
    "flash_attention": _run_flash,
    "decode_attention": _run_decode,
    "moe_gmm": _run_gmm,
    "rwkv6_scan": _run_rwkv6,
    "mamba_scan": _run_mamba,
}


def _measure(space: KernelSpace, params: Mapping[str, int], mode: str,
             warmup: int, repeats: int) -> list[float]:
    """Per-rep wall-times of one config point (synthetic: analytic, exact)."""
    if mode == "synthetic":
        return [space.synthetic_s(params)] * max(repeats, 1)
    import jax

    from repro.kernels import ops

    # the override table must be live while jit TRACES the thunk (first call),
    # so the whole warmup+timing loop runs inside the scope
    with ops.tuned_scope({space.op: {space.impl: dict(params)}}):
        thunk = _RUNNERS[space.op](space, space.impl)
        for _ in range(max(warmup, 0)):
            jax.block_until_ready(thunk())
        out: list[float] = []
        for _ in range(max(repeats, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(thunk())
            out.append(time.perf_counter() - t0)
    return out


def _worker_measure(task: tuple) -> tuple[str, str, list[float]]:
    """Pool entry point (module-level: spawn workers pickle by reference)."""
    space_key, params, mode, warmup, repeats = task
    space = default_spaces()[space_key]
    return space_key, encode_config(params), _measure(space, params, mode, warmup, repeats)


# ---------------------------------------------------------------------------
# Explorer
# ---------------------------------------------------------------------------


class Explorer:
    """Sweep design spaces, feed the store, report winners."""

    def __init__(
        self,
        store: ProfileStore,
        *,
        chip: Optional[ChipSpec] = None,
        spaces: Optional[dict[str, KernelSpace]] = None,
        log: Optional[EventLog] = None,
        settings: Optional[SweepSettings] = None,
    ) -> None:
        self.store = store
        self.chip = chip or default_chip()
        self.spaces = spaces if spaces is not None else default_spaces()
        self.log = GLOBAL_LOG if log is None else log
        self.settings = settings or SweepSettings()
        # sweep samples carry the same provenance stamps dispatcher samples
        # do, so age_out treats tuned points identically
        from repro.trace.session import git_sha

        self.store.set_stamp(git_sha=git_sha(), chip=self.chip.name)

    def _selected(self, ops_filter: Optional[list[str]]) -> list[KernelSpace]:
        spaces = [
            s for s in self.spaces.values()
            if ops_filter is None or s.op in ops_filter
        ]
        if self.settings.mode == "real":
            # off-TPU, Pallas only lowers under interpret=True; a "real"
            # sweep must not publish interpret timings as pallas winners
            import jax

            if jax.default_backend() != "tpu":
                spaces = [s for s in spaces if s.backend != "pallas"]
        return spaces

    def sweep(self, ops_filter: Optional[list[str]] = None) -> dict[str, Any]:
        st = self.settings
        # a point is only usable by the dispatcher once warm; never measure
        # fewer reps than the warmth threshold
        repeats = max(st.repeats, self.store.min_samples)
        spaces = self._selected(ops_filter)
        pruner = RooflinePruner(self.chip, st.prune_ratio)

        summary: dict[str, Any] = {
            "mode": st.mode, "workers": st.workers, "prune_ratio": st.prune_ratio,
            "spaces": len(spaces), "points_total": 0, "pruned": 0,
            "skipped_warm": 0, "sweep_points": 0, "winners": {},
        }
        tasks: list[tuple] = []
        by_key = {s.key: s for s in spaces}
        with self.log.lifecycle("tune_run", {
            "mode": st.mode, "spaces": sorted(by_key), "workers": st.workers,
        }):
            for space in spaces:
                points = space.points(self.chip)
                kept, cut = pruner.prune(space, points)
                summary["points_total"] += len(points)
                summary["pruned"] += len(cut)
                for c in cut:
                    self.log.record("tune", space.op, {
                        "op": space.op, "backend": space.backend,
                        "sig": space.sig, "config": c.point.config,
                        "pruned": True, "predicted_s": c.predicted_s,
                        "bound_s": c.bound_s,
                    })
                for p in kept:
                    if self.store.warm(space.op, space.backend, space.sig, p.config):
                        summary["skipped_warm"] += 1
                    else:
                        tasks.append((space.key, dict(p.params), st.mode,
                                      st.warmup, repeats))
            summary["sweep_points"] = len(tasks)

            if st.workers > 0 and len(tasks) > 1:
                import multiprocessing

                ctx = multiprocessing.get_context("spawn")
                with ctx.Pool(min(st.workers, len(tasks))) as pool:
                    results = pool.map(_worker_measure, tasks)
            else:
                results = [_worker_measure(t) for t in tasks]

            # record in sorted (space, config) order: the store's content must
            # not depend on worker scheduling
            for space_key, config, reps in sorted(results, key=lambda r: (r[0], r[1])):
                space = by_key[space_key]
                for s in reps:
                    self.store.record(space.op, space.backend, space.sig, s,
                                      config=config)
                self.log.record("tune", space.op, {
                    "op": space.op, "backend": space.backend, "sig": space.sig,
                    "config": config, "pruned": False, "reps": len(reps),
                    "min_s": min(reps),
                })

            for space in spaces:
                win = self._winner(space)
                if win is not None:
                    summary["winners"][space.key] = win
                    self.log.record("tune", space.op, {"winner": True, **win})
        return summary

    def _winner(self, space: KernelSpace) -> Optional[dict[str, Any]]:
        best = self.store.best_config(space.op, space.backend, space.sig)
        if best is None:
            return None
        config, best_s = best
        default_e = self.store.entry(space.op, space.backend, space.sig,
                                     space.default_config)
        default_s = default_e.min_s if default_e and default_e.count else None
        win: dict[str, Any] = {
            "op": space.op, "backend": space.backend, "sig": space.sig,
            "config": config, "best_s": best_s,
        }
        if default_s is not None:
            win["default_s"] = default_s
            # >= 1.0 by construction: the default point is always enumerated,
            # never pruned, and competes in the same argmin
            win["speedup"] = default_s / best_s if best_s > 0 else 1.0
        return win


# ---------------------------------------------------------------------------
# Winner application (the consumer side)
# ---------------------------------------------------------------------------


def winners_from_store(
    store: ProfileStore, spaces: Optional[dict[str, KernelSpace]] = None
) -> tuple[dict[str, dict[str, dict[str, Any]]], dict[str, dict[str, Any]]]:
    """Argmin config per space from whatever the store holds (this run's
    sweep, a ``--profile-in`` file, or a fleet pull).

    Returns ``(table, details)``: ``table`` is the ``kernels.ops`` override
    table ``{op: {impl: params}}`` (empty-config winners — the hand-picked
    default won — contribute nothing), ``details`` records per-space
    provenance for driver JSON.
    """
    spaces = spaces if spaces is not None else default_spaces()
    table: dict[str, dict[str, dict[str, Any]]] = {}
    details: dict[str, dict[str, Any]] = {}
    for space in spaces.values():
        best = store.best_config(space.op, space.backend, space.sig)
        if best is None:
            continue
        config, best_s = best
        details[space.key] = {"config": config, "best_s": best_s}
        if not config:
            continue  # legacy/default point won: nothing to override
        table.setdefault(space.op, {})[space.impl] = decode_config(config)
    return table, details


def apply_winners(table: Mapping[str, Mapping[str, Mapping[str, Any]]]) -> int:
    """Install winners into ``kernels.ops`` (call before jit tracing).

    Returns the number of (op, impl) overrides applied.  Imports ops lazily:
    jax-free callers (CLI summaries) can compute winners without applying.
    """
    from repro.kernels import ops

    ops.set_tuned_configs(table)
    return sum(len(impls) for impls in table.values())


def driver_tune(
    policy: str,
    dispatcher: Any,
    log: EventLog,
    *,
    ops_filter: Optional[list[str]] = None,
    mode: str = "interpret",
    workers: int = 0,
    warmup: int = 1,
    repeats: int = 3,
    prune_ratio: float = DEFAULT_PRUNE_RATIO,
) -> dict[str, Any]:
    """The ``--tune {cached,sweep}`` wiring shared by both launch drivers.

    Call after the fleet warm-start (pulled config points make sweep points
    warm — a fed fleet means ``sweep_points == 0``) and before the engine /
    train-step variants are built (winners must be installed before jit
    traces them).  ``cached`` only applies winners already in the store;
    ``sweep`` measures what's missing first.  Sweep samples land in the
    dispatcher's own store, so the driver's FleetPusher delta-pushes tuned
    winners with no extra plumbing.
    """
    rec: dict[str, Any] = {"mode": policy, "sweep_points": 0, "pruned": 0}
    if policy == "sweep":
        explorer = Explorer(
            dispatcher.store, chip=dispatcher.chip, log=log,
            settings=SweepSettings(mode=mode, warmup=warmup, repeats=repeats,
                                   workers=workers, prune_ratio=prune_ratio),
        )
        summary = explorer.sweep(ops_filter)
        rec["sweep_points"] = summary["sweep_points"]
        rec["pruned"] = summary["pruned"]
        rec["skipped_warm"] = summary["skipped_warm"]
        rec["winners"] = summary["winners"]
    table, _ = winners_from_store(dispatcher.store)
    rec["applied"] = apply_winners(table)
    rec["configs"] = {
        op: {impl: encode_config(params) for impl, params in impls.items()}
        for op, impls in table.items()
    }
    return rec
