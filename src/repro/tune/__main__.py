import sys

from repro.tune.cli import main

sys.exit(main())
