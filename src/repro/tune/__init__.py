"""repro.tune — fleet-wide kernel autotuning via design-space exploration.

The paper's loop, applied to *configuration* instead of just placement:
measure the design space (block/tile/chunk sizes per kernel backend), prune
it with the roofline model, time the survivors in parallel, and publish the
winners through the fleet so one machine's sweep warm-starts every later run
on matching hardware.

    space.py     candidate config grids per (op, backend), constraint-aware
    prune.py     roofline pruning (never cuts the shipped default)
    explore.py   the parallel sweep + winner application
    cli.py       ``python -m repro.tune {sweep,show,spaces}``

Everything here is importable without jax; real measurement imports it
lazily inside the sweep workers.
"""
from repro.tune.explore import (
    Explorer,
    SweepSettings,
    apply_winners,
    driver_tune,
    winners_from_store,
)
from repro.tune.prune import DEFAULT_PRUNE_RATIO, RooflinePruner
from repro.tune.space import ConfigPoint, KernelSpace, default_spaces

__all__ = [
    "ConfigPoint",
    "DEFAULT_PRUNE_RATIO",
    "Explorer",
    "KernelSpace",
    "RooflinePruner",
    "SweepSettings",
    "apply_winners",
    "default_spaces",
    "driver_tune",
    "winners_from_store",
]
