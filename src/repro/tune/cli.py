"""Kernel autotuning CLI.

  PYTHONPATH=src python -m repro.tune sweep --mode synthetic --workers 4
  PYTHONPATH=src python -m repro.tune sweep --ops rwkv6_scan mamba_scan \\
      --fleet fleet_store --out tuned.json
  PYTHONPATH=src python -m repro.tune show --profile-in tuned.json
  PYTHONPATH=src python -m repro.tune spaces

``sweep`` enumerates + prunes + times the design spaces and records every
point into a ProfileStore; ``--fleet`` pulls matching profiles first (warm
points are skipped — a second sweep against a fed fleet measures nothing)
and delta-pushes the new samples when done.  ``show`` prints the measured
config points of each space from a profile artifact or a fleet pull.
``spaces`` lists the candidate grids and what the roofline pruner would cut.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from repro.dispatch.profiles import ProfileStore
from repro.fleet.client import FleetClient, FleetError, FleetPusher
from repro.tune.explore import MODES, Explorer, SweepSettings, winners_from_store
from repro.tune.prune import DEFAULT_PRUNE_RATIO, RooflinePruner
from repro.tune.space import default_spaces


def _env_key() -> tuple[str, str]:
    from repro.hw.specs import default_chip
    from repro.trace.session import git_sha

    return git_sha(), default_chip().name


def _load_store(args: argparse.Namespace) -> ProfileStore:
    store = ProfileStore(min_samples=2)
    if getattr(args, "profile_in", None):
        from repro.trace.session import load_profile_store

        store.merge(load_profile_store(args.profile_in))
    return store


def _fleet_pull(store: ProfileStore, target: str,
                token: Optional[str]) -> tuple[Optional[FleetPusher], dict]:
    """Pull + merge matching fleet profiles, return a delta pusher.

    Mirrors the drivers' warm-start: stale-stamped entries are aged out
    *before* the merge, and the pusher baseline is taken after it, so a
    sweep only ever pushes its own new samples.
    """
    from repro.trace.session import age_out_profiles

    sha, chip = _env_key()
    client = FleetClient(target, token=token)
    rec: dict = {"target": target}
    try:
        pulled = client.pull(sha, chip)
        rec["match"] = pulled["match"]
        if pulled["store"] is not None:
            pulled["store"].age_out(git_sha=sha, chip=chip)
            rec["merged_samples"] = store.merge(pulled["store"])
            age_out_profiles(store, chip)
    except FleetError as exc:
        rec["match"] = "error"
        rec["error"] = str(exc)
        print(f"fleet: pull failed, sweeping cold: {exc}", file=sys.stderr)
    return FleetPusher(client, store, sha, chip), rec


def cmd_sweep(args: argparse.Namespace) -> int:
    store = _load_store(args)
    pusher, fleet_rec = (None, None)
    if args.fleet:
        pusher, fleet_rec = _fleet_pull(store, args.fleet, args.token)
    settings = SweepSettings(
        mode=args.mode, warmup=args.warmup, repeats=args.repeats,
        workers=args.workers, prune_ratio=args.prune_ratio,
    )
    explorer = Explorer(store, settings=settings)
    summary = explorer.sweep(args.ops or None)
    if fleet_rec is not None:
        summary["fleet"] = fleet_rec
    if pusher is not None:
        push = pusher.push()
        summary["fleet"]["push"] = {
            "pushed": push.get("pushed", False),
            "samples": pusher.pushed_samples,
        }
        if "error" in push:
            print(f"fleet: push failed (samples ride a retry): {push['error']}",
                  file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            f.write(store.to_json())
        print(f"wrote {args.out} ({len(store)} entries)", file=sys.stderr)
    if args.json:
        print(json.dumps(summary, indent=1))
        return 0
    print(f"sweep[{summary['mode']}]: {summary['spaces']} spaces, "
          f"{summary['points_total']} points "
          f"({summary['pruned']} pruned, {summary['skipped_warm']} warm, "
          f"{summary['sweep_points']} measured)")
    for key, win in sorted(summary["winners"].items()):
        speed = (f"  {win['speedup']:.2f}x vs default"
                 if "speedup" in win else "")
        print(f"  {key:<28} best={win['config'] or '<default>'} "
              f"min={win['best_s']:.3e}s{speed}")
    return 0


def cmd_show(args: argparse.Namespace) -> int:
    store = _load_store(args)
    if args.fleet:
        sha, chip = _env_key()
        try:
            pulled = FleetClient(args.fleet, token=args.token).pull(sha, chip)
        except FleetError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 1
        if pulled["store"] is not None:
            store.merge(pulled["store"])
    spaces = default_spaces()
    _, details = winners_from_store(store, spaces)
    out: dict = {}
    for key, space in sorted(spaces.items()):
        points = store.config_points(space.op, space.backend, space.sig)
        if not points:
            continue
        best = details.get(key, {}).get("config")
        out[key] = {
            "points": {
                cfg or "<default>": {"count": e.count, "min_s": e.min_s}
                for cfg, e in sorted(points.items())
            },
            "best": best if best is not None else "<none warm>",
            "default": space.default_config,
        }
    if args.json:
        print(json.dumps(out, indent=1))
        return 0
    if not out:
        print("(no measured config points)")
        return 0
    for key, rec in out.items():
        print(f"{key}  (default {rec['default']})")
        for cfg, row in rec["points"].items():
            mark = " *" if cfg == (rec["best"] or "<default>") else ""
            print(f"  {cfg:<40} n={row['count']:<4} min={row['min_s']:.3e}s{mark}")
    return 0


def cmd_spaces(args: argparse.Namespace) -> int:
    pruner = RooflinePruner(ratio=args.prune_ratio)
    rows = []
    for key, space in sorted(default_spaces().items()):
        points = space.points()
        kept, cut = pruner.prune(space, points)
        rows.append({
            "space": key, "grid": {k: list(v) for k, v in space.grid.items()},
            "default": space.default_config, "feasible": len(points),
            "pruned": len(cut), "sweep": len(kept),
        })
    if args.json:
        print(json.dumps({"spaces": rows}, indent=1))
        return 0
    print(f"{'space':<28}{'feasible':>9}{'pruned':>8}{'sweep':>7}  default")
    for r in rows:
        print(f"{r['space']:<28}{r['feasible']:>9}{r['pruned']:>8}"
              f"{r['sweep']:>7}  {r['default']}")
    return 0


def _add_common(p: argparse.ArgumentParser) -> None:
    p.add_argument("--fleet", default=None, metavar="URL|DIR",
                   help="fleet daemon URL or store directory")
    p.add_argument("--token", default=None, metavar="TOKEN",
                   help="bearer token for a --token-protected daemon")
    p.add_argument("--profile-in", default=None, metavar="PATH",
                   help="seed the store from a profile/session artifact")
    p.add_argument("--json", action="store_true")


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("sweep", help="enumerate, prune, and time the design spaces")
    _add_common(p)
    p.add_argument("--ops", nargs="*", default=None, metavar="OP",
                   help="restrict to these kernel ops (default: all spaces)")
    p.add_argument("--mode", default="interpret", choices=MODES)
    p.add_argument("--workers", type=int, default=0,
                   help="multiprocessing pool size (0 = in-process)")
    p.add_argument("--warmup", type=int, default=1)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--prune-ratio", type=float, default=DEFAULT_PRUNE_RATIO,
                   help="drop points predicted worse than RATIO x the bound")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="write the swept ProfileStore JSON here")
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("show", help="print measured config points per space")
    _add_common(p)
    p.set_defaults(fn=cmd_show)

    p = sub.add_parser("spaces", help="list design spaces and prune counts")
    p.add_argument("--prune-ratio", type=float, default=DEFAULT_PRUNE_RATIO)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_spaces)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (ValueError, FileNotFoundError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
