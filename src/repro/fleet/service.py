"""Fleet profile daemon: a stdlib ``http.server`` front end over FleetStore.

No third-party dependencies — a ``ThreadingHTTPServer`` speaking a small
JSON protocol (one route per :class:`~repro.fleet.store.FleetStore` verb):

    GET  /healthz                          liveness + bucket count + stats
    GET  /metrics                          Prometheus text (same counters)
    GET  /v1/ls                            bucket metadata listing
    GET  /v1/pull?git_sha=S&chip=C         best match (exact → chip → miss)
    POST /v1/push   {git_sha, chip, store} Welford-merge a snapshot in
    POST /v1/gc     {max_age_s, keep_per_chip}

Run it with ``python -m repro.fleet serve --root DIR``; talk to it with
:class:`~repro.fleet.client.FleetClient` (which also speaks directly to a
store directory for single-host use — same verbs, no daemon).

``--token T`` turns on write authentication: push and gc (the mutating
verbs) then require ``Authorization: Bearer T``; pull/ls/healthz stay open
— a shared fleet wants everyone warm-starting but only trusted runs feeding
the Welford state.  Rejections are 401s, counted in the daemon's stats
(``auth_failures`` in ``/healthz``).

``--quota-rps R`` adds per-source rate quotas on the same mutating verbs: a
token bucket per client address (refill R req/s, capacity ``--quota-burst``)
so one chatty replica can't starve the rest of the fleet's writers.  Over-
quota requests get 429, counted as ``throttled``; each throttle *episode*
(the transition into denial, not every denied request) lands in
``AUDIT.jsonl``.

``/healthz`` and ``/metrics`` read the **same**
:class:`~repro.metrics.registry.MetricsRegistry` counters — there is one
counter source, so the two surfaces can never drift apart.

Every successful mutating verb is also appended to ``AUDIT.jsonl`` in the
store root — who (source address + a token digest, never the token itself)
changed what (git_sha/chip/sample counts for push, removal count for gc)
and when.  ``python -m repro.fleet audit --root DIR`` tails it.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import sys
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Optional

from repro.dispatch.profiles import ProfileStore
from repro.fleet.store import FleetStore
from repro.metrics.http import PROM_CONTENT_TYPE
from repro.metrics.registry import MetricsRegistry

MAX_PUSH_BYTES = 64 << 20  # a merged ProfileStore is KBs; 64 MiB is generous

AUDIT_NAME = "AUDIT.jsonl"  # one JSON record per successful push/gc


def read_audit(root: str, n: Optional[int] = None) -> list[dict[str, Any]]:
    """The last ``n`` audit records of a fleet store (all when ``n`` is
    None); missing file means no mutations yet, not an error.  Torn final
    lines (daemon killed mid-append) are skipped."""
    path = os.path.join(root, AUDIT_NAME)
    if not os.path.exists(path):
        return []
    out: list[dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict):
                out.append(rec)
    return out[-n:] if n is not None else out

# Daemon verb counters; /healthz reports them under these short keys, the
# Prometheus surface as repro_fleet_<key>_total — same Counter objects.
STAT_KEYS = ("pushes", "pulls", "gcs", "auth_failures", "throttled")


class RateQuota:
    """Per-source token bucket over the mutating verbs (push/gc).

    One bucket per client address: refill ``rps`` tokens/s up to ``burst``
    capacity, one token per request.  ``allow`` returns ``(allowed,
    episode_start)`` — the second flag is True only on the transition into
    denial, so callers can audit one record per throttle episode instead of
    one per denied request (a runaway client would otherwise flood the very
    audit log the quota protects).

    ``clock`` is injectable (tests pass a fake monotonic clock).  The bucket
    table is LRU-bounded: address churn (NAT pools, short-lived replicas) can't
    grow it without bound, and an evicted source simply restarts with a full
    bucket — the quota fails open, never spuriously throttles.
    """

    def __init__(self, rps: float, burst: Optional[float] = None, *,
                 clock: Any = time.monotonic, max_sources: int = 1024) -> None:
        if rps <= 0:
            raise ValueError(f"quota rps must be positive, got {rps}")
        self.rps = float(rps)
        self.burst = float(burst) if burst is not None else max(1.0, self.rps)
        if self.burst < 1.0:
            raise ValueError(f"quota burst must be >= 1, got {self.burst}")
        self.clock = clock
        self.max_sources = max_sources
        self._lock = threading.Lock()
        # source -> (tokens, t_last); insertion order is recency (pop+reinsert)
        self._buckets: dict[str, tuple[float, float]] = {}
        self._throttled: set[str] = set()

    def allow(self, source: str) -> tuple[bool, bool]:
        now = self.clock()
        with self._lock:
            tokens, last = self._buckets.pop(source, (self.burst, now))
            tokens = min(self.burst, tokens + (now - last) * self.rps)
            allowed = tokens >= 1.0
            if allowed:
                tokens -= 1.0
            self._buckets[source] = (tokens, now)
            while len(self._buckets) > self.max_sources:
                evicted = next(iter(self._buckets))
                del self._buckets[evicted]
                self._throttled.discard(evicted)
            if allowed:
                self._throttled.discard(source)
                return True, False
            episode_start = source not in self._throttled
            self._throttled.add(source)
            return False, episode_start


class FleetServer(ThreadingHTTPServer):
    """HTTP server owning one FleetStore (threaded: pushes serialize on the
    store's lock, reads are cheap).  ``token`` guards the mutating verbs."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr: tuple[str, int], fleet: FleetStore,
                 quiet: bool = True, token: Optional[str] = None,
                 quota: Optional[RateQuota] = None) -> None:
        self.fleet = fleet
        self.quiet = quiet
        self.token = token
        self.quota = quota
        self.audit_path = os.path.join(fleet.root, AUDIT_NAME)
        self._audit_lock = threading.Lock()
        # single counter source for /healthz AND /metrics: a parallel dict
        # would inevitably drift from the scraped series
        self.metrics = MetricsRegistry()
        for key in STAT_KEYS:
            self.metrics.counter(f"repro_fleet_{key}_total",
                                 f"fleet daemon {key.replace('_', ' ')}")
        super().__init__(addr, _Handler)

    def count(self, key: str) -> None:
        self.metrics.counter(f"repro_fleet_{key}_total").inc()

    def audit(self, verb: str, addr: str, **fields: Any) -> None:
        """Append one audit record for a successful mutating verb.

        The token is recorded as a short sha256 digest — enough to tell two
        writers apart without persisting the secret itself.  Append + flush
        per record: a killed daemon loses at most its torn final line
        (which ``read_audit`` skips).
        """
        rec: dict[str, Any] = {"t": round(time.time(), 3), "verb": verb,
                               "addr": addr}
        if self.token is not None:
            rec["token_sha"] = hashlib.sha256(
                self.token.encode()).hexdigest()[:12]
        rec.update({k: v for k, v in fields.items() if v is not None})
        line = json.dumps(rec, sort_keys=True) + "\n"
        with self._audit_lock, open(self.audit_path, "a") as f:
            f.write(line)
            f.flush()

    def stats_snapshot(self) -> dict[str, int]:
        return {key: int(self.metrics.counter(f"repro_fleet_{key}_total").value)
                for key in STAT_KEYS}

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        if host in ("0.0.0.0", "::"):  # wildcard binds aren't connectable —
            # give scripts/--ready-file consumers a reachable name
            import socket

            host = socket.getfqdn() or socket.gethostname()
        return f"http://{host}:{port}"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-fleet/1"
    server: FleetServer  # narrowed for the route handlers

    # -- plumbing -------------------------------------------------------------

    def log_message(self, fmt: str, *args: Any) -> None:
        if not self.server.quiet:
            sys.stderr.write("fleet: " + (fmt % args) + "\n")

    def _send(self, code: int, doc: dict[str, Any]) -> None:
        body = json.dumps(doc, indent=1).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, code: int, body: str, ctype: str) -> None:
        raw = body.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(raw)))
        self.end_headers()
        self.wfile.write(raw)

    def _error(self, code: int, message: str) -> None:
        self._send(code, {"error": message})

    def _body(self) -> Optional[dict[str, Any]]:
        try:
            n = int(self.headers.get("Content-Length", "0"))
        except ValueError:
            n = 0
        if n <= 0 or n > MAX_PUSH_BYTES:
            self._error(400, f"body required (≤ {MAX_PUSH_BYTES} bytes)")
            return None
        try:
            doc = json.loads(self.rfile.read(n))
        except json.JSONDecodeError as exc:
            self._error(400, f"invalid JSON body: {exc}")
            return None
        if not isinstance(doc, dict):
            self._error(400, "body must be a JSON object")
            return None
        return doc

    def _authorized(self) -> bool:
        """Bearer check for the mutating verbs (push/gc).  Open when the
        daemon runs without --token; 401s are counted in the daemon stats."""
        token = self.server.token
        if token is None:
            return True
        header = self.headers.get("Authorization", "")
        # compare bytes: compare_digest raises TypeError on non-ASCII str,
        # and HTTP headers arrive latin-1 decoded
        if hmac.compare_digest(header.encode("latin-1", "replace"),
                               f"Bearer {token}".encode("latin-1", "replace")):
            return True
        self.server.count("auth_failures")
        self._error(401, "push/gc require 'Authorization: Bearer <token>' "
                         "(daemon started with --token)")
        return False

    def _within_quota(self, path: str) -> bool:
        """Per-source token bucket on the mutating verbs (after auth, so
        unauthenticated floods are 401s, not quota spend).  Denials are 429,
        counted; each throttle episode gets exactly one audit record."""
        quota = self.server.quota
        if quota is None:
            return True
        source = self.client_address[0]
        allowed, episode_start = quota.allow(source)
        if allowed:
            return True
        self.server.count("throttled")
        if episode_start:
            self.server.audit("throttle", source, path=path,
                              rps=quota.rps, burst=quota.burst)
        self._error(429, f"per-source rate quota exceeded "
                         f"({quota.rps:g} req/s, burst {quota.burst:g})")
        return False

    # -- routes ---------------------------------------------------------------

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        url = urllib.parse.urlsplit(self.path)
        q = urllib.parse.parse_qs(url.query)
        try:
            if url.path == "/healthz":
                self._send(200, {"ok": True, "schema": "repro.fleet/v1",
                                 "snapshots": len(self.server.fleet),
                                 "auth": self.server.token is not None,
                                 "stats": self.server.stats_snapshot()})
            elif url.path == "/metrics":
                # same registry /healthz reads — one counter source, no drift
                self.server.metrics.gauge(
                    "repro_fleet_snapshots",
                    "profile snapshots held by the store").set(len(self.server.fleet))
                self._send_text(200, self.server.metrics.render(),
                                PROM_CONTENT_TYPE)
            elif url.path == "/v1/ls":
                self._send(200, {"snapshots": self.server.fleet.ls()})
            elif url.path == "/v1/pull":
                git_sha = (q.get("git_sha") or [""])[0]
                chip = (q.get("chip") or [""])[0]
                if not git_sha or not chip:
                    self._error(400, "pull requires git_sha= and chip= params")
                    return
                self.server.count("pulls")
                self._send(200, self.server.fleet.pull(git_sha, chip))
            else:
                self._error(404, f"unknown path {url.path}")
        except Exception as exc:  # surface the failure to the client, not a 500 page
            self._error(500, f"{type(exc).__name__}: {exc}")

    def do_POST(self) -> None:  # noqa: N802
        url = urllib.parse.urlsplit(self.path)
        if url.path in ("/v1/push", "/v1/gc"):
            if not self._authorized():
                return
            if not self._within_quota(url.path):
                return
        body = self._body()
        if body is None:
            return
        try:
            if url.path == "/v1/push":
                git_sha = body.get("git_sha", "")
                chip = body.get("chip", "")
                raw = body.get("store")
                if not isinstance(raw, dict) or "entries" not in raw:
                    self._error(400, "push body needs a 'store' ProfileStore object")
                    return
                store = ProfileStore.from_json(json.dumps(raw))
                self.server.count("pushes")
                res = self.server.fleet.push(
                    store, git_sha, chip,
                    source=body.get("source"), seq=body.get("seq"))
                self.server.audit(
                    "push", self.client_address[0],
                    git_sha=git_sha, chip=chip, source=body.get("source"),
                    entries=len(store),
                    merged_samples=res.get("merged_samples")
                    if isinstance(res, dict) else None)
                self._send(200, res)
            elif url.path == "/v1/gc":
                self.server.count("gcs")
                removed = self.server.fleet.gc(
                    max_age_s=body.get("max_age_s"),
                    keep_per_chip=body.get("keep_per_chip"),
                )
                self.server.audit(
                    "gc", self.client_address[0],
                    max_age_s=body.get("max_age_s"),
                    keep_per_chip=body.get("keep_per_chip"), removed=removed)
                self._send(200, {"removed": removed})
            else:
                self._error(404, f"unknown path {url.path}")
        except (ValueError, KeyError, TypeError) as exc:
            self._error(400, f"{type(exc).__name__}: {exc}")
        except Exception as exc:
            self._error(500, f"{type(exc).__name__}: {exc}")


def make_server(root: str, host: str = "127.0.0.1", port: int = 8377,
                quiet: bool = True, token: Optional[str] = None,
                quota_rps: Optional[float] = None,
                quota_burst: Optional[float] = None) -> FleetServer:
    """Bind a fleet daemon (``port=0`` picks a free port; see ``.url``).

    ``token`` requires ``Authorization: Bearer <token>`` on push/gc.
    ``quota_rps`` rate-limits push/gc per source address (token bucket of
    ``quota_burst`` capacity, default max(1, rps)); over-quota gets 429.
    """
    import os

    os.makedirs(root, exist_ok=True)  # the daemon's root is explicit intent
    quota = RateQuota(quota_rps, quota_burst) if quota_rps is not None else None
    return FleetServer((host, port), FleetStore(root), quiet=quiet, token=token,
                       quota=quota)
