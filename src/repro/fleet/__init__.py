"""repro.fleet — central cross-run profile aggregation with auto warm-start.

Closes the analyze→aggregate→dispatch loop *across processes*: every run's
measured :class:`~repro.dispatch.profiles.ProfileStore` is Welford-merged
into a central store keyed by (git SHA, chip), and any later run on matching
code + hardware warm-starts from the freshest fleet profile instead of
re-exploring (the Adaptyst cross-run aggregation the ROADMAP called for).

* :mod:`repro.fleet.store` — :class:`FleetStore`, the on-disk bucket store
  (Welford merge on push, exact → chip-only → miss pull fallback,
  staleness/retention gc, ``"mixed"`` provenance never shadows a real match);
* :mod:`repro.fleet.service` — stdlib ``http.server`` daemon over one store;
* :mod:`repro.fleet.client` — :class:`FleetClient` (HTTP or direct-path
  transport) and :class:`FleetPusher` (delta pushes that never double-count);
* :mod:`repro.fleet.cli` — ``python -m repro.fleet {serve,push,pull,ls,gc}``.

Drivers wire it end-to-end via ``--fleet <url|dir>`` on ``launch.serve`` /
``launch.train``: pull + age-out at startup, per-rotation pushes while
streaming (``--trace-dir``), and a final delta push at shutdown.
"""
from repro.fleet.client import (
    FleetClient,
    FleetError,
    FleetPusher,
    warm_start_from_fleet,
)
from repro.fleet.service import FleetServer, make_server
from repro.fleet.store import FLEET_SCHEMA, FleetStore, declared_stamp

__all__ = [
    "FLEET_SCHEMA",
    "FleetClient",
    "FleetError",
    "FleetPusher",
    "FleetServer",
    "FleetStore",
    "declared_stamp",
    "make_server",
    "warm_start_from_fleet",
]
