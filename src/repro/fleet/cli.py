"""Fleet profile service CLI.

  PYTHONPATH=src python -m repro.fleet serve --root fleet_store [--port 8377]
  PYTHONPATH=src python -m repro.fleet push  profiles.json --fleet http://host:8377
  PYTHONPATH=src python -m repro.fleet pull  --fleet fleet_store -o warm.json
  PYTHONPATH=src python -m repro.fleet ls    --fleet http://host:8377
  PYTHONPATH=src python -m repro.fleet gc    --fleet fleet_store --max-age-s 604800
  PYTHONPATH=src python -m repro.fleet audit --root fleet_store [-n 20] [--json]

``--fleet`` accepts a daemon URL (``http://host:port``) or a store directory
path / ``file://`` URL (single-host direct mode — same on-disk format, no
daemon).  ``push`` takes a bare ProfileStore JSON (``--profile-out``), a
trace session file (``--trace-out``), or a streaming segment directory
(``--trace-dir``); the (git SHA, chip) bucket key defaults to the source's
own provenance and can be overridden with ``--git-sha`` / ``--chip``.

``audit`` tails the daemon's mutation log (``AUDIT.jsonl`` in the store
root): one record per successful push/gc with the source address, a token
digest when the daemon ran with ``--token``, and what changed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Optional

from repro.dispatch.profiles import ProfileStore
from repro.fleet.client import FleetClient, FleetError
from repro.fleet.service import make_server
from repro.fleet.store import declared_stamp

EXIT_MISS = 4  # pull found nothing (distinct from argparse=2, errors=1)


def _default_key(git_sha: Optional[str], chip: Optional[str]) -> tuple[str, str]:
    """Fill missing key halves from the current environment."""
    if not git_sha:
        from repro.trace.session import git_sha as current_sha

        git_sha = current_sha()
    if not chip:
        from repro.hw.specs import default_chip

        chip = default_chip().name
    return git_sha, chip


def load_store_and_provenance(path: str) -> tuple[ProfileStore, dict[str, Any]]:
    """A ProfileStore + its provenance record from any profile artifact.

    Accepts a streaming segment directory, a trace session JSON, or a bare
    ProfileStore JSON (validation shared with ``--profile-in`` via
    :func:`repro.trace.session.load_profile_store`).  The returned dict has
    ``git_sha``/``chip`` (from session/manifest metadata when present, else
    from unanimous entry stamps, else '') and ``fleet`` — the ``--fleet``
    target the run itself fed live, if any (double-count guard).
    """
    if os.path.isdir(path):
        from repro.trace.stream import load_stream

        sess = load_stream(path)
        if sess.store is None or len(sess.store) == 0:
            raise ValueError(f"{path} carries no profile snapshot "
                             "(was the run dispatch-enabled?)")
        return sess.store, {
            "git_sha": sess.meta.get("git_sha", ""),
            "chip": (sess.chip or {}).get("name", ""),
            "fleet": sess.meta.get("fleet"),
        }
    from repro.trace.session import is_session, load_profile_store

    store = load_profile_store(path)  # one place owns format validation
    with open(path) as f:
        raw = json.load(f)
    if is_session(raw):
        return store, {
            "git_sha": raw.get("meta", {}).get("git_sha", ""),
            "chip": (raw.get("dispatch", {}).get("chip") or {}).get("name", ""),
            "fleet": raw.get("meta", {}).get("fleet"),
        }
    sha, chip = declared_stamp(store)
    # bare --profile-out stores written by a --fleet run carry a top-level
    # "fleet" marker (drivers add it) — surface it for the double-count guard
    return store, {"git_sha": sha, "chip": chip, "fleet": raw.get("fleet")}


PUSH_RESULT_KEYS = ("git_sha", "chip", "merged_samples", "samples",
                    "entries", "pushes")


def push_source(source: str, fleet: str, git_sha: Optional[str] = None,
                chip: Optional[str] = None, force: bool = False,
                token: Optional[str] = None) -> dict[str, Any]:
    """Load any profile artifact and push it into a fleet target (shared by
    ``repro.fleet push`` and ``repro.trace push-profiles``).

    Two safety rails, both overridable:

    * a run recorded with ``--fleet`` already fed the fleet live (delta
      pushes) — re-pushing its cumulative snapshot would double-count every
      sample in the bucket's Welford state, so it is refused without
      ``force``;
    * the bucket key must come from the artifact's own provenance or
      explicit flags — silently keying foreign/unstamped samples to *this*
      environment would turn them into a trusted exact-match warm start.
    """
    store, prov = load_store_and_provenance(source)
    fed = prov.get("fleet")
    if fed and fed == fleet and not force:
        # only the fleet the run actually fed live can double-count
        raise ValueError(
            f"{source} was recorded with --fleet {fed} and already fed it "
            "live (delta pushes); re-pushing the cumulative snapshot would "
            "double-count every sample — pass --force to override"
        )
    if fed and fed != fleet:
        import sys

        print(f"warning: {source} already fed {fed} live; pushing its "
              f"cumulative snapshot to {fleet} — make sure the two targets "
              "are not backed by the same store", file=sys.stderr)
    sha = git_sha or prov["git_sha"]
    ch = chip or prov["chip"]
    if not sha or not ch:
        raise ValueError(
            f"{source} carries no unambiguous (git SHA, chip) provenance "
            f"(got {(sha, ch)!r}); pass --git-sha/--chip explicitly — "
            "defaulting to the current environment would disguise foreign "
            "samples as a trusted exact match"
        )
    return FleetClient(fleet, token=token).push(store, sha, ch)


# -- commands -----------------------------------------------------------------


def cmd_serve(args: argparse.Namespace) -> int:
    server = make_server(args.root, host=args.host, port=args.port,
                         quiet=not args.verbose, token=args.token,
                         quota_rps=args.quota_rps, quota_burst=args.quota_burst)
    print(json.dumps({"fleet": server.url, "root": os.path.abspath(args.root),
                      "pid": os.getpid(), "auth": args.token is not None,
                      "quota_rps": args.quota_rps}),
          flush=True)
    if args.ready_file:
        from repro.utils.ready import write_ready_file

        write_ready_file(args.ready_file, server.url)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
    return 0


def cmd_push(args: argparse.Namespace) -> int:
    res = push_source(args.source, args.fleet, args.git_sha, args.chip,
                      force=args.force, token=args.token)
    print(json.dumps(res if args.json else
                     {k: res.get(k) for k in PUSH_RESULT_KEYS}))
    return 0


def cmd_pull(args: argparse.Namespace) -> int:
    git_sha, chip = _default_key(args.git_sha, args.chip)
    res = FleetClient(args.fleet, token=args.token).pull(git_sha, chip)
    store = res.pop("store")
    if args.json:
        print(json.dumps(res))
    else:
        print(f"pull ({git_sha}, {chip}): match={res['match']}"
              + (f"  bucket=({res.get('git_sha')}, {res.get('chip')})  "
                 f"entries={res.get('entries')}  samples={res.get('samples')}"
                 if res["match"] != "miss" else ""))
    if res["match"] == "miss":
        return EXIT_MISS
    if args.out:
        with open(args.out, "w") as f:
            f.write(store.to_json())
        print(f"wrote {args.out} ({len(store)} entries)", file=sys.stderr)
    return 0


def cmd_ls(args: argparse.Namespace) -> int:
    rows = FleetClient(args.fleet, token=args.token).ls()
    if args.json:
        print(json.dumps({"snapshots": rows}, indent=1))
        return 0
    if not rows:
        print("(empty fleet store)")
        return 0
    print(f"{'chip':<16}{'git_sha':<12}{'entries':>8}{'samples':>9}"
          f"{'pushes':>8}  pushed_unix")
    for r in rows:
        print(f"{str(r.get('chip')):<16}{str(r.get('git_sha')):<12}"
              f"{r.get('entries') or 0:>8}{r.get('samples') or 0:>9}"
              f"{r.get('pushes') or 0:>8}  {r.get('pushed_unix')}")
    return 0


def cmd_gc(args: argparse.Namespace) -> int:
    removed = FleetClient(args.fleet, token=args.token).gc(
        max_age_s=args.max_age_s, keep_per_chip=args.keep_per_chip)
    if args.json:
        print(json.dumps({"removed": removed}, indent=1))
    else:
        for r in removed:
            print(f"removed ({r.get('git_sha')}, {r.get('chip')}): {r.get('reason')}")
        print(f"gc: removed {len(removed)} bucket(s)")
    return 0


def cmd_audit(args: argparse.Namespace) -> int:
    from repro.fleet.service import read_audit

    recs = read_audit(args.root, n=args.n if args.n > 0 else None)
    if args.json:
        print(json.dumps({"records": recs}, indent=1))
        return 0
    if not recs:
        print("(no audit records)")
        return 0
    print(f"{'t':>14}  {'verb':<5}{'addr':<16}{'token_sha':<13}detail")
    for r in recs:
        if r.get("verb") == "push":
            detail = (f"({r.get('git_sha')}, {r.get('chip')}) "
                      f"entries={r.get('entries')} "
                      f"merged_samples={r.get('merged_samples')}"
                      + (f" source={r['source']}" if r.get("source") else ""))
        elif r.get("verb") == "gc":
            removed = r.get("removed")
            detail = (f"removed={len(removed) if isinstance(removed, list) else removed}"
                      + (f" max_age_s={r['max_age_s']}"
                         if r.get("max_age_s") is not None else "")
                      + (f" keep_per_chip={r['keep_per_chip']}"
                         if r.get("keep_per_chip") is not None else ""))
        else:
            detail = json.dumps({k: v for k, v in r.items()
                                 if k not in ("t", "verb", "addr", "token_sha")})
        print(f"{r.get('t', 0):>14.3f}  {str(r.get('verb')):<5}"
              f"{str(r.get('addr')):<16}{str(r.get('token_sha', '-')):<13}{detail}")
    return 0


def _add_fleet_arg(p: argparse.ArgumentParser) -> None:
    p.add_argument("--fleet", required=True, metavar="URL|DIR",
                   help="daemon URL (http://host:port) or store directory")
    p.add_argument("--token", default=None, metavar="TOKEN",
                   help="bearer token for a --token-protected daemon")


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.fleet", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("serve", help="run the fleet profile daemon")
    p.add_argument("--root", required=True, help="store directory")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8377,
                   help="0 picks a free port (printed in the startup JSON)")
    p.add_argument("--ready-file", default=None, metavar="PATH",
                   help="write the bound URL here once listening (for scripts/CI)")
    p.add_argument("--token", default=None, metavar="TOKEN",
                   help="require 'Authorization: Bearer TOKEN' on push/gc "
                        "(pull/ls stay open); 401s are counted in /healthz stats")
    p.add_argument("--quota-rps", type=float, default=None, metavar="R",
                   help="per-source rate quota on push/gc (token bucket, R "
                        "req/s per client address); over-quota gets 429, "
                        "counted as 'throttled', audited per episode")
    p.add_argument("--quota-burst", type=float, default=None, metavar="B",
                   help="quota bucket capacity (default max(1, R))")
    p.add_argument("--verbose", action="store_true", help="log each request to stderr")
    p.set_defaults(fn=cmd_serve)

    p = sub.add_parser("push", help="merge a profile artifact into the fleet")
    p.add_argument("source", help="ProfileStore JSON, session JSON, or segment dir")
    _add_fleet_arg(p)
    p.add_argument("--git-sha", default=None, help="bucket key override")
    p.add_argument("--chip", default=None, help="bucket key override")
    p.add_argument("--force", action="store_true",
                   help="push even if the run already fed this fleet live "
                        "(accepts the double count)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_push)

    p = sub.add_parser("pull", help="fetch the best matching profile snapshot")
    _add_fleet_arg(p)
    p.add_argument("--git-sha", default=None, help="default: current repo SHA")
    p.add_argument("--chip", default=None, help="default: this host's chip")
    p.add_argument("-o", "--out", default=None, help="write the pulled store JSON here")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_pull)

    p = sub.add_parser("ls", help="list fleet buckets")
    _add_fleet_arg(p)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_ls)

    p = sub.add_parser("gc", help="apply the staleness/retention policy")
    _add_fleet_arg(p)
    p.add_argument("--max-age-s", type=float, default=None,
                   help="drop buckets last pushed longer ago than this")
    p.add_argument("--keep-per-chip", type=int, default=None,
                   help="keep only the newest N buckets per chip")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_gc)

    p = sub.add_parser("audit", help="tail the store's push/gc audit log")
    p.add_argument("--root", required=True, help="store directory")
    p.add_argument("-n", type=int, default=20, metavar="N",
                   help="show the last N records (0 = all)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_audit)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (FleetError, ValueError, FileNotFoundError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
