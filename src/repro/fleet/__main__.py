import sys

from repro.fleet.cli import main

if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:  # e.g. `... | head`: not an error
        sys.exit(0)
