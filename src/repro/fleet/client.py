"""FleetClient + FleetPusher: the run-side half of the fleet profile service.

``FleetClient`` speaks the same verbs (push/pull/ls/gc) to either transport:

* ``http://host:port`` — the :mod:`repro.fleet.service` daemon;
* ``file:///path`` or a plain directory path — direct
  :class:`~repro.fleet.store.FleetStore` access for single-host fleets
  (no daemon, same on-disk format, advisory-locked).

``FleetPusher`` is the incremental feeder a long-lived run attaches to its
:class:`~repro.trace.stream.StreamingSession`: every rotation it pushes only
the samples recorded *since its last push* (``ProfileStore.delta_since``), so
repeated pushes never double-count in the fleet's Welford merge.
"""
from __future__ import annotations

import json
import threading
import urllib.error
import urllib.parse
import urllib.request
from typing import Any, Optional

from repro.dispatch.profiles import ProfileStore
from repro.fleet.store import FleetStore


class FleetError(RuntimeError):
    """The fleet target is unreachable or rejected the request."""


def _parse_target(target: str) -> tuple[str, str]:
    """('http', url) for daemon targets; ('file', path) for direct mode."""
    if target.startswith(("http://", "https://")):
        return "http", target.rstrip("/")
    if target.startswith("file://"):
        return "file", urllib.request.url2pathname(
            urllib.parse.urlsplit(target).path)
    return "file", target


class FleetClient:
    """Push/pull/ls/gc against an HTTP daemon or a store directory.

    ``token`` is sent as ``Authorization: Bearer <token>`` on every HTTP
    request — daemons started with ``--token`` require it on push/gc.
    Direct (file) mode ignores it: whoever can open the store directory
    already has write access.
    """

    def __init__(self, target: str, timeout: float = 10.0,
                 token: Optional[str] = None) -> None:
        self.target = target
        self.timeout = timeout
        self.token = token
        self.mode, loc = _parse_target(target)
        self._url: Optional[str] = loc if self.mode == "http" else None
        self._store: Optional[FleetStore] = (
            FleetStore(loc) if self.mode == "file" else None
        )

    # -- transport ------------------------------------------------------------

    def _direct(self, fn: Any, *args: Any, **kwargs: Any) -> Any:
        """File-mode verb with I/O failures normalised to FleetError, so
        callers (FleetPusher, warm_start_from_fleet, the drivers) handle a
        full disk or permission error the same as an unreachable daemon —
        log/degrade, never crash the traced run."""
        try:
            return fn(*args, **kwargs)
        except OSError as exc:
            raise FleetError(
                f"fleet {self.target}: {type(exc).__name__}: {exc}") from exc

    def _request(self, method: str, path: str,
                 body: Optional[dict[str, Any]] = None) -> dict[str, Any]:
        data = json.dumps(body).encode() if body is not None else None
        headers = {"Content-Type": "application/json"} if data else {}
        if self.token is not None:
            headers["Authorization"] = f"Bearer {self.token}"
        req = urllib.request.Request(
            f"{self._url}{path}", data=data, method=method, headers=headers,
        )
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read())
        except urllib.error.HTTPError as exc:
            try:
                detail = json.loads(exc.read()).get("error", "")
            except Exception:
                detail = ""
            raise FleetError(
                f"fleet {self.target}{path}: HTTP {exc.code}"
                + (f" ({detail})" if detail else "")
            ) from exc
        except (urllib.error.URLError, TimeoutError, ConnectionError, OSError) as exc:
            raise FleetError(f"fleet {self.target} unreachable: {exc}") from exc

    # -- verbs ----------------------------------------------------------------

    def push(self, store: ProfileStore, git_sha: str, chip: str,
             source: Optional[str] = None, seq: Optional[int] = None) -> dict[str, Any]:
        """Merge a snapshot into the fleet.  ``(source, seq)`` lets retrying
        callers make the push idempotent (see :meth:`FleetStore.push`)."""
        if self.mode == "file":
            return self._direct(self._store.push, store, git_sha, chip,
                                source=source, seq=seq)
        body: dict[str, Any] = {
            "git_sha": git_sha, "chip": chip,
            "store": json.loads(store.to_json()),
        }
        if source is not None:
            body["source"] = source
            body["seq"] = seq
        return self._request("POST", "/v1/push", body)

    def pull(self, git_sha: str, chip: str) -> dict[str, Any]:
        """Best-match pull; ``result["store"]`` is a ProfileStore or None."""
        if self.mode == "file":
            out = dict(self._direct(self._store.pull, git_sha, chip))
        else:
            out = self._request(
                "GET",
                "/v1/pull?" + urllib.parse.urlencode(
                    {"git_sha": git_sha, "chip": chip}),
            )
        raw = out.get("store")
        out["store"] = ProfileStore.from_json(json.dumps(raw)) if raw else None
        return out

    def ls(self) -> list[dict[str, Any]]:
        if self.mode == "file":
            return self._direct(self._store.ls)
        return self._request("GET", "/v1/ls")["snapshots"]

    def gc(self, max_age_s: Optional[float] = None,
           keep_per_chip: Optional[int] = None) -> list[dict[str, Any]]:
        if self.mode == "file":
            return self._direct(self._store.gc, max_age_s=max_age_s,
                                keep_per_chip=keep_per_chip)
        return self._request("POST", "/v1/gc", {
            "max_age_s": max_age_s, "keep_per_chip": keep_per_chip,
        })["removed"]

    def health(self) -> dict[str, Any]:
        if self.mode == "file":
            return {"ok": True, "snapshots": self._direct(len, self._store)}
        return self._request("GET", "/healthz")


class FleetPusher:
    """Incremental (delta-based) pusher bound to one live ProfileStore.

    The baseline snapshot is taken at construction, so create the pusher
    *after* merging any pulled fleet profiles into the store — otherwise the
    first push would echo the fleet's own samples back at it.  ``push()`` is
    thread-safe (streaming rotations happen on whichever thread tripped the
    rotation budget) and best-effort by default: an unreachable fleet leaves
    the baseline untouched, so the missed samples ride the next push.

    Pushes are **exactly-once**: each carries a per-pusher source id and a
    sequence number, and an in-flight delta is retried verbatim (same seq)
    until the fleet acknowledges it — so a push that *landed* but whose
    response was lost (timeout) is deduped server-side instead of being
    Welford-merged twice.  Samples recorded while a delta is pending ride
    the next one.
    """

    def __init__(self, client: FleetClient, store: ProfileStore,
                 git_sha: str, chip: str) -> None:
        import uuid

        self.client = client
        self.store = store
        self.git_sha = git_sha
        self.chip = chip
        self.source = uuid.uuid4().hex  # identifies this run's push stream
        self._seq = 0
        self._lock = threading.Lock()
        self._baseline = ProfileStore.from_json(store.to_json())
        self._pending: Optional[tuple[ProfileStore, ProfileStore, int]] = None
        self.pushed_samples = 0

    def push(self, raise_on_error: bool = False) -> dict[str, Any]:
        with self._lock:
            if self._pending is None:
                snap = ProfileStore.from_json(self.store.to_json())
                delta = snap.delta_since(self._baseline)
                if len(delta) == 0:
                    return {"pushed": False, "samples": 0}
                n = sum(e.count for e in delta._entries.values())
                self._seq += 1
                self._pending = (delta, snap, n)
            delta, snap, n = self._pending
            try:
                res = self.client.push(delta, self.git_sha, self.chip,
                                       source=self.source, seq=self._seq)
            except FleetError as exc:
                # ambiguous: the delta may or may not have landed — keep it
                # pending and retry the SAME (delta, seq) so the fleet can
                # dedup instead of double-merging
                if raise_on_error:
                    raise
                return {"pushed": False, "samples": 0, "error": str(exc)}
            # acknowledged (merged now, or recognised as an earlier duplicate)
            self._baseline = snap
            self._pending = None
            self.pushed_samples += n
            return {"pushed": True, **res}


def warm_start_from_fleet(
    target: str, dispatcher: Any, token: Optional[str] = None
) -> tuple[dict[str, Any], FleetPusher]:
    """Driver-side fleet wiring (the ``--fleet`` flag on serve/train).

    Pulls the best matching snapshot (exact (git SHA, chip) → freshest
    same-chip → miss), Welford-merges it into the dispatcher's live store,
    ages out entries whose stamps mismatch this environment (a chip-only
    fallback across code versions degrades to cold re-exploration, never to
    trusting stale timings), and returns the driver-JSON record plus a
    :class:`FleetPusher` whose baseline excludes the pulled samples.  An
    unreachable fleet logs, starts cold, and still returns a pusher — pushes
    retry at each rotation.
    """
    import sys

    from repro.trace.session import age_out_profiles, git_sha

    sha, chip_name = git_sha(), dispatcher.chip.name
    client = FleetClient(target, token=token)
    rec: dict[str, Any] = {"target": target}
    try:
        pulled = client.pull(sha, chip_name)
        pull_rec: dict[str, Any] = {"match": pulled["match"]}
        if pulled["store"] is not None:
            pull_rec["bucket_git_sha"] = pulled.get("git_sha")
            pull_rec["bucket_chip"] = pulled.get("chip")
            pull_rec["entries"] = len(pulled["store"])
            # discard stale-stamped fleet entries BEFORE merging: merging
            # first would degrade overlapping locally-valid entries (e.g.
            # from --profile-in) to 'mixed' and the age-out would then
            # destroy the driver's own warm-start data
            aged = pulled["store"].age_out(git_sha=sha, chip=chip_name)
            for a in aged:
                print(f"fleet: aged out {a['key']}: {a['reason']}",
                      file=sys.stderr)
            pull_rec["merged_samples"] = dispatcher.store.merge(pulled["store"])
            # unstamped fleet entries colliding with stamped local ones still
            # degrade to 'mixed' in the merge; evict those conservatively too
            pull_rec["aged_out"] = len(aged) + len(
                age_out_profiles(dispatcher.store, chip_name))
        rec["pull"] = pull_rec
        print(f"fleet: pull ({sha}, {chip_name}) -> {pull_rec['match']}"
              + (f", {pull_rec.get('entries')} entries"
                 f" ({pull_rec.get('aged_out')} aged out)"
                 if pulled["store"] is not None else ""),
              file=sys.stderr)
    except FleetError as exc:
        rec["pull"] = {"match": "error", "error": str(exc)}
        print(f"fleet: pull failed, starting cold: {exc}", file=sys.stderr)
    pusher = FleetPusher(client, dispatcher.store, sha, chip_name)
    return rec, pusher
