"""FleetStore: on-disk cross-run profile aggregation keyed by (git SHA, chip).

Each run's :class:`~repro.dispatch.profiles.ProfileStore` dies with its
``--profile-out`` file; the fleet store is the durable rendezvous the ROADMAP
calls for — a directory of merged profile snapshots, one bucket per
(git SHA, chip), so any process on matching code + hardware can warm-start
from the freshest samples the whole fleet has measured.

Semantics:

* **push** Welford-merges the incoming store into the bucket (Chan et al.
  parallel variance — N runs pushing equals one run that saw every sample);
* **pull** falls back provenance-safely: exact (git SHA, chip) match first,
  then freshest same-chip bucket (whose entries a driver will age out and
  re-explore if their SHA stamps mismatch), then a miss.  Buckets keyed
  ``"mixed"`` — samples of unknown provenance — never shadow either level;
* **gc** applies the staleness/retention policy: drop buckets older than
  ``max_age_s``, keep only the newest ``keep_per_chip`` per chip.

On-disk layout (one JSON doc per bucket, written atomically)::

    <root>/<chip>/<git_sha>.json

Thread-safe within a process (the HTTP daemon wraps one instance), and
best-effort cross-process safe in ``file://`` direct mode via an advisory
``flock`` on ``<root>/.lock``.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import time
from contextlib import contextmanager
from typing import Any, Iterator, Optional

from repro.dispatch.profiles import ProfileStore
from repro.utils.io import atomic_write

FLEET_SCHEMA = "repro.fleet/v1"
MIXED_STAMP = "mixed"  # ProfileStore's unknown-provenance marker


def _slug(s: str) -> str:
    """Filesystem-safe bucket-file name; hash-suffixed when lossy."""
    safe = re.sub(r"[^A-Za-z0-9._-]", "_", s) or "_"
    if safe != s or len(safe) > 80:
        safe = f"{safe[:64]}-{hashlib.sha1(s.encode()).hexdigest()[:8]}"
    return safe


def declared_stamp(store: ProfileStore) -> tuple[str, str]:
    """The (git_sha, chip) a store's samples unanimously claim, else ''.

    Used to default a push's bucket key from a bare ``--profile-out`` file:
    if every non-empty entry agrees on a stamp, that stamp is trustworthy;
    any disagreement yields '' so the caller must choose explicitly.  A
    unanimous ``"mixed"`` stamp is unknown provenance, not agreement — it
    also yields '' (otherwise merged-across-environments stores would mint
    ``mixed/mixed`` buckets instead of being refused).
    """
    shas = {e.git_sha for e in store._entries.values() if e.count}
    chips = {e.chip for e in store._entries.values() if e.count}
    sha = shas.pop() if len(shas) == 1 else ""
    chip = chips.pop() if len(chips) == 1 else ""
    return ("" if sha == MIXED_STAMP else sha,
            "" if chip == MIXED_STAMP else chip)


class FleetStore:
    """Directory of Welford-merged ProfileStore buckets keyed (git SHA, chip)."""

    MAX_SOURCES = 128  # per-bucket push-dedup window (see push())

    def __init__(self, root: str) -> None:
        # the root is created lazily on first push: read verbs on a mistyped
        # path must report the miss/absence, not mint an empty store
        self.root = root
        self._lock = threading.Lock()

    def _require_root(self) -> None:
        if not os.path.isdir(self.root):
            raise ValueError(f"fleet store {self.root} does not exist "
                             "(created on first push / by `serve`)")

    # -- locking / io ---------------------------------------------------------

    @contextmanager
    def _locked(self) -> Iterator[None]:
        """Process lock + advisory cross-process flock (direct-path mode:
        two hosts sharing an NFS root should not lose a racing push)."""
        with self._lock:
            lock_fd = None
            try:
                try:
                    import fcntl

                    lock_fd = os.open(os.path.join(self.root, ".lock"),
                                      os.O_CREAT | os.O_RDWR)
                    fcntl.flock(lock_fd, fcntl.LOCK_EX)
                except (ImportError, OSError):
                    lock_fd = None  # non-posix / odd fs: in-process lock only
                yield
            finally:
                if lock_fd is not None:
                    import fcntl

                    fcntl.flock(lock_fd, fcntl.LOCK_UN)
                    os.close(lock_fd)

    def _bucket_path(self, git_sha: str, chip: str) -> str:
        return os.path.join(self.root, _slug(chip), f"{_slug(git_sha)}.json")

    def _read_bucket(self, path: str) -> Optional[dict[str, Any]]:
        try:
            with open(path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def _write_bucket(self, path: str, doc: dict[str, Any]) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        atomic_write(path, json.dumps(doc, indent=1))

    def _iter_buckets(self) -> Iterator[tuple[str, dict[str, Any]]]:
        if not os.path.isdir(self.root):
            return
        for chip_dir in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, chip_dir)
            if not os.path.isdir(d):
                continue
            for name in sorted(os.listdir(d)):
                if not name.endswith(".json"):
                    continue
                path = os.path.join(d, name)
                doc = self._read_bucket(path)
                if doc is not None:
                    yield path, doc

    @staticmethod
    def _meta(doc: dict[str, Any]) -> dict[str, Any]:
        return {k: doc.get(k) for k in
                ("git_sha", "chip", "created_unix", "pushed_unix",
                 "pushes", "samples", "entries")}

    # -- the service verbs ----------------------------------------------------

    def push(self, store: ProfileStore, git_sha: str, chip: str,
             source: Optional[str] = None, seq: Optional[int] = None) -> dict[str, Any]:
        """Welford-merge ``store`` into the (git_sha, chip) bucket.

        Entries with an *empty* git_sha/chip stamp adopt the bucket key (the
        push declares their provenance): otherwise unstamped samples would
        survive every later age-out pass and be trusted across code changes.
        ``store`` is mutated in place — every call site passes a throwaway
        (a parsed request body, a computed delta, a freshly-loaded file).

        ``(source, seq)`` makes pushes idempotent for retrying clients
        (:class:`~repro.fleet.client.FleetPusher`): a push whose response was
        lost can be resent with the same sequence number — if the bucket
        already recorded it, the re-push is acknowledged as a ``duplicate``
        without merging again (the samples are already in).  The per-bucket
        dedup window keeps the newest :data:`MAX_SOURCES` sources.
        """
        if not git_sha or not chip:
            raise ValueError(f"push needs a git_sha and chip, got "
                             f"({git_sha!r}, {chip!r})")
        for e in store._entries.values():
            if not e.git_sha:
                e.git_sha = git_sha
            if not e.chip:
                e.chip = chip
        os.makedirs(self.root, exist_ok=True)
        path = self._bucket_path(git_sha, chip)
        with self._locked():
            doc = self._read_bucket(path)
            now = time.time()
            if doc is None:
                doc = {"schema": FLEET_SCHEMA, "git_sha": git_sha, "chip": chip,
                       "created_unix": now, "pushes": 0, "samples": 0,
                       "sources": {}, "store": json.loads(ProfileStore().to_json())}
            sources = doc.setdefault("sources", {})
            if source is not None and seq is not None and sources.get(source, 0) >= seq:
                return {"merged_samples": 0, "duplicate": True, **self._meta(doc)}
            merged = ProfileStore.from_json(json.dumps(doc["store"]))
            n = merged.merge(store)
            doc["store"] = json.loads(merged.to_json())
            doc["pushed_unix"] = now
            doc["pushes"] += 1
            doc["samples"] += n
            doc["entries"] = len(merged)
            if source is not None and seq is not None:
                sources.pop(source, None)  # re-insert: dict order = recency
                sources[source] = seq
                while len(sources) > self.MAX_SOURCES:
                    sources.pop(next(iter(sources)))
            self._write_bucket(path, doc)
            return {"merged_samples": n, **self._meta(doc)}

    def pull(self, git_sha: str, chip: str) -> dict[str, Any]:
        """Best matching bucket: exact → freshest same-chip → miss.

        The chip-only fallback intentionally returns entries stamped with a
        *different* git SHA: the driver's age-out pass evicts them, so a
        mismatched pull degrades to cold exploration rather than trusting
        stale timings.  ``"mixed"``-keyed buckets are skipped at both levels —
        unknown provenance never shadows a real match.
        """
        with self._locked():
            exact = self._read_bucket(self._bucket_path(git_sha, chip))
            if exact is not None and exact.get("git_sha") != MIXED_STAMP:
                return {"match": "exact", "store": exact["store"],
                        **self._meta(exact)}
            best: Optional[dict[str, Any]] = None
            for _, doc in self._iter_buckets():
                if doc.get("chip") != chip or doc.get("git_sha") == MIXED_STAMP:
                    continue
                if best is None or doc.get("pushed_unix", 0) > best.get("pushed_unix", 0):
                    best = doc
            if best is not None:
                return {"match": "chip", "store": best["store"],
                        **self._meta(best)}
            return {"match": "miss", "store": None,
                    "git_sha": git_sha, "chip": chip}

    def ls(self) -> list[dict[str, Any]]:
        """Bucket metadata (no payloads), freshest first within each chip."""
        self._require_root()
        with self._locked():
            rows = [self._meta(doc) for _, doc in self._iter_buckets()]
        rows.sort(key=lambda r: (r.get("chip") or "",
                                 -(r.get("pushed_unix") or 0)))
        return rows

    def gc(
        self,
        max_age_s: Optional[float] = None,
        keep_per_chip: Optional[int] = None,
        now: Optional[float] = None,
    ) -> list[dict[str, Any]]:
        """Staleness/retention sweep; returns the removed buckets' metadata.

        ``max_age_s`` drops buckets whose last push is older; ``keep_per_chip``
        then keeps only the newest N per chip.  ``now`` is injectable for
        deterministic tests.
        """
        now = time.time() if now is None else now
        self._require_root()
        removed: list[dict[str, Any]] = []
        with self._locked():
            by_chip: dict[str, list[tuple[str, dict[str, Any]]]] = {}
            for path, doc in self._iter_buckets():
                age = now - doc.get("pushed_unix", doc.get("created_unix", now))
                if max_age_s is not None and age > max_age_s:
                    removed.append({**self._meta(doc), "reason": f"age {age:.0f}s > {max_age_s:g}s"})
                    os.unlink(path)
                    continue
                by_chip.setdefault(doc.get("chip", "?"), []).append((path, doc))
            if keep_per_chip is not None:
                for chip, rows in by_chip.items():
                    rows.sort(key=lambda r: -(r[1].get("pushed_unix") or 0))
                    for path, doc in rows[keep_per_chip:]:
                        removed.append({**self._meta(doc),
                                        "reason": f"beyond keep_per_chip={keep_per_chip}"})
                        os.unlink(path)
            for name in os.listdir(self.root):  # drop emptied chip dirs
                d = os.path.join(self.root, name)
                if os.path.isdir(d) and not os.listdir(d):
                    os.rmdir(d)
        return removed

    def __len__(self) -> int:
        return sum(1 for _ in self._iter_buckets())
