"""Data substrate: deterministic, resumable synthetic LM pipeline."""
