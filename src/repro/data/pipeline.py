"""Deterministic synthetic LM data pipeline (sharded, resumable).

Design constraints of a 1000+-node data path, kept in the synthetic setting:

* **Stateless indexing** — batch ``i`` is a pure function of (seed, i, shard),
  so resume-after-failure needs only the step counter (stored in the train
  state / checkpoint), and any host can regenerate any shard: no data
  redistribution on elastic resize.
* **Learnable structure** — sequences follow a seeded affine-chain over the
  vocab with occasional resets and copy motifs, so a real model's loss
  actually falls during the example runs (pure-uniform tokens would pin CE at
  ln V).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1  # data-loading hosts
    shard: int = 0


class SyntheticLM:
    """Batch ``i`` -> {"tokens", "labels"} (host numpy, ready to device_put)."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_shards == 0
        self.shard_batch = cfg.global_batch // cfg.n_shards
        base = np.random.Generator(np.random.Philox(key=cfg.seed))
        v = cfg.vocab_size
        # fixed affine-chain params define the learnable structure
        self.mult = int(base.integers(2, max(3, v // 2))) * 2 + 1  # odd -> bijective
        self.add = int(base.integers(1, v))
        self.reset_p = 0.02
        self.noise_p = 0.05

    def batch(self, index: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=[0, 0, cfg.shard, index])
        )
        B, S, V = self.shard_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        resets = rng.random((B, S)) < self.reset_p
        noise = rng.random((B, S)) < self.noise_p
        rand_toks = rng.integers(0, V, (B, S))
        for t in range(1, S + 1):
            nxt = (toks[:, t - 1] * self.mult + self.add) % V
            nxt = np.where(noise[:, t - 1], rand_toks[:, t - 1], nxt)
            toks[:, t] = np.where(resets[:, t - 1], rand_toks[:, t - 1], nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def iterate(self, start: int = 0) -> Iterator[dict[str, np.ndarray]]:
        i = start
        while True:
            yield self.batch(i)
            i += 1
