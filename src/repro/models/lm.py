"""Unified decoder-only LM over a per-layer pattern spec.

One model covers all 10 assigned architectures (dense / MoE / hybrid-SSM /
RWKV / VLM-stub / audio-stub) via ``ModelConfig.layer_pattern``.  Layers are
stacked per pattern position and **scanned over periods**, keeping the HLO
size O(period) instead of O(n_layers) — essential for fast multi-pod
compilation at 512 devices.

Execution surfaces:
  * ``forward``      — hidden states for a full sequence (train / prefill).
  * ``loss_fn``      — token-chunked cross-entropy (never materialises the
                       (B·S, vocab) logits; each chunk is rematerialised in
                       the backward pass).
  * ``prefill``      — forward + KV/SSM cache construction + last-pos logits.
  * ``decode_step``  — one token per sequence against the caches.

Static tracepoints (the paper's USDT analogue, repro.core.tracepoints) are
compiled in at the graph-level boundaries: embed, after the layer stack,
final hidden, loss.  (Markers must stay outside lax.scan bodies — the tape is
functional trace-time state; per-layer taps are provided by the uprobes-style
jaxpr injection instead, which attaches by named_scope.)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import tracepoints as tp
from repro.nn import attention as attn
from repro.nn import core as nn
from repro.nn import ffn as ffn_mod
from repro.nn import frontend as frontend_mod
from repro.nn import mamba as mamba_mod
from repro.nn import rwkv as rwkv_mod

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter construction (single source of truth for values / axes / shapes)
# ---------------------------------------------------------------------------


def _block_init(pf: nn.ParamFactory, cfg: ModelConfig, spec: LayerSpec) -> dict:
    p: dict = {"norm1": nn.rmsnorm_init(pf, "norm1", cfg.d_model)}
    with pf.scope("mixer"):
        if spec.mixer in ("ga", "swa"):
            p["mixer"] = attn.attention_init(pf, cfg)
        elif spec.mixer == "mamba":
            p["mixer"] = mamba_mod.mamba_init(pf, cfg)
        elif spec.mixer == "rwkv":
            p["mixer"] = rwkv_mod.time_mix_init(pf, cfg)
        else:
            raise ValueError(spec.mixer)
    if cfg.post_block_norms:
        p["norm1_post"] = nn.rmsnorm_init(pf, "norm1_post", cfg.d_model)
    if spec.ffn != "none":
        p["norm2"] = nn.rmsnorm_init(pf, "norm2", cfg.d_model)
        with pf.scope("ffn"):
            if spec.ffn == "dense":
                p["ffn"] = ffn_mod.ffn_init(pf, cfg)
            elif spec.ffn == "moe":
                p["ffn"] = ffn_mod.moe_init(pf, cfg)
            elif spec.ffn == "rwkv_ffn":
                p["ffn"] = rwkv_mod.channel_mix_init(pf, cfg)
            else:
                raise ValueError(spec.ffn)
        if cfg.post_block_norms:
            p["norm2_post"] = nn.rmsnorm_init(pf, "norm2_post", cfg.d_model)
    return p


def _unscanned_layers(cfg: ModelConfig) -> list[tuple[str, LayerSpec]]:
    """(scope_name, spec) for layers outside the scanned periods."""
    out = []
    for i in range(cfg.first_k_dense):
        out.append((f"head{i}", cfg.layer_spec(i)))
    tail_start = cfg.first_k_dense + cfg.n_periods * cfg.period
    for i in range(tail_start, cfg.n_layers):
        out.append((f"tail{i}", cfg.layer_spec(i)))
    return out


def build_params(cfg: ModelConfig, pf: nn.ParamFactory) -> dict:
    p: dict = {"embed": nn.embedding_init(pf, "embed", cfg.vocab_size, cfg.d_model)}
    if cfg.frontend != "text":
        with pf.scope("frontend"):
            p["frontend"] = frontend_mod.frontend_init(pf, cfg)
    for name, spec in _unscanned_layers(cfg):
        with pf.scope(name):
            p[name] = _block_init(pf, cfg, spec)
    if cfg.n_periods > 0:
        p["blocks"] = {}
        for pos, spec in enumerate(cfg.layer_pattern):
            with pf.scope(f"pos{pos}"):
                p["blocks"][f"pos{pos}"] = _stacked_init(pf, cfg, spec, cfg.n_periods)
    p["final_norm"] = nn.rmsnorm_init(pf, "final_norm", cfg.d_model)
    if not cfg.tied_embeddings:
        p["lm_head"] = nn.embedding_init(pf, "lm_head", cfg.vocab_size, cfg.d_model)
    return p


def _stacked_init(pf: nn.ParamFactory, cfg: ModelConfig, spec: LayerSpec, n: int):
    """Stack one pattern position's params over the n periods (scan axis)."""
    if isinstance(pf, nn.AxesFactory):
        sub = _block_init(pf, cfg, spec)
        return jax.tree.map(lambda axes: "layers," + axes, sub)
    if isinstance(pf, nn.ValueFactory):
        keys = jax.random.split(pf._key, n)

        def one(key):
            sub_pf = nn.ValueFactory(key, pf.param_dtype)
            sub_pf._scope = list(pf._scope)
            return _block_init(sub_pf, cfg, spec)

        return jax.vmap(one)(keys)
    if isinstance(pf, nn.ShapeFactory):
        sub = _block_init(pf, cfg, spec)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), sub
        )
    raise TypeError(type(pf))


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return build_params(cfg, nn.ValueFactory(key, jnp.dtype(cfg.param_dtype)))


def param_axes(cfg: ModelConfig) -> dict:
    return build_params(cfg, nn.AxesFactory())


def abstract_params(cfg: ModelConfig) -> dict:
    """Allocation-free param skeleton (dry-run)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _block_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int, dtype
) -> dict:
    c: dict = {}
    if spec.mixer in ("ga", "swa"):
        c["mixer"] = attn.init_cache(cfg, spec.mixer, batch, max_seq, dtype)
    elif spec.mixer == "mamba":
        c["mixer"] = mamba_mod.init_cache(cfg, batch, dtype)
    elif spec.mixer == "rwkv":
        c["mixer"] = rwkv_mod.init_time_cache(cfg, batch, dtype)
    if spec.ffn == "rwkv_ffn":
        c["ffn"] = rwkv_mod.init_channel_cache(cfg, batch, dtype)
    return c


def init_caches(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = jnp.dtype(cfg.activation_dtype)
    caches: dict = {}
    for name, spec in _unscanned_layers(cfg):
        caches[name] = _block_cache(cfg, spec, batch, max_seq, dtype)
    if cfg.n_periods > 0:
        caches["blocks"] = {}
        for pos, spec in enumerate(cfg.layer_pattern):
            one = _block_cache(cfg, spec, batch, max_seq, dtype)
            caches["blocks"][f"pos{pos}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), one
            )
    return caches


def abstract_caches(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_seq))


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical axes for cache leaves (mirrors init_caches structure)."""
    A = nn.axes_str

    def block_axes(spec: LayerSpec):
        c = {}
        if spec.mixer in ("ga", "swa"):
            c["mixer"] = {
                "k": A(("batch", "cache_seq", "kv_heads", "head_dim")),
                "v": A(("batch", "cache_seq", "kv_heads", "head_dim")),
                "pos_ids": A(("batch", "cache_seq")),
            }
        elif spec.mixer == "mamba":
            c["mixer"] = {
                "conv": A(("batch", None, "mlp")),
                "ssm": A(("batch", "mlp", None)),
            }
        elif spec.mixer == "rwkv":
            c["mixer"] = {
                "shift": A(("batch", "embed")),
                "wkv": A(("batch", "heads", "head_dim", "head_dim")),
            }
        if spec.ffn == "rwkv_ffn":
            c["ffn"] = {"shift": A(("batch", "embed"))}
        return c

    axes: dict = {}
    for name, spec in _unscanned_layers(cfg):
        axes[name] = block_axes(spec)
    if cfg.n_periods > 0:
        axes["blocks"] = {}
        for pos, spec in enumerate(cfg.layer_pattern):
            axes["blocks"][f"pos{pos}"] = jax.tree.map(
                lambda a: "layers," + a, block_axes(spec)
            )
    return axes


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,
    *,
    mode: str,
    cache: Optional[dict],
) -> tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (x, aux_loss_scalar, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    h = nn.rmsnorm(p["norm1"], x, cfg.norm_eps)
    mixer_cache = cache.get("mixer") if cache else None
    with jax.named_scope(f"mixer_{spec.mixer}"):
        if spec.mixer in ("ga", "swa"):
            h, mc = attn.attention_apply(
                p["mixer"], h, cfg, spec.mixer, positions, mode=mode, cache=mixer_cache
            )
        elif spec.mixer == "mamba":
            h, mc = mamba_mod.mamba_apply(p["mixer"], h, cfg, mode=mode, cache=mixer_cache)
        elif spec.mixer == "rwkv":
            h, mc = rwkv_mod.time_mix_apply(
                p["mixer"], h, cfg, mode=mode, cache=mixer_cache
            )
    if mc is not None:
        new_cache["mixer"] = mc
    if "norm1_post" in p:
        h = nn.rmsnorm(p["norm1_post"], h, cfg.norm_eps)
    x = x + h
    if spec.ffn != "none":
        h = nn.rmsnorm(p["norm2"], x, cfg.norm_eps)
        ffn_cache = cache.get("ffn") if cache else None
        with jax.named_scope(f"ffn_{spec.ffn}"):
            if spec.ffn == "dense":
                h = ffn_mod.ffn_apply(p["ffn"], h, cfg)
            elif spec.ffn == "moe":
                h, moe_aux = ffn_mod.moe_apply(p["ffn"], h, cfg)
                aux = aux + moe_aux["moe_load_balance"] + moe_aux["moe_z_loss"]
            elif spec.ffn == "rwkv_ffn":
                h, fc = rwkv_mod.channel_mix_apply(p["ffn"], h, cfg, cache=ffn_cache)
                if fc is not None:
                    new_cache["ffn"] = fc
        if "norm2_post" in p:
            h = nn.rmsnorm(p["norm2_post"], h, cfg.norm_eps)
        x = x + h
    return x, aux, (new_cache or None)


def _remat(fn, policy: str):
    if policy == "everything":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    positions: Optional[jax.Array] = None,
    frontend_embed: Optional[jax.Array] = None,
    *,
    mode: str = "full",
    caches: Optional[dict] = None,
) -> tuple[jax.Array, jax.Array, Optional[dict]]:
    """tokens: (B, S) -> (hidden (B, S, D), aux_loss, new_caches)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    with jax.named_scope("embed"):
        x = nn.embed(params["embed"], tokens, scale_by_dim=cfg.scale_embedding)
        x = x.astype(jnp.dtype(cfg.activation_dtype))
        if cfg.frontend != "text" and frontend_embed is not None:
            x = x + frontend_mod.frontend_apply(
                params["frontend"], frontend_embed.astype(x.dtype)
            )
    tp.point("lm.embed_out", x)
    aux = jnp.zeros((), jnp.float32)
    new_caches: dict = {}

    # head layers (unscanned)
    unscanned = _unscanned_layers(cfg)
    for name, spec in unscanned:
        if not name.startswith("head"):
            continue
        with jax.named_scope(name):
            x, a, c = _block_apply(
                params[name], x, cfg, spec, positions, mode=mode,
                cache=(caches or {}).get(name),
            )
        aux = aux + a
        if c is not None:
            new_caches[name] = c

    # scanned periods
    if cfg.n_periods > 0:
        block_params = params["blocks"]
        block_caches = (caches or {}).get("blocks")
        want_cache = block_caches is not None

        def period_body(carry, xs):
            x, aux = carry
            pp, pc = xs
            out_caches = {}
            for pos, spec in enumerate(cfg.layer_pattern):
                with jax.named_scope(f"pos{pos}_{spec.mixer}_{spec.ffn}"):
                    x, a, c = _block_apply(
                        pp[f"pos{pos}"], x, cfg, spec, positions, mode=mode,
                        cache=pc[f"pos{pos}"] if pc is not None else None,
                    )
                aux = aux + a
                if c is not None:
                    out_caches[f"pos{pos}"] = c
            return (x, aux), (out_caches if want_cache else None)

        body = _remat(period_body, cfg.remat_policy)
        if cfg.scan_layers:
            (x, aux), scan_caches = jax.lax.scan(
                body, (x, aux), (block_params, block_caches)
            )
        else:
            # unrolled (analysis/dry-run): same math, every period explicit in
            # the HLO so cost_analysis prices all layers.
            per_period = []
            for i in range(cfg.n_periods):
                xs_i = jax.tree.map(lambda a: a[i], (block_params, block_caches))
                (x, aux), c_i = body((x, aux), xs_i)
                per_period.append(c_i)
            scan_caches = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
                if want_cache else None
            )
        if want_cache:
            new_caches["blocks"] = scan_caches

    # tail layers (unscanned)
    for name, spec in unscanned:
        if not name.startswith("tail"):
            continue
        with jax.named_scope(name):
            x, a, c = _block_apply(
                params[name], x, cfg, spec, positions, mode=mode,
                cache=(caches or {}).get(name),
            )
        aux = aux + a
        if c is not None:
            new_caches[name] = c

    tp.point("lm.stack_out", x)
    with jax.named_scope("final_norm"):
        x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, (new_caches or None)


def _logits(params: dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    table = params["embed"] if cfg.tied_embeddings else params["lm_head"]
    logits = nn.unembed(table, hidden)  # f32
    return nn.softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# Loss (token-chunked cross-entropy)
# ---------------------------------------------------------------------------


def loss_fn(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    frontend_embed: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Next-token CE over all positions; logits never fully materialised."""
    hidden, aux, _ = forward(params, cfg, tokens, frontend_embed=frontend_embed)
    B, S, D = hidden.shape
    T = B * S
    chunk = min(cfg.loss_chunk, T)
    n_chunks = T // chunk if T % chunk == 0 else 1
    if T % chunk != 0:
        chunk = T
    h = hidden.reshape(n_chunks, chunk, D)
    y = labels.reshape(n_chunks, chunk)
    table = params["embed"] if cfg.tied_embeddings else params["lm_head"]
    if cfg.loss_table_replicated:
        # §Perf: the FSDP ('data') shard of the table's embed dim would force
        # a partial-sum all-reduce of every chunk's logits (n_chunks of them);
        # replicating the embed dim here hoists ONE all-gather of the table
        # out of the loss loop instead.  Vocab stays TP-sharded.
        from repro.distributed.constrain import constrain

        table = {"table": constrain(table["table"], "vocab", None)}

    @jax.checkpoint
    def chunk_loss(carry, xs):
        h_c, y_c = xs
        logits = nn.unembed(table, h_c)  # (chunk, V) f32
        logits = nn.softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[:, None], axis=-1)[:, 0]
        nll = (lse - gold).sum()
        zl = (lse**2).sum() * cfg.z_loss_weight
        nll_sum, z_sum = carry
        return (nll_sum + nll, z_sum + zl), None

    (nll_sum, z_sum), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (h, y)
    )
    ce = nll_sum / T
    z = z_sum / T
    loss = ce + z + aux
    tp.point("lm.loss", loss)
    return loss, {"ce": ce, "z_loss": z, "aux": aux, "tokens": jnp.float32(T)}


# ---------------------------------------------------------------------------
# Serving surfaces
# ---------------------------------------------------------------------------


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    frontend_embed: Optional[jax.Array] = None,
    *,
    max_seq: Optional[int] = None,
) -> tuple[jax.Array, dict]:
    """Process the prompt; returns (last-position logits (B, V), caches)."""
    B, S = tokens.shape
    caches = init_caches(cfg, B, max_seq or S)
    hidden, _, new_caches = forward(
        params, cfg, tokens, frontend_embed=frontend_embed, mode="full", caches=caches
    )
    logits = _logits(params, cfg, hidden[:, -1:])[:, 0]
    tp.point("lm.prefill_logits", logits)
    return logits, new_caches


def decode_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    cur_pos: jax.Array,
    caches: dict,
    frontend_embed: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """tokens: (B,) new token ids; cur_pos: (B,) absolute positions.

    Returns (logits (B, V), updated caches).
    """
    positions = cur_pos[:, None].astype(jnp.int32)
    hidden, _, new_caches = forward(
        params,
        cfg,
        tokens[:, None],
        positions,
        frontend_embed=frontend_embed,
        mode="decode",
        caches=caches,
    )
    logits = _logits(params, cfg, hidden[:, -1])
    tp.point("lm.decode_logits", logits)
    return logits, new_caches
