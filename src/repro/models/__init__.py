"""Model definitions: one unified decoder-only LM over a layer-pattern spec."""
