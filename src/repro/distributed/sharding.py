"""Logical-axis → mesh-axis sharding rules (MaxText-style, divisibility-safe).

Every parameter / activation / cache leaf carries logical axis names (see
repro.nn.core.AxesFactory).  A *rule set* maps logical names to mesh axes:

  * ``data``  doubles as the FSDP axis: parameter 'embed'/'mlp'-class dims are
    sharded over it (ZeRO-3), all-gathered per scanned block.
  * ``model`` is the TP/EP axis: heads, ffn width, vocab, experts.
  * ``pod``   is the DCN axis: pure data parallelism (batch) — parameters are
    replicated across pods so weight all-gathers never cross DCN.

Divisibility fallback: a mapping is *dropped per-leaf* when the dim size is
not divisible by the mesh axis (e.g. smollm's 15 heads on a 16-way model
axis ⇒ attention params stay replicated on 'model' while its FFN shards).
This is what makes one rule set serve 10 heterogeneous architectures; the
roofline report surfaces the cost of any dropped mapping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.core import parse_axes

PyTree = Any

# Rule sets: logical axis -> mesh axis (or tuple of mesh axes).
# fmt: off
PARAM_RULES: dict[str, Any] = {
    "vocab":      "model",   # TP: embedding/unembedding vocab-sharded
    "heads":      "model",   # TP: attention heads
    "kv_heads":   "model",
    "mlp":        "model",   # TP: FFN width / mamba d_inner
    "expert_mlp": "model",   # fallback when 'experts' itself can't shard
    "experts":    "model",   # EP
    "embed":      "data",    # FSDP (ZeRO-3) over the data axis
    "embed_out":  None,
    "head_dim":   None,
    "layers":     None,      # scan axis
}
ACT_RULES: dict[str, Any] = {
    "batch":      ("pod", "data"),
    "seq":        None,
    "embed":      None,
    "heads":      "model",
    "kv_heads":   "model",
    "mlp":        "model",
    "experts":    "model",
    "vocab":      "model",
    "cache_seq":  None,
}
# fmt: on


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    param: dict[str, Any]
    act: dict[str, Any]

    def with_overrides(self, *, param=None, act=None) -> "ShardingRules":
        return ShardingRules(
            {**self.param, **(param or {})}, {**self.act, **(act or {})}
        )


DEFAULT_RULES = ShardingRules(PARAM_RULES, ACT_RULES)


def _axis_size(mesh: Mesh, assignment) -> int:
    if assignment is None:
        return 1
    if isinstance(assignment, str):
        assignment = (assignment,)
    size = 1
    for a in assignment:
        size *= mesh.shape.get(a, 1)
    return size


def spec_for(
    shape: tuple[int, ...],
    axes_s: str,
    rules: dict[str, Any],
    mesh: Mesh,
) -> P:
    """Build a PartitionSpec, dropping any non-divisible / absent mapping."""
    axes = parse_axes(axes_s)
    assert len(axes) == len(shape), f"axes {axes} vs shape {shape}"
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        assignment = rules.get(name) if name else None
        if assignment is None:
            parts.append(None)
            continue
        if isinstance(assignment, str):
            assignment = (assignment,)
        # keep only mesh axes present, unused so far, and divisible
        kept = []
        remaining = dim
        for a in assignment:
            if a not in mesh.shape or a in used:
                continue
            if remaining % mesh.shape[a] == 0:
                kept.append(a)
                remaining //= mesh.shape[a]
        for a in kept:
            used.add(a)
        parts.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    # strip trailing Nones for tidy specs
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_specs(
    tree_axes: PyTree, tree_shapes: PyTree, rules: dict[str, Any], mesh: Mesh
) -> PyTree:
    """Map (axes-string tree, shaped tree) -> PartitionSpec tree."""
    return jax.tree.map(
        lambda axes_s, leaf: spec_for(tuple(leaf.shape), axes_s, rules, mesh),
        tree_axes,
        tree_shapes,
    )


def tree_shardings(
    tree_axes: PyTree, tree_shapes: PyTree, rules: dict[str, Any], mesh: Mesh
) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs(tree_axes, tree_shapes, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def shard_bytes_per_device(tree_shapes: PyTree, tree_specs_: PyTree, mesh: Mesh) -> int:
    """Napkin per-device bytes for a sharded tree (dry-run feasibility)."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree_shapes), jax.tree.leaves(
        tree_specs_, is_leaf=lambda x: isinstance(x, P)
    )):
        n = int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
        denom = 1
        for part in spec:
            for a in (part if isinstance(part, tuple) else (part,)) if part else ():
                denom *= mesh.shape[a]
        total += n * np.dtype(leaf.dtype).itemsize // denom
    return total


def rules_for_shape(
    kind: str,
    *,
    global_batch: int,
    seq_len: int,
    mesh: Mesh,
    n_kv_heads: int,
    weight_stationary: bool = False,
) -> ShardingRules:
    """Shape-conditional rule adjustments (the production heuristics).

    * decode shapes: KV caches shard kv_heads over 'model' when divisible,
      else the cache *sequence* dim goes to 'model' (flash-decoding split-KV —
      GSPMD inserts the distributed-softmax collectives).
    * long-context (batch < data axis): sequence-parallel decode — the cache
      seq dim shards over 'data' (and kv-head sharding stays on 'model').
    * ``weight_stationary`` (§Perf, decode only): ZeRO-style FSDP weight
      gathers cost GBs *per generated token*; instead 2D-shard the weights'
      output dims over (data × model), replicate the (tiny) per-token
      activations over 'data', and shard caches over spare axes.  Weights
      never move; only KB-scale activation partials are reduced.
    """
    rules = DEFAULT_RULES
    if kind not in ("decode",):
        return rules
    data_ax = mesh.shape.get("data", 1)
    model_ax = mesh.shape.get("model", 1)
    batch_axes = _axis_size(mesh, ACT_RULES["batch"])
    act: dict[str, Any] = {}
    if weight_stationary:
        act["batch"] = ("pod",) if "pod" in mesh.shape else None
        act["mlp"] = ("data", "model")
        act["experts"] = "model"
        if n_kv_heads % model_ax == 0:
            act["cache_seq"] = "data"
        else:
            act["cache_seq"] = ("data", "model")
            act["kv_heads"] = None
        param = {
            "embed": None,  # no FSDP at decode: weights stay put
            "mlp": ("data", "model"),
            "expert_mlp": "data",  # experts already on 'model'
        }
        return rules.with_overrides(param=param, act=act)
    if global_batch < batch_axes:
        # SP: batch can't fill (pod, data) — put cache seq on 'data' instead.
        act["batch"] = None if global_batch < data_ax else ("pod",)
        act["cache_seq"] = "data"
        if n_kv_heads % model_ax != 0:
            act["cache_seq"] = ("data", "model")
            act["kv_heads"] = None
    elif n_kv_heads % model_ax != 0:
        # GQA too narrow for TP: split-KV over 'model' instead of replicating.
        act["cache_seq"] = "model"
        act["kv_heads"] = None
    return rules.with_overrides(act=act)
