"""Distribution layer: mesh construction + logical-axis sharding rules."""
