"""Activation sharding constraints by logical axis names (mesh-optional).

``constrain(x, "batch", "seq", "heads", "head_dim")`` applies a
with_sharding_constraint built from ACT_RULES against the ambient mesh —
divisibility-safe (a non-divisible mapping is dropped per-dim, same policy as
parameter sharding), and a no-op when no mesh is active (CPU unit tests).
"""
from __future__ import annotations

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding

from repro.distributed.sharding import ACT_RULES, spec_for
from repro.nn.core import axes_str


def _ambient_mesh():
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def constrain(x: jax.Array, *axes: str | None, rules: dict | None = None) -> jax.Array:
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = spec_for(tuple(x.shape), axes_str(tuple(axes)), rules or ACT_RULES, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
