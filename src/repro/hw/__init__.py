from repro.hw.specs import CHIPS, MXU_ALIGN, TPU_V5E, ChipSpec, default_chip

__all__ = ["CHIPS", "MXU_ALIGN", "TPU_V5E", "ChipSpec", "default_chip"]
