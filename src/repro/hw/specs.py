"""Hardware backend models.

This is the Adaptyst-style "backend module" registry: every SDFG node is
eventually assigned to one of these component models (MXU / VPU / HBM / ICI /
HOST), and the roofline engine prices a node's work against the component it
was assigned to.  The numbers below are the TARGET hardware (TPU v5e); the
container we develop on is CPU-only, so these are modelling constants, never
measured.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware constants for one accelerator generation."""

    name: str
    # Compute units.
    peak_flops_bf16: float  # FLOP/s, MXU systolic arrays
    peak_flops_f32: float
    # Memory hierarchy (HBM -> VMEM -> VREG).
    hbm_bytes: int
    hbm_bw: float  # bytes/s
    vmem_bytes: int
    # Interconnect.
    ici_link_bw: float  # bytes/s per link, one direction
    ici_links: int  # links per chip (2D torus on v5e: 4)
    # Host link (PCIe) — the "system" side of the sys/user split.
    host_bw: float

    @property
    def ici_bisection_bw(self) -> float:
        return self.ici_link_bw * self.ici_links


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024**2,
    ici_link_bw=50e9,
    ici_links=4,
    host_bw=32e9,
)

# Registry keyed by name so configs can select hardware symbolically.
CHIPS: dict[str, ChipSpec] = {"tpu_v5e": TPU_V5E}

# MXU tile alignment: matmul dims should be multiples of this for full
# systolic-array utilisation; Pallas BlockSpecs in kernels/ honour it.
MXU_ALIGN = 128
# VPU lane/sublane shape for fp32 (8, 128); bf16 packs (16, 128).
VPU_LANES = 128
VPU_SUBLANES = 8


def default_chip() -> ChipSpec:
    return TPU_V5E
