"""Decode attention Pallas TPU kernel — one new token vs. a KV cache.

Flash-decoding adapted to the TPU memory system:
* Decode is HBM-bandwidth-bound (the whole KV cache is read once per token,
  arithmetic intensity ≈ 1 FLOP/byte), so the kernel's job is to stream K/V
  tiles HBM→VMEM at full bandwidth while the VPU does the mask/softmax work.
* GQA rows are batched: the grid is (batch, kv_heads, kv_blocks) and the q
  tile holds all G = Hq/Hkv rows that share one KV head, so each streamed KV
  tile is reused G times (a GPU warp-level trick re-expressed as tile shape).
* Ring-buffer SWA caches are handled by slot-position masking: pos_ids[b, s]
  carries the absolute position held in cache slot s (-1 = empty), the same
  contract as kernels.ref.decode_attention_ref.

Validated against the ref oracle with interpret=True in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    cur_ref,
    q_ref,
    k_ref,
    v_ref,
    pos_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    window: Optional[int],
    softcap: Optional[float],
    n_blocks: int,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cur = cur_ref[0]
    pos = pos_ref[0]  # (block_s,) int32 slot positions
    ok = (pos >= 0) & (pos <= cur)
    if window is not None:
        ok &= pos > cur - window

    @pl.when(jnp.any(ok))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_s, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, block_s)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(ok[None, :], s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "block_s", "interpret"),
)
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos_ids: jax.Array,
    cur_pos: jax.Array,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, D); caches: (B, S, Hkv, D); pos_ids: (B, S); cur_pos: (B,)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    block_s = min(block_s, S)
    pad_s = -S % block_s
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    pos = pos_ids
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad_s)), constant_values=-1)
    n_blocks = (S + pad_s) // block_s
    qt = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(
        _decode_kernel,
        scale=scale,
        window=window,
        softcap=softcap,
        n_blocks=n_blocks,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, si: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, si: (b, h, si, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, si: (b, h, si, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, si: (b, si)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(cur_pos.astype(jnp.int32), qt, kt, vt, pos.astype(jnp.int32))
    return out.reshape(B, Hq, D)
