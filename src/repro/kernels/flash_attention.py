"""Flash attention Pallas TPU kernel (causal / sliding-window / GQA / softcap).

TPU-native design (not a CUDA port):
* Blocks are MXU-aligned (block_q × block_k = 128×128 by default, multiples of
  128 on the contracting dims) so the s = q·kᵀ and p·v matmuls map to the
  systolic array at full utilisation.
* The grid is (batch, q_heads, q_blocks, kv_blocks); on TPU the grid is
  executed sequentially with the last dim fastest, so the f32 running-softmax
  state (m, l, acc) lives in VMEM scratch and persists across the kv_block
  sweep — the HBM→VMEM pipeline streams one (block_k, head_dim) K/V tile per
  step while the previous tile is being consumed (double-buffered by Mosaic).
* Fully-masked tiles (above the causal diagonal, or outside the sliding
  window) skip their matmuls via pl.when — the same work-skipping a GPU kernel
  would get from early-exiting thread blocks.

Validated against kernels.ref.mha_ref with interpret=True in
tests/test_kernels.py (CPU container; TPU is the lowering target).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    sq: int,
    sk: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < sk  # kv padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window

    # Tile-level skip: first q row is the latest, last k col the earliest.
    any_live = jnp.any(mask)

    @pl.when(any_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "softcap",
        "scale",
        "block_q",
        "block_k",
        "q_offset",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    # head-major layout for clean 2D tiles
    qt = q.transpose(0, 2, 1, 3)  # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    pad_q = -Sq % block_q
    pad_k = -Sk % block_k
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = qt.shape[2] // block_q
    n_k = kt.shape[2] // block_k

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
        sq=Sq,
        sk=Sk,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, n_q * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
